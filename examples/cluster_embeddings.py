"""Cluster LM hidden states with the distributed mini-batch kernel k-means
service — the framework's first-class integration of the paper's technique
(DESIGN.md §6): here, pseudo-labeling HuBERT-style audio features through
the sharded ``KernelKMeans`` plan.

    PYTHONPATH=src python examples/cluster_embeddings.py
    # multi-device (simulated):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/cluster_embeddings.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelKMeans, SolverConfig
from repro.configs import get_config
from repro.core import median_sq_dist_heuristic
from repro.models import forward_train, init_params

# a reduced hubert-style encoder produces the features we cluster
cfg = get_config("hubert-xlarge").reduced(dtype="float32")
params = init_params(cfg, jax.random.PRNGKey(0))


def hidden_states(n_batches=40, batch=4, seq=64):
    """(B*S, D) hidden-state features from the encoder."""
    feats = []
    for i in range(n_batches):
        key = jax.random.fold_in(jax.random.PRNGKey(42), i)
        frames = jax.random.normal(key, (batch, seq, cfg.frontend_dim))
        logits = forward_train(params, cfg, {"embeds": frames})
        del logits  # features below; logits shown for the full path
        h = frames @ params["frontend_w"]         # frontend projection
        feats.append(np.asarray(h.reshape(-1, cfg.d_model)))
    return np.concatenate(feats, axis=0)


if len(jax.devices()) > 1:
    mesh = jax.make_mesh((len(jax.devices()) // 2, 2), ("data", "model"))
else:
    mesh = jax.make_mesh((1, 1), ("data", "model"))

# deliberately a NON-divisible row count: the estimator pads the dataset
# over the data shards and masks the pad rows out of the shard-local
# samplers (no synthetic point ever enters a batch) — this was a hard
# ValueError on the legacy fit_distributed_jit surface.
feats = hidden_states()[:-3]
kappa = float(median_sq_dist_heuristic(jnp.asarray(feats[:1024])))

est = KernelKMeans(
    SolverConfig(k=8, batch_size=256, tau=128, epsilon=1e-4, max_iters=30,
                 kernel="rbf", kernel_params={"kappa": kappa},
                 distribution="sharded", cache="none", jit=True),
    mesh=mesh)
est.fit(jnp.asarray(feats), key=0)

print(f"devices={len(jax.devices())} mesh={dict(mesh.shape)} "
      f"plan={est.plan_.name}")
print(f"clustered {feats.shape[0]} hidden states into k=8 pseudo-labels; "
      f"{int(est.iters_)} iterations (fully on-device while_loop)")
labels = est.predict(jnp.asarray(feats[:4096]))
print("pseudo-label histogram:", jnp.bincount(labels, length=8).tolist())
print("per-center window fill:", np.asarray(
    (est.state_.coef > 0).sum(axis=1)).tolist())
