"""Cluster LM hidden states with the distributed mini-batch kernel k-means
service — the framework's first-class integration of the paper's technique
(DESIGN.md §6): here, pseudo-labeling HuBERT-style audio features.

    PYTHONPATH=src python examples/cluster_embeddings.py
    # multi-device (simulated):
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/cluster_embeddings.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import Gaussian, MBConfig, median_sq_dist_heuristic
from repro.core.distributed import cluster_hidden_states
from repro.models import forward_train, init_params
from repro.models.common import rms_norm

# a reduced hubert-style encoder produces the features we cluster
cfg = get_config("hubert-xlarge").reduced(dtype="float32")
params = init_params(cfg, jax.random.PRNGKey(0))


def hidden_state_stream(n_batches=40, batch=4, seq=64):
    """Stream of (tokens, hidden-state) batches from the encoder."""
    for i in range(n_batches):
        key = jax.random.fold_in(jax.random.PRNGKey(42), i)
        frames = jax.random.normal(key, (batch, seq, cfg.frontend_dim))
        # take pre-head hidden states as features (B*S, D)
        logits = forward_train(params, cfg, {"embeds": frames})
        del logits  # features below; logits shown for the full path
        h = frames @ params["frontend_w"]         # frontend projection
        yield np.asarray(h.reshape(-1, cfg.d_model))


if len(jax.devices()) > 1:
    mesh = jax.make_mesh((len(jax.devices()) // 2, 2), ("data", "model"))
else:
    mesh = jax.make_mesh((1, 1), ("data", "model"))

first = next(hidden_state_stream(1))
kappa = float(median_sq_dist_heuristic(jnp.asarray(first)))
kern = Gaussian(kappa=jnp.float32(kappa))
mb = MBConfig(k=8, batch_size=first.shape[0], tau=128, epsilon=1e-4,
              max_iters=30)

state, hist = cluster_hidden_states(
    hidden_state_stream(), k=8, kernel=kern, cfg=mb, mesh=mesh)
print(f"devices={len(jax.devices())} mesh={dict(mesh.shape)}")
print(f"clustered hidden states into k=8 pseudo-labels; "
      f"{len(hist)} iterations")
print(f"objective {hist[0]['f_before']:.4f} -> {hist[-1]['f_after']:.4f}")
print("per-center window fill:", np.asarray(
    (state.coef > 0).sum(axis=1)).tolist())
