"""End-to-end training driver: a ~100M-parameter qwen3-family model trained
for a few hundred steps on the deterministic token pipeline, with
checkpointing + crash recovery enabled.

    PYTHONPATH=src python examples/train_lm.py            # CPU demo scale
    PYTHONPATH=src python examples/train_lm.py --full     # ~100M config

(The paper's kind is an algorithmic clustering speedup, so the clustering
service launcher `repro.launch.cluster` is the paper-native driver; this
example proves the LM substrate trains end to end.)
"""
import argparse
import dataclasses
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import TokenPipeline
from repro.models import init_params
from repro.train import AdamWConfig, make_train_state, make_train_step
from repro.train.checkpoint import Checkpointer
from repro.train.resilience import run_resilient

ap = argparse.ArgumentParser()
ap.add_argument("--full", action="store_true",
                help="~100M params (slow on 1 CPU core)")
ap.add_argument("--steps", type=int, default=None)
args = ap.parse_args()

base = get_config("qwen3-1.7b")
if args.full:
    # ~100M-class: 12 x 512 with the qwen3 feature set
    cfg = dataclasses.replace(
        base, n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
        head_dim=64, d_ff=1536, vocab=32000, dtype="float32")
    steps, batch, seq = args.steps or 300, 8, 256
else:
    cfg = base.reduced(dtype="float32")
    steps, batch, seq = args.steps or 200, 8, 64

params = init_params(cfg, jax.random.PRNGKey(0))
n = sum(int(x.size) for x in jax.tree.leaves(params))
print(f"model: {cfg.name}-style, {n / 1e6:.1f}M params "
      f"({cfg.n_layers}L d={cfg.d_model})")

state = make_train_state(params)
step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup=20)),
               donate_argnums=(0,))
pipe = TokenPipeline(vocab=cfg.vocab, batch=batch, seq=seq, seed=0)

losses = []


def logging_step(st, b):
    st, m = step(st, b)
    losses.append(float(m["loss"]))
    s = int(st.step)
    if s % 25 == 0 or s == 1:
        print(f"step {s:4d}  loss {losses[-1]:.4f}")
    return st, m


with tempfile.TemporaryDirectory() as d:
    state, hist = run_resilient(
        logging_step, pipe, state, steps, Checkpointer(d), ckpt_every=50,
        make_state_like=lambda: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state))

first, last = np.mean(losses[:10]), np.mean(losses[-10:])
print(f"loss: {first:.4f} -> {last:.4f} "
      f"({'LEARNED' if last < first - 0.2 else 'check config'})")
