"""Quickstart: mini-batch kernel k-means on non-linearly-separable data.

    PYTHONPATH=src python examples/quickstart.py

Plain k-means cannot separate two concentric circles; kernel k-means with a
graph heat kernel nails it — and the mini-batch algorithm (the paper's
contribution) does so while touching only b points per iteration.  Every
execution strategy is one ``SolverConfig`` point behind the single
``KernelKMeans`` front door (docs/api.md).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.api import KernelKMeans, SolverConfig
from repro.core import adjusted_rand_index, gamma_of
from repro.core.lloyd import kmeans_fit
from repro.data import circles
from repro.data.graph_kernels import heat_kernel

x, y = circles(n=2000, seed=0)

# 1) plain k-means fails
_, assign_plain, _ = kmeans_fit(jnp.asarray(x), 2, jax.random.PRNGKey(0))
print(f"plain k-means      ARI = "
      f"{adjusted_rand_index(y, np.asarray(assign_plain)):.3f}")

# 2) truncated mini-batch kernel k-means (Algorithm 2) through the
#    estimator: the heat kernel is a Precomputed pytree, so the "data" is
#    its (n, 1) index view xi
kern, xi = heat_kernel(x, k=10, t=2000.0)
kern = jax.tree.map(jnp.asarray, kern)
xi = jnp.asarray(xi)
print(f"heat-kernel gamma  = {float(gamma_of(kern, xi)):.4f}  (<< 1, "
      "so Theorem 1 allows a tiny batch)")

cfg = SolverConfig(k=2, batch_size=256, tau=200, epsilon=1e-4,
                   max_iters=200, kernel=kern, cache="none",
                   distribution="single", jit=False)
est = KernelKMeans(cfg).fit(xi, key=0)
pred = np.asarray(est.predict(xi))
print(f"mini-batch kernel  ARI = {adjusted_rand_index(y, pred):.3f}  "
      f"({len(est.history_)} iterations, early-stopped, "
      f"window = {cfg.tau}+{cfg.batch_size} points/center, "
      f"plan = {est.plan_.name})")

# 3) same fit through the Gram tile cache (docs/cache.md): flip ONE config
#    axis — batches keep resampling the same rows, so most kernel
#    evaluations are redundant; the cache serves them as gathers and
#    counts what it saved.
from repro.cache import stats

x2, y2 = circles(n=2048, seed=1)
x2j = jnp.asarray(x2, jnp.float32)
cfg2 = SolverConfig(k=2, batch_size=256, tau=200, epsilon=1e-4,
                    max_iters=60, kernel="rbf",
                    kernel_params={"kappa": 0.5}, cache="lru",
                    sampler="nested", cache_tile=128, cache_capacity=16,
                    distribution="single", jit=False)
est2 = KernelKMeans(cfg2).fit(x2j, key=0)
s = stats(est2.cache_.cache)
w = cfg2.tau + cfg2.batch_size
uncached = len(est2.history_) * (2 * cfg2.batch_size * cfg2.k * w
                                 + cfg2.k * w * w)
print(f"cached fit         {len(est2.history_)} iterations, hit rate "
      f"{s['hit_rate']:.0%} ({s['misses']} tile misses = {s['evals']} "
      f"kernel evals instead of ~{uncached})")

# 4) serving round-trip: save the fitted centers, reload in a fresh
#    process-like estimator, predict — no cache, Gram or mesh needed.
path = est2.save("/tmp/quickstart_centers.npz")
served = KernelKMeans.load(path)
agree = float(jnp.mean((served.predict(x2j) == est2.predict(x2j))
                       .astype(jnp.float32)))
print(f"save/load/predict  agreement = {agree:.0%} ({path})")
