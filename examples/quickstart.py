"""Quickstart: mini-batch kernel k-means on non-linearly-separable data.

    PYTHONPATH=src python examples/quickstart.py

Plain k-means cannot separate two concentric circles; kernel k-means with a
graph heat kernel nails it — and the mini-batch algorithm (the paper's
contribution) does so while touching only b points per iteration.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    MBConfig, adjusted_rand_index, fit, gamma_of, predict,
)
from repro.core.lloyd import kmeans_fit
from repro.data import circles
from repro.data.graph_kernels import heat_kernel

x, y = circles(n=2000, seed=0)

# 1) plain k-means fails
_, assign_plain, _ = kmeans_fit(jnp.asarray(x), 2, jax.random.PRNGKey(0))
print(f"plain k-means      ARI = "
      f"{adjusted_rand_index(y, np.asarray(assign_plain)):.3f}")

# 2) truncated mini-batch kernel k-means (Algorithm 2)
kern, xi = heat_kernel(x, k=10, t=2000.0)
kern = jax.tree.map(jnp.asarray, kern)
xi = jnp.asarray(xi)
print(f"heat-kernel gamma  = {float(gamma_of(kern, xi)):.4f}  (<< 1, "
      "so Theorem 1 allows a tiny batch)")

cfg = MBConfig(k=2, batch_size=256, tau=200, epsilon=1e-4, max_iters=200)
state, hist = fit(xi, kern, cfg, jax.random.PRNGKey(0))
pred = np.asarray(predict(state, xi, xi, kern))
print(f"mini-batch kernel  ARI = {adjusted_rand_index(y, pred):.3f}  "
      f"({len(hist)} iterations, early-stopped, "
      f"window = {cfg.tau}+{cfg.batch_size} points/center)")

# 3) same fit through the Gram tile cache (docs/cache.md): batches keep
#    resampling the same rows, so most kernel evaluations are redundant —
#    the cache serves them as gathers and counts what it saved.
from repro.cache import stats
from repro.core import fit_cached

x2, y2 = circles(n=2048, seed=1)
from repro.core import Gaussian
gk = Gaussian(kappa=jnp.float32(0.5))
x2j = jnp.asarray(x2, jnp.float32)
cfg2 = MBConfig(k=2, batch_size=256, tau=200, epsilon=1e-4, max_iters=60)
state2, hist2, ck = fit_cached(x2j, gk, cfg2, jax.random.PRNGKey(0),
                               tile=128, capacity=16, sampler="nested")
s = stats(ck.cache)
w = cfg2.tau + cfg2.batch_size
uncached = len(hist2) * (2 * cfg2.batch_size * cfg2.k * w
                         + cfg2.k * w * w)
print(f"cached fit         {len(hist2)} iterations, hit rate "
      f"{s['hit_rate']:.0%} ({s['misses']} tile misses = {s['evals']} "
      f"kernel evals instead of ~{uncached})")
