"""Training runtime: optimization, microbatching, gradient compression,
checkpointing, crash recovery (fault tolerance)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import TokenPipeline
from repro.models import ModelConfig, init_params
from repro.train import AdamWConfig, make_train_state, make_train_step
from repro.train.checkpoint import Checkpointer
from repro.train.resilience import run_resilient

CFG = ModelConfig(name="tiny", n_layers=2, d_model=64, n_heads=4,
                  n_kv_heads=2, d_ff=128, vocab=256, head_dim=16,
                  dtype="float32", remat=False)
OPT = AdamWConfig(lr=3e-3, warmup=5)


def _setup(compress=False, microbatch=None):
    params = init_params(CFG, jax.random.PRNGKey(0))
    state = make_train_state(params, compress=compress)
    step = jax.jit(make_train_step(CFG, OPT, microbatch=microbatch,
                                   compress=compress))
    pipe = TokenPipeline(vocab=256, batch=8, seq=32, seed=0)
    return state, step, pipe


def test_loss_decreases():
    state, step, pipe = _setup()
    losses = []
    for i in range(30):
        state, m = step(state, pipe(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_microbatch_matches_full_batch():
    s1, step1, pipe = _setup()
    s2, step2, _ = _setup(microbatch=4)
    b = pipe(0)
    s1, m1 = step1(s1, b)
    s2, m2 = step2(s2, b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), abs=1e-5)
    for a, c in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c), atol=2e-5)


def test_grad_compression_error_feedback():
    state, step, pipe = _setup(compress=True)
    losses = []
    for i in range(25):
        state, m = step(state, pipe(i))
        losses.append(float(m["loss"]))
    # still trains
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05
    # error-feedback buffer is live (residuals being carried)
    ef_norm = sum(float(jnp.sum(jnp.abs(x)))
                  for x in jax.tree.leaves(state.ef_error))
    assert ef_norm > 0


def test_checkpoint_roundtrip(tmp_path):
    state, step, pipe = _setup()
    for i in range(3):
        state, _ = step(state, pipe(i))
    ck = Checkpointer(str(tmp_path))
    ck.save(3, state, meta={"mesh": [1]}, blocking=True)
    assert ck.latest_step() == 3
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        state)
    restored = ck.restore(3, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ck.manifest(3)["step"] == 3


def test_async_checkpoint(tmp_path):
    state, step, pipe = _setup()
    ck = Checkpointer(str(tmp_path))
    ck.save(1, state)          # async
    ck.wait()
    assert ck.latest_step() == 1


def test_crash_recovery_bit_exact(tmp_path):
    """Deterministic (seed, step) pipeline + checkpoint restart == the
    uninterrupted run, exactly (the fault-tolerance contract)."""
    n_steps, ckpt_every = 17, 5

    # uninterrupted reference
    state_ref, step, pipe = _setup()
    for i in range(n_steps):
        state_ref, _ = step(state_ref, pipe(i))

    # crashing run: dies at step 12, twice
    crashes = {12: 2}

    def crashing_step(state, batch):
        s = int(state.step)
        if s in crashes and crashes[s] > 0:
            crashes[s] -= 1
            raise RuntimeError("injected node failure")
        return step(state, batch)

    state0, _, _ = _setup()
    ck = Checkpointer(str(tmp_path))
    final, hist = run_resilient(
        crashing_step, pipe, state0, n_steps, ck, ckpt_every=ckpt_every,
        max_restarts=5,
        make_state_like=lambda: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state0))
    assert int(final.step) == n_steps
    for a, b in zip(jax.tree.leaves(state_ref.params),
                    jax.tree.leaves(final.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_timeout_triggers_recovery(tmp_path):
    import time

    state0, step, pipe = _setup()
    step(state0, pipe(0))       # warm the jit cache (compile != straggler)
    slow = {"armed": True}

    def maybe_slow_step(state, batch):
        if int(state.step) == 6 and slow["armed"]:
            slow["armed"] = False
            time.sleep(0.5)     # straggler
        return step(state, batch)

    ck = Checkpointer(str(tmp_path))
    final, hist = run_resilient(
        maybe_slow_step, pipe, state0, 10, ck, ckpt_every=2,
        step_timeout_s=0.4, max_restarts=5,
        make_state_like=lambda: jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state0))
    assert int(final.step) == 10


def test_pipeline_determinism():
    p1 = TokenPipeline(vocab=128, batch=4, seq=16, seed=3)
    p2 = TokenPipeline(vocab=128, batch=4, seq=16, seed=3)
    for s in (0, 5, 11):
        np.testing.assert_array_equal(np.asarray(p1(s)["tokens"]),
                                      np.asarray(p2(s)["tokens"]))
    assert not np.array_equal(np.asarray(p1(0)["tokens"]),
                              np.asarray(p1(1)["tokens"]))
