"""The unified estimator surface: KernelKMeans + SolverConfig + plan layer.

Grid equivalence against the legacy twins lives in test_api_grid.py; here:
config validation, the kernel name registry, unified key derivation,
save/load round-trip, partial_fit resumption, the solver registry, the
public-API lock, and the deprecation-warning contract of the shims.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.api
from repro.api import (
    KernelKMeans, SolverConfig, list_kernels, list_solvers, make_kernel,
    register_solver, resolve_plan, unregister_solver,
)
from repro.api import keys as api_keys
from repro.core.kernel_fns import Gaussian, register_kernel_factory
from repro.data import blobs

GAUSS = Gaussian(kappa=jnp.float32(1.5))


def _blobs(n=256, d=8, k=4, seed=0):
    x, _ = blobs(n=n, d=d, k=k, seed=seed)
    return jnp.asarray(x)


def _cfg(**kw):
    base = dict(k=4, batch_size=32, tau=16, max_iters=6, epsilon=-1.0,
                kernel=GAUSS, cache="none", distribution="single",
                jit=False)
    base.update(kw)
    return SolverConfig(**base)


# ------------------------------------------------------------ SolverConfig
def test_config_validates_axes():
    for bad in (dict(cache="lfu"), dict(distribution="multihost"),
                dict(sampler="poisson"), dict(restarts=0),
                dict(init="farthest")):
        with pytest.raises(ValueError):
            _cfg(**bad)


def test_config_auto_resolution():
    c = SolverConfig(kernel="rbf")                  # cache/distribution auto
    r = c.resolve(n=512, mesh=None)
    assert r.distribution == "single"
    assert r.cache == "precomputed"                 # n^2 small -> full Gram
    r2 = c.resolve(n=1 << 20, mesh=None)
    assert r2.cache == "none"
    r3 = c.replace(sampler="nested").resolve(n=1 << 20)
    assert r3.cache == "lru"
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    assert c.resolve(n=512, mesh=mesh).distribution == "sharded"
    # precomputed kernels never get another cache layer on top
    from repro.core.kernel_fns import Precomputed
    pk = Precomputed(gram=jnp.eye(8))
    assert SolverConfig(kernel=pk).resolve(n=8).cache == "none"


# ------------------------------------------------------- kernel registry
def test_kernel_registry_names_and_resolution():
    names = list_kernels()
    for expected in ("rbf", "gaussian", "laplacian", "polynomial",
                     "linear", "precomputed"):
        assert expected in names
    k = make_kernel("rbf", kappa=2.0)
    assert isinstance(k, Gaussian)
    assert float(k.kappa) == 2.0
    # instance passthrough
    assert make_kernel(GAUSS) is GAUSS
    with pytest.raises(ValueError, match="registered kernels"):
        make_kernel("not-a-kernel")
    with pytest.raises(ValueError, match="kernel_params"):
        make_kernel(GAUSS, kappa=1.0)


def test_kernel_registry_duplicate_name_raises():
    with pytest.raises(ValueError, match="already registered"):
        register_kernel_factory("rbf", lambda: GAUSS)
    # overwrite with itself round-trips cleanly
    register_kernel_factory("test_dup_kernel", lambda: GAUSS)
    try:
        with pytest.raises(ValueError, match="already registered"):
            register_kernel_factory("test_dup_kernel", lambda: GAUSS)
        register_kernel_factory("test_dup_kernel", lambda: GAUSS,
                                overwrite=True)
    finally:
        from repro.core.kernel_fns import _KERNEL_FACTORIES
        _KERNEL_FACTORIES.pop("test_dup_kernel", None)


def test_config_kernel_string_matches_instance():
    x = _blobs()
    key = jax.random.PRNGKey(3)
    by_name = KernelKMeans(_cfg(kernel="rbf",
                                kernel_params={"kappa": 1.5})).fit(x, key)
    by_inst = KernelKMeans(_cfg(kernel=GAUSS)).fit(x, key)
    np.testing.assert_array_equal(np.asarray(by_name.state_.sqnorm),
                                  np.asarray(by_inst.state_.sqnorm))


# ------------------------------------------------------------ key unification
def test_same_seed_same_batches_across_single_restart_plans():
    """The satellite fix: one seed -> one batch sequence for the whole
    single-restart family.  Window contents (dataset row ids) are the
    batch-sequence fingerprint; the cached plan computes identical indices
    (tile-blocked Gram numerics differ only in float rounding)."""
    x = _blobs()
    key = jax.random.PRNGKey(11)
    host = KernelKMeans(_cfg()).fit(x, key)
    jit = KernelKMeans(_cfg(jit=True)).fit(x, key)
    lru = KernelKMeans(_cfg(cache="lru", cache_tile=32,
                            cache_capacity=8)).fit(x, key)
    np.testing.assert_array_equal(np.asarray(host.state_.idx),
                                  np.asarray(jit.state_.idx))
    np.testing.assert_array_equal(np.asarray(host.state_.idx),
                                  np.asarray(lru.state_.idx))
    np.testing.assert_allclose(np.asarray(host.state_.sqnorm),
                               np.asarray(jit.state_.sqnorm), atol=1e-5)
    np.testing.assert_allclose(np.asarray(host.state_.sqnorm),
                               np.asarray(lru.state_.sqnorm), atol=1e-5)


def test_key_helpers_document_the_derivation():
    key = jax.random.PRNGKey(0)
    ik, fk = api_keys.split_init(key)
    np.testing.assert_array_equal(np.asarray(jax.random.split(key)[0]),
                                  np.asarray(ik))
    k1, kb1 = api_keys.next_batch_key(fk)
    np.testing.assert_array_equal(np.asarray(jax.random.split(fk)[1]),
                                  np.asarray(kb1))
    # batch_key_at replays the sequential stream
    k2, kb2 = api_keys.next_batch_key(k1)
    np.testing.assert_array_equal(np.asarray(api_keys.batch_key_at(fk, 1)),
                                  np.asarray(kb2))


# ------------------------------------------------------------- estimator
def test_estimator_transform_score_and_shapes():
    x = _blobs()
    est = KernelKMeans(_cfg()).fit(x, jax.random.PRNGKey(0))
    d = est.transform(x[:17])
    assert d.shape == (17, 4)
    assert bool(jnp.all(d >= -1e-6))
    labels = est.predict(x[:17])
    np.testing.assert_array_equal(np.asarray(labels),
                                  np.asarray(jnp.argmin(d, axis=1)))
    s = est.score(x)
    assert np.isfinite(s) and s <= 0


def test_estimator_fit_predict_matches_legacy_predict():
    from repro.core.minibatch import predict as legacy_predict

    x = _blobs()
    est = KernelKMeans(_cfg()).fit(x, jax.random.PRNGKey(1))
    want = legacy_predict(est.state_, x, x[:50], GAUSS)
    np.testing.assert_array_equal(np.asarray(est.predict(x[:50])),
                                  np.asarray(want))


def test_save_load_predict_roundtrip(tmp_path):
    x = _blobs()
    for cfg in (_cfg(), _cfg(cache="lru", cache_tile=32, cache_capacity=8),
                _cfg(cache="precomputed")):
        est = KernelKMeans(cfg).fit(x, jax.random.PRNGKey(2))
        p = str(tmp_path / f"centers_{cfg.cache}.npz")
        est.save(p)
        served = KernelKMeans.load(p)
        np.testing.assert_array_equal(np.asarray(served.predict(x)),
                                      np.asarray(est.predict(x)))
        np.testing.assert_allclose(np.asarray(served.transform(x[:9])),
                                   np.asarray(est.transform(x[:9])),
                                   atol=1e-6)
        assert served.config.k == cfg.k
        if cfg.cache == "none":
            # partial_fit-capable plan: the full FitCarry round-trips
            assert served._outcome is not None
            assert served._outcome.key is not None
        else:
            # serving-only (no resumable carry saved)
            assert served._outcome is None


def test_save_load_roundtrips_partial_fit_carry(tmp_path):
    """fit(a); save; load; partial_fit(b) must be BIT-identical to
    fit(a); partial_fit(b): the carry (center state, PRNG fit key,
    nested-sampler step cursor) survives serialization exactly."""
    x, b = _blobs(seed=0), _blobs(seed=3)
    key = jax.random.PRNGKey(5)
    for kw in (dict(jit=False), dict(jit=True),
               dict(jit=False, sampler="nested")):
        cfg = _cfg(max_iters=7, **kw)
        ref = KernelKMeans(cfg).fit(x, key).partial_fit(b, iters=5)
        est = KernelKMeans(cfg).fit(x, key)
        p = str(tmp_path / "carry.npz")
        est.save(p)
        loaded = KernelKMeans.load(p)
        # serving before resume still works (and matches the saved fit)
        np.testing.assert_array_equal(np.asarray(loaded.predict(x[:31])),
                                      np.asarray(est.predict(x[:31])))
        loaded.partial_fit(b, iters=5)
        np.testing.assert_array_equal(np.asarray(ref.state_.idx),
                                      np.asarray(loaded.state_.idx),
                                      err_msg=str(kw))
        np.testing.assert_allclose(np.asarray(ref.state_.sqnorm),
                                   np.asarray(loaded.state_.sqnorm),
                                   atol=0, err_msg=str(kw))
        # a re-save after load keeps the carry (still resumable)
        p2 = str(tmp_path / "carry2.npz")
        KernelKMeans.load(p).save(p2)
        assert KernelKMeans.load(p2)._outcome is not None


def test_loaded_carry_resumes_on_saved_plan_not_auto(tmp_path):
    """Regression: partial_fit on a load()ed estimator must resume on the
    plan that PRODUCED the carry — a cache='auto' fit on large data
    (plan 'single') resumed on small data used to re-resolve to
    'single_precomputed' and raise NotImplementedError."""
    from repro.api.config import PRECOMPUTED_AUTO_MAX_ELEMS

    n_big = int(np.sqrt(PRECOMPUTED_AUTO_MAX_ELEMS)) + 8   # auto -> none
    x, _ = blobs(n=n_big, d=4, k=2, seed=0)
    x = jnp.asarray(x)
    b = _blobs(n=64, d=4, k=2, seed=3)
    cfg = SolverConfig(k=2, batch_size=16, tau=8, max_iters=3,
                       epsilon=-1.0, kernel=GAUSS, cache="auto",
                       distribution="single", jit=True)
    key = jax.random.PRNGKey(2)
    ref = KernelKMeans(cfg).fit(x, key)
    assert ref.plan_.name == "single"
    p = str(tmp_path / "auto_carry.npz")
    ref.save(p)
    loaded = KernelKMeans.load(p).partial_fit(b, iters=2)
    ref.partial_fit(b, iters=2)
    assert loaded.plan_.name == "single"
    np.testing.assert_array_equal(np.asarray(ref.state_.idx),
                                  np.asarray(loaded.state_.idx))
    np.testing.assert_allclose(np.asarray(ref.state_.sqnorm),
                               np.asarray(loaded.state_.sqnorm), atol=0)
    # ...but a subsequent FULL fit re-resolves through the registry: on
    # small data the auto cache axis picks the precomputed plan again
    # (the carry-forced executor must not leak past the resume)
    loaded.fit(b, key)
    assert loaded.plan_.name == "single_precomputed"


def test_partial_fit_matches_one_long_fit():
    x = _blobs()
    key = jax.random.PRNGKey(5)
    for jit in (False, True):
        long = KernelKMeans(_cfg(jit=jit, max_iters=12)).fit(x, key)
        two = KernelKMeans(_cfg(jit=jit, max_iters=12))
        two.partial_fit(x, key, iters=7)
        two.partial_fit(x, iters=5)
        np.testing.assert_array_equal(np.asarray(long.state_.idx),
                                      np.asarray(two.state_.idx))
        np.testing.assert_allclose(np.asarray(long.state_.sqnorm),
                                   np.asarray(two.state_.sqnorm), atol=0)
        if not jit:
            assert len(two.history_) == 12
            assert [h["step"] for h in two.history_] == list(range(12))


def test_early_stop_false_honored_on_jit_plans():
    """early_stop=False must defeat the epsilon condition even inside the
    compiled while_loop (regression: it was silently ignored on every jit
    path)."""
    x = _blobs()
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    for cfg, m in [
            (_cfg(jit=True, epsilon=1e9, early_stop=False, max_iters=5),
             None),
            (_cfg(jit=True, epsilon=1e9, early_stop=False, max_iters=5,
                  cache="precomputed"), None),
            (_cfg(jit=True, epsilon=1e9, early_stop=False, max_iters=5,
                  distribution="sharded"), mesh)]:
        est = KernelKMeans(cfg, mesh=m).fit(x, jax.random.PRNGKey(0))
        assert int(est.iters_) == 5, cfg.axes_repr()
        est2 = KernelKMeans(cfg.replace(early_stop=True),
                            mesh=m).fit(x, jax.random.PRNGKey(0))
        assert int(est2.iters_) == 1, cfg.axes_repr()


def test_nested_sampler_rejects_sample_weight():
    x = _blobs()
    est = KernelKMeans(_cfg(sampler="nested"))
    with pytest.raises(NotImplementedError, match="sample weights"):
        est.fit(x, jax.random.PRNGKey(0),
                sample_weight=jnp.ones(x.shape[0]))


def test_refit_same_shape_different_data_is_fresh():
    """Executors cache compiled programs across fits — refitting the SAME
    estimator on different data of the same shape must equal a fresh
    estimator's fit (regression: the sharded-lru run cache baked the first
    dataset's coordinates in as jit constants)."""
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    x1, x2 = _blobs(seed=0), _blobs(seed=1)
    key = jax.random.PRNGKey(4)
    for cfg, m in [
            (_cfg(jit=True), None),
            (_cfg(cache="precomputed", jit=True), None),
            (_cfg(cache="lru", cache_tile=32, cache_capacity=8), None),
            (_cfg(distribution="sharded", jit=True), mesh),
            (_cfg(distribution="sharded", cache="lru", jit=True,
                  cache_tile=32, cache_capacity=16), mesh)]:
        reused = KernelKMeans(cfg, mesh=m)
        reused.fit(x1, key)
        reused.fit(x2, key)
        fresh = KernelKMeans(cfg, mesh=m).fit(x2, key)
        np.testing.assert_array_equal(
            np.asarray(reused.state_.sqnorm),
            np.asarray(fresh.state_.sqnorm),
            err_msg=cfg.axes_repr())


def test_partial_fit_unsupported_plans_raise():
    x = _blobs()
    est = KernelKMeans(_cfg(restarts=2))
    with pytest.raises(NotImplementedError, match="partial_fit"):
        est.partial_fit(x)


# ------------------------------------------------------------ solver registry
def test_unmatched_config_names_register_solver():
    x = _blobs()
    # restarts > 1 x sharded is claimed by the fused plan for jit=True
    # only; the host-driven (jit=False) point stays unclaimed and must
    # point at the registry
    cfg = _cfg(restarts=2, distribution="sharded", jit=False)
    with pytest.raises(NotImplementedError, match="register_solver"):
        KernelKMeans(cfg).fit(x, jax.random.PRNGKey(0))


def test_fused_plan_claims_restarts_sharded_jit():
    """The acceptance point: SolverConfig(restarts=4,
    distribution='sharded') resolves to the fused plan via the registry —
    no new fit_* function anywhere."""
    from repro.api.plan import resolve_plan as rp

    cfg = SolverConfig(kernel=GAUSS, restarts=4, distribution="sharded")
    mesh = jax.make_mesh((1, 1, 1), ("restart", "data", "model"),
                         devices=jax.devices()[:1])
    plan = rp(cfg, n=256, mesh=mesh)
    assert plan.name == "fused_restart_sharded"
    assert plan.config.cache == "none"          # auto -> none when sharded
    assert plan.config.restart_axis == "restart"  # pinned by resolve()
    assert "fused_restart_sharded" in list_solvers()


def test_register_solver_claims_a_config_point():
    calls = {}

    class DummyExecutor:
        supports_partial_fit = False

        def __init__(self, config, mesh):
            calls["built"] = config

        def fit(self, x, key, **kw):
            from repro.api.executors import FitOutcome
            calls["fit"] = True
            st = KernelKMeans(_cfg()).fit(x, key).state_
            return FitOutcome(state=st, iters=0)

        def serving_tuple(self, outcome, x):
            return GAUSS, x[:1], outcome.state.coef, outcome.state.sqnorm

        def predict(self, outcome, x, xq, chunk=4096):
            return jnp.zeros(xq.shape[0], jnp.int32)

    register_solver(
        "test_fused",
        matches=lambda c: c.restarts > 1 and c.distribution == "sharded",
        build=DummyExecutor)
    try:
        assert "test_fused" in list_solvers()
        with pytest.raises(ValueError, match="already registered"):
            register_solver("test_fused", matches=lambda c: False,
                            build=DummyExecutor)
        x = _blobs()
        est = KernelKMeans(_cfg(restarts=2, distribution="sharded"))
        est.fit(x, jax.random.PRNGKey(0))
        assert est.plan_.name == "test_fused"
        assert calls["fit"]
    finally:
        unregister_solver("test_fused")
    with pytest.raises(ValueError, match="not registered"):
        unregister_solver("test_fused")
    cfg = _cfg(restarts=2, distribution="sharded")
    with pytest.raises(NotImplementedError):
        resolve_plan(cfg, n=256)


# --------------------------------------------------------------- API lock
EXPECTED_API = [
    "FitOutcome", "KernelKMeans", "Plan", "SolverConfig", "SolverSpec",
    "keys", "list_kernels", "list_solvers", "make_kernel",
    "register_kernel_factory", "register_solver", "resolve_plan",
    "unregister_solver",
]

EXPECTED_CONFIG_FIELDS = [
    "k", "batch_size", "tau", "rate", "sqnorm_mode", "eval_mode",
    "epsilon", "max_iters", "use_pallas", "compute_dtype", "kernel",
    "kernel_params", "init", "early_stop", "cache", "distribution",
    "restarts", "sampler", "jit", "step", "precision", "prefetch",
    "cache_tile", "cache_capacity", "cache_dtype", "reuse", "refresh",
    "data_axes", "model_axis", "restart_axis", "eval_batch_size",
    "share_eval_gram", "compress",
]


def test_public_api_lock():
    """Snapshot of the public surface: repro.api.__all__ and the
    SolverConfig schema.  Additions/removals/reorders are API changes —
    update this test deliberately, with docs/api.md."""
    assert sorted(repro.api.__all__) == EXPECTED_API
    assert [f.name for f in dataclasses.fields(SolverConfig)] == \
        EXPECTED_CONFIG_FIELDS
    # every exported name resolves
    for name in repro.api.__all__:
        assert getattr(repro.api, name) is not None


# ------------------------------------------------------------- deprecation
def test_legacy_shims_warn_exactly_once():
    from repro.api import deprecation
    from repro.core import fit

    x = _blobs(n=128, k=2)
    cfg_mb = KernelKMeans(_cfg(k=2, batch_size=16, tau=8,
                               max_iters=2)).config.mb_config()
    deprecation.reset_warnings()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        fit(x, GAUSS, cfg_mb, jax.random.PRNGKey(0), early_stop=False)
        fit(x, GAUSS, cfg_mb, jax.random.PRNGKey(1), early_stop=False)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "repro.core.fit is deprecated" in str(w.message)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    assert "KernelKMeans" in str(dep[0].message)
    deprecation.reset_warnings()


def test_all_shims_carry_migration_pointer():
    """Each legacy entry point warns once, naming its SolverConfig twin."""
    from repro.api import deprecation
    from repro.core import engine, minibatch
    from repro.core import distributed as dist

    x = _blobs(n=128, k=2)
    mb = KernelKMeans(_cfg(k=2, batch_size=16, tau=8,
                           max_iters=2)).config.mb_config()
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    init_idx = jnp.array([0, 50], jnp.int32)
    shim_calls = [
        ("repro.core.fit", lambda: minibatch.fit(
            x, GAUSS, mb, jax.random.PRNGKey(0), early_stop=False)),
        ("repro.core.fit_jit", lambda: minibatch.fit_jit(
            x, GAUSS, mb, jax.random.PRNGKey(0), init_idx)),
        ("repro.core.fit_cached", lambda: minibatch.fit_cached(
            x, GAUSS, mb, jax.random.PRNGKey(0), tile=32, capacity=4,
            early_stop=False)),
        ("repro.core.fit_restarts", lambda: engine.fit_restarts(
            x, GAUSS, mb, jax.random.PRNGKey(0), restarts=2)),
        ("repro.core.distributed.fit_distributed_jit",
         lambda: dist.fit_distributed_jit(
             x, x[init_idx], GAUSS, mb, mesh, jax.random.PRNGKey(0))),
    ]
    deprecation.reset_warnings()
    try:
        for name, call in shim_calls:
            with warnings.catch_warnings(record=True) as rec:
                warnings.simplefilter("always")
                call()
            dep = [w for w in rec
                   if issubclass(w.category, DeprecationWarning)
                   and name + " is deprecated" in str(w.message)]
            assert len(dep) == 1, (name, [str(w.message) for w in rec])
    finally:
        deprecation.reset_warnings()
