"""Streaming fused step subsystem (repro.kernels.fused_step + the
`step`/`precision`/`prefetch` SolverConfig axes).

Three contracts:
* the streaming Pallas kernel (interpret mode) matches the XLA streaming
  fallback to float tolerance across tile/shape sweeps (the fallback is
  itself pinned BIT-exactly to the composed step — that equivalence runs
  across the full plan grid in tests/test_api_grid.py);
* mixed precision (`precision="bf16"`) stays within a fixed relative
  objective gap of the f32 fit on the normalized kernels;
* the perf plumbing — host-loop/stream prefetch bit-identity, and the
  cross-executor compiled-program cache (donated-argnum signatures) that
  keeps repeated fits on one executable.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelKMeans, SolverConfig
from repro.core.kernel_fns import Gaussian, Laplacian, Linear, Polynomial
from repro.core.kernel_fns import diag_of
from repro.data import blobs
from repro.kernels import fused_step as fs
from repro.kernels import ops as kops

GAUSS = Gaussian(kappa=jnp.float32(1.5))
KEY = jax.random.PRNGKey(9)

KERNELS = {
    "gaussian": (Gaussian(kappa=jnp.float32(1.3)),
                 dict(kind="gaussian", p0=1.3)),
    "linear": (Linear(), dict(kind="linear")),
    "polynomial": (Polynomial(bias=jnp.float32(1.0), scale=jnp.float32(2.0),
                              degree=2),
                   dict(kind="polynomial", p0=1.0, p1=2.0, p2=2)),
}


def _rand(shape, seed, scale=1.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape) * scale, jnp.float32)


def _blobs(n=256, d=8, k=4, seed=0):
    x, _ = blobs(n=n, d=d, k=k, seed=seed)
    return jnp.asarray(x)


def _cfg(**kw):
    base = dict(k=4, batch_size=32, tau=16, max_iters=6, epsilon=-1.0,
                kernel=GAUSS)
    base.update(kw)
    return SolverConfig(**base)


# ------------------------------------------------------------ chunk plan
def test_center_chunks_cover_and_never_width_one():
    for k in range(1, 40):
        for kc in (2, 3, 8):
            chunks = fs.center_chunks(k, kc)
            # contiguous cover of [0, k)
            assert chunks[0][0] == 0
            assert sum(kk for _, kk in chunks) == k
            for (a, wa), (b, _) in zip(chunks, chunks[1:]):
                assert a + wa == b
            # bit-identity precondition: no 1-wide slab unless k == 1
            if k > 1:
                assert min(kk for _, kk in chunks) >= 2, (k, kc, chunks)


# ----------------------------------------------- streaming XLA fallback
@pytest.mark.parametrize("kname", ["gaussian", "linear", "polynomial"])
@pytest.mark.parametrize("b,k,w,d", [
    (32, 4, 48, 8), (37, 7, 21, 9), (64, 13, 40, 3),
])
def test_streaming_xla_bit_identical_to_composed(kname, b, k, w, d):
    """The fallback's running argmin/min over >=2-center slabs reproduces
    the composed full-matrix pass BIT-exactly (the property the plan-grid
    equivalence in test_api_grid.py rests on)."""
    from repro.core.kernel_fns import kernel_cross

    kern, _ = KERNELS[kname]
    xb = _rand((b, d), 0)
    sup = _rand((k, w, d), 1, 0.7).reshape(k * w, d)
    coef = _rand((k, w), 2, 0.1)
    sq = jnp.abs(_rand((k,), 3))
    diag_b = diag_of(kern, xb)

    # arrays as jit ARGUMENTS, like the real step: a jit over closed-over
    # concrete arrays constant-folds through a different evaluator and
    # the comparison would measure the folder, not the compiled program
    @jax.jit
    def composed(xb, sup, coef, sq, diag_b):
        cross = kernel_cross(kern, xb, sup)
        p = jnp.einsum("bkw,kw->bk", cross.reshape(b, k, w), coef)
        dd = diag_b[:, None] - 2.0 * p + sq[None, :]
        return jnp.min(dd, axis=1), jnp.argmin(dd, axis=1).astype(jnp.int32)

    want_min, want_idx = composed(xb, sup, coef, sq, diag_b)
    for kc in (2, 4, k):
        assign = jax.jit(lambda *a, kc=kc:
                         fs.streaming_assign_xla(kern, *a, kc=kc))
        got_min, got_idx = assign(xb, sup, coef, sq, diag_b)
        np.testing.assert_array_equal(
            np.asarray(got_min).view(np.uint32),
            np.asarray(want_min).view(np.uint32), err_msg=f"kc={kc}")
        np.testing.assert_array_equal(np.asarray(got_idx),
                                      np.asarray(want_idx))
        only_min = jax.jit(lambda *a, kc=kc:
                           fs.streaming_min_xla(kern, *a, kc=kc))(
            xb, sup, coef, sq, diag_b)
        np.testing.assert_array_equal(
            np.asarray(only_min).view(np.uint32),
            np.asarray(want_min).view(np.uint32))
        dists = jax.jit(lambda *a, kc=kc:
                        fs.streaming_dists_xla(kern, *a, kc=kc))(
            xb, sup, coef, sq, diag_b)
        assert dists.shape == (b, k)
        np.testing.assert_array_equal(
            np.asarray(jnp.min(dists, axis=1)).view(np.uint32),
            np.asarray(want_min).view(np.uint32))


def test_streamed_sqnorm_bit_identical_to_recompute():
    from repro.core.minibatch import _sqnorm_recompute

    x = _rand((512, 8), 0)
    ref = jax.jit(lambda x, idx, coef:
                  _sqnorm_recompute(GAUSS, x, idx, coef))
    for k, w in [(4, 48), (7, 21), (16, 12)]:
        idx = jnp.asarray(
            np.random.default_rng(k).integers(0, 512, (k, w)), jnp.int32)
        coef = _rand((k, w), k + 1, 0.05)
        want = ref(x, idx, coef)
        for kc in (2, 4):
            got = jax.jit(lambda x, idx, coef, kc=kc:
                          fs.streamed_sqnorm(GAUSS, x, idx, coef,
                                             kc=kc))(x, idx, coef)
            np.testing.assert_array_equal(np.asarray(got).view(np.uint32),
                                          np.asarray(want).view(np.uint32))


# ------------------------------------------------- streaming Pallas kernel
@pytest.mark.parametrize("kname", list(KERNELS))
@pytest.mark.parametrize("b,k,w,d,bt,st", [
    (32, 4, 48, 8, 8, 8),      # several window tiles per center
    (17, 3, 21, 5, 8, 24),     # unaligned everything, one window tile
    (64, 8, 40, 16, 16, 16),   # bt < b, st < w
])
def test_streaming_pallas_interpret_matches_fallback(kname, b, k, w, d,
                                                     bt, st):
    kern, kw = KERNELS[kname]
    xb = _rand((b, d), 0)
    sup = _rand((k, w, d), 1, 0.6)
    coef = _rand((k, w), 2, 0.1)
    sq = jnp.abs(_rand((k,), 3))
    diag_b = diag_of(kern, xb)
    want_min, want_idx = fs.streaming_assign_xla(
        kern, xb, sup.reshape(k * w, d), coef, sq, diag_b)
    got_min, got_idx = fs.streaming_assign_pallas(
        xb, sup, coef, sq, diag_b, bt=bt, st=st, interpret=True, **kw)
    np.testing.assert_allclose(np.asarray(got_min), np.asarray(want_min),
                               rtol=2e-5, atol=2e-5)
    # distances can tie to the last ulp across implementations; accept an
    # index mismatch only where the two best distances are this close
    idx_ok = np.asarray(got_idx) == np.asarray(want_idx)
    assert np.mean(idx_ok) > 0.99, np.mean(idx_ok)


def test_streaming_pallas_bf16_mode_close_to_f32():
    kern, kw = KERNELS["gaussian"]
    xb = _rand((24, 16), 0)
    sup = _rand((3, 20, 16), 1, 0.6)
    coef = _rand((3, 20), 2, 0.1)
    sq = jnp.abs(_rand((3,), 3))
    diag_b = diag_of(kern, xb)
    want, _ = fs.streaming_assign_xla(kern, xb, sup.reshape(60, 16), coef,
                                      sq, diag_b)
    got, _ = fs.streaming_assign_pallas(xb, sup, coef, sq, diag_b, bt=8,
                                        st=8, bf16=True, interpret=True,
                                        **kw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0.05, atol=0.05)


def test_ops_streaming_dispatch_cpu_uses_fallback():
    """On the CPU backend the dispatcher must route to the bit-identical
    XLA fallback, never interpret-mode Pallas (slow AND not bit-exact)."""
    xb = _rand((16, 4), 0)
    sup = _rand((4, 12, 4), 1)
    coef = _rand((4, 12), 2, 0.1)
    sq = jnp.abs(_rand((4,), 3))
    diag_b = diag_of(GAUSS, xb)
    got = kops.streaming_assign(GAUSS, xb, sup.reshape(48, 4), coef, sq,
                                diag_b)
    want = fs.streaming_assign_xla(GAUSS, xb, sup.reshape(48, 4), coef,
                                   sq, diag_b)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


# --------------------------------------------------- config axis plumbing
def test_step_axis_validation_and_resolution():
    with pytest.raises(ValueError):
        _cfg(step="tiled")
    with pytest.raises(ValueError):
        _cfg(precision="fp8")
    # auto resolves to a concrete impl ('composed' off-TPU) and mb_config
    # carries it
    r = _cfg().resolve(n=256)
    assert r.step in ("composed", "fused")
    assert r.mb_config().step == r.step
    assert _cfg(step="fused").mb_config().step == "fused"
    # precision lowers to the kernel-eval compute dtype
    assert _cfg(precision="bf16").mb_config().compute_dtype == "bfloat16"
    assert _cfg().mb_config().compute_dtype == "float32"
    # non-default algorithm modes keep auto on the composed chain
    assert _cfg(sqnorm_mode="incremental").resolve(n=256).step == "composed"


def test_fused_step_rejects_non_recompute_modes():
    from repro.core.minibatch import make_step

    mb = _cfg(step="fused", sqnorm_mode="incremental").mb_config()
    with pytest.raises(ValueError, match="fused"):
        make_step(GAUSS, mb)


# -------------------------------------------------- bf16 quality bounds
@pytest.mark.parametrize("step", ["fused", "composed"])
@pytest.mark.parametrize("kernel", [Gaussian(kappa=jnp.float32(2.0)),
                                    Laplacian(kappa=jnp.float32(2.0))])
def test_bf16_objective_within_relative_gap(kernel, step):
    """Schwartzman'23 regime: bf16 kernel evals with f32 accumulation
    leave the fitted objective within a small relative gap of f32 — on
    the fused step AND the composed chain (the axis must not be inert
    anywhere)."""
    x = _blobs(n=512, d=8, k=4, seed=1)
    kw = dict(kernel=kernel, cache="none", distribution="single",
              jit=False, step=step, max_iters=12)
    f32 = KernelKMeans(_cfg(**kw)).fit(x, KEY)
    b16 = KernelKMeans(_cfg(precision="bf16", **kw)).fit(x, KEY)
    o32, o16 = -f32.score(x), -b16.score(x)
    assert o32 > 0
    assert abs(o16 - o32) / o32 < 0.05, (o32, o16)
    # bf16 actually changed the kernel evals (the axis is live): the
    # trajectories must not be bitwise identical to f32
    assert not np.array_equal(np.asarray(f32.state_.sqnorm),
                              np.asarray(b16.state_.sqnorm))


def test_bf16_never_touches_index_data():
    """Regression: index-data kernels carry row ids as data — the bf16
    cast must be skipped for them on EVERY plan (ids >256 round under
    bf16 and gather the wrong Gram rows).  precision='bf16' on the
    precomputed plan is therefore exactly the f32 fit, bit for bit,
    under both step impls; likewise on a sharded Precomputed fit."""
    x = _blobs(n=512, d=8, k=4, seed=2)
    for step in ("fused", "composed"):
        kw = dict(cache="precomputed", distribution="single", jit=True,
                  step=step)
        f32 = KernelKMeans(_cfg(**kw)).fit(x, KEY)
        b16 = KernelKMeans(_cfg(precision="bf16", **kw)).fit(x, KEY)
        for f in ("idx", "coef", "sqnorm", "counts"):
            np.testing.assert_array_equal(
                np.asarray(getattr(f32.state_, f)),
                np.asarray(getattr(b16.state_, f)),
                err_msg=f"{step}:{f}")
    # sharded plan driven with an explicit Precomputed kernel
    from repro.core.kernel_fns import kernel_cross, Precomputed

    pk = Precomputed(gram=kernel_cross(GAUSS, x, x))
    xi = jnp.arange(x.shape[0], dtype=jnp.float32)[:, None]
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    for step in ("fused", "composed"):
        kw = dict(kernel=pk, cache="none", distribution="sharded",
                  jit=True, step=step)
        f32 = KernelKMeans(_cfg(**kw), mesh=mesh).fit(xi, KEY)
        b16 = KernelKMeans(_cfg(precision="bf16", **kw),
                           mesh=mesh).fit(xi, KEY)
        for f in ("pts", "coef", "sqnorm", "counts"):
            np.testing.assert_array_equal(
                np.asarray(getattr(f32.state_, f)),
                np.asarray(getattr(b16.state_, f)),
                err_msg=f"sharded:{step}:{f}")


try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                    # pragma: no cover
    HAVE_HYP = False


if HAVE_HYP:
    @settings(max_examples=5, deadline=None)
    @given(kappa=st.floats(0.5, 4.0), seed=st.integers(0, 2 ** 16))
    def test_bf16_objective_gap_property(kappa, seed):
        x = _blobs(n=256, d=8, k=4, seed=seed % 7)
        kern = Gaussian(kappa=jnp.float32(kappa))
        kw = dict(kernel=kern, cache="none", distribution="single",
                  jit=False, step="fused", max_iters=6)
        f32 = KernelKMeans(_cfg(**kw)).fit(x, jax.random.PRNGKey(seed))
        b16 = KernelKMeans(_cfg(precision="bf16", **kw)).fit(
            x, jax.random.PRNGKey(seed))
        o32, o16 = -f32.score(x), -b16.score(x)
        assert abs(o16 - o32) / max(o32, 1e-6) < 0.08


# ------------------------------------------------------ prefetch pipeline
@pytest.mark.parametrize("sampler", ["iid", "nested"])
def test_host_prefetch_bit_identical(sampler):
    """One-deep host-loop prefetch: same states, same history, same
    CARRIED KEY (partial_fit resumption must not see the prefetched
    draw) — with and without early stopping."""
    x = _blobs()
    for eps in (-1.0, 5e-3):          # never-stop and early-stop paths
        kw = dict(cache="none", distribution="single", jit=False,
                  sampler=sampler, epsilon=eps, max_iters=10)
        off = KernelKMeans(_cfg(prefetch=False, **kw)).fit(x, KEY)
        on = KernelKMeans(_cfg(prefetch=True, **kw)).fit(x, KEY)
        for f in ("idx", "coef", "sqnorm", "counts"):
            np.testing.assert_array_equal(
                np.asarray(getattr(off.state_, f)),
                np.asarray(getattr(on.state_, f)), err_msg=f)
        assert off.history_ == on.history_
        np.testing.assert_array_equal(np.asarray(off._outcome.key),
                                      np.asarray(on._outcome.key))


def test_sharded_host_prefetch_bit_identical():
    """The ROADMAP async-prefetch item: double-buffered device_put on the
    sharded jit=False plan is bit-identical to the blocking path."""
    x = _blobs()
    mesh = jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])
    kw = dict(cache="none", distribution="sharded", jit=False,
              max_iters=8)
    off = KernelKMeans(_cfg(prefetch=False, **kw), mesh=mesh).fit(x, KEY)
    on = KernelKMeans(_cfg(prefetch=True, **kw), mesh=mesh).fit(x, KEY)
    for f in ("pts", "coef", "sqnorm", "counts", "head"):
        np.testing.assert_array_equal(np.asarray(getattr(off.state_, f)),
                                      np.asarray(getattr(on.state_, f)),
                                      err_msg=f)
    assert off.history_ == on.history_


# ------------------------------------- program cache / donation signatures
def test_repeated_fit_reuses_one_executable():
    """Donation audit regression: a FRESH estimator of the same config on
    same-shape data must re-bind nothing — the donated-argnum-keyed
    program registry hands back the already-compiled executable, and the
    jit cache underneath holds exactly one entry."""
    from repro.api import executors as ex

    x = _blobs()
    cfg = _cfg(cache="none", distribution="single", jit=True)
    e1 = KernelKMeans(cfg)
    e1.fit(x, KEY)
    run = e1.plan_.executor._jit_run("init", cfg.max_iters)
    builds = ex.program_builds()
    e2 = KernelKMeans(cfg)
    e2.fit(x, jax.random.PRNGKey(3))           # different key, same shapes
    assert ex.program_builds() == builds, "fresh estimator re-bound"
    assert e2.plan_.executor._jit_run("init", cfg.max_iters) is run
    assert run._cache_size() == 1
    for f in ("coef", "sqnorm"):
        assert np.isfinite(np.asarray(getattr(e2.state_, f))).all()


def test_partial_fit_resume_donates_and_reuses():
    """The resume program donates the FitCarry buffers and is reused
    across partial_fit calls (one executable, one jit entry)."""
    x = _blobs()
    cfg = _cfg(cache="none", distribution="single", jit=True, max_iters=4)
    est = KernelKMeans(cfg)
    est.fit(x, KEY)
    est.partial_fit(x, iters=3)
    run = est.plan_.executor._jit_run("resume", 3)
    assert run._cache_size() == 1
    est.partial_fit(x, iters=3)
    assert run._cache_size() == 1
    # equivalence with one long fit still holds under donation
    ref = KernelKMeans(cfg.replace(max_iters=10)).fit(x, KEY)
    two = KernelKMeans(cfg).fit(x, KEY).partial_fit(x, iters=3) \
                                       .partial_fit(x, iters=3)
    np.testing.assert_array_equal(np.asarray(ref.state_.coef),
                                  np.asarray(two.state_.coef))


# ----------------------------------------------------- 8-dev equivalence
FUSED_8DEV = """
    import warnings; warnings.simplefilter("ignore", DeprecationWarning)
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import KernelKMeans, SolverConfig
    from repro.core import Gaussian
    from repro.data import blobs

    assert len(jax.devices()) == 8, jax.devices()
    kern = Gaussian(kappa=jnp.float32(2.0))
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    key = jax.random.PRNGKey(7)
    base = dict(k=8, batch_size=128, tau=64, max_iters=6, epsilon=-1.0,
                kernel=kern, cache="none", distribution="sharded",
                jit=True)

    # sharded plan on a 4x2 data x model mesh
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    ec = KernelKMeans(SolverConfig(step="composed", **base),
                      mesh=mesh).fit(x, key)
    ef = KernelKMeans(SolverConfig(step="fused", **base),
                      mesh=mesh).fit(x, key)
    for f in ("pts", "coef", "sqnorm", "counts", "head"):
        np.testing.assert_array_equal(np.asarray(getattr(ec.state_, f)),
                                      np.asarray(getattr(ef.state_, f)),
                                      err_msg=f)
    assert int(ec.iters_) == int(ef.iters_)

    # fused restart x data x model plan on a 2x2x2 mesh
    fmesh = jax.make_mesh((2, 2, 2), ("restart", "data", "model"))
    rc = KernelKMeans(SolverConfig(restarts=4, step="composed", **base),
                      mesh=fmesh).fit(x, key)
    rf = KernelKMeans(SolverConfig(restarts=4, step="fused", **base),
                      mesh=fmesh).fit(x, key)
    assert rf.plan_.name == "fused_restart_sharded"
    np.testing.assert_array_equal(np.asarray(rc.result_.objectives),
                                  np.asarray(rf.result_.objectives))
    np.testing.assert_array_equal(np.asarray(rc.result_.iters),
                                  np.asarray(rf.result_.iters))
    for f in ("pts", "coef", "sqnorm", "counts", "head"):
        np.testing.assert_array_equal(np.asarray(getattr(rc.state_, f)),
                                      np.asarray(getattr(rf.state_, f)),
                                      err_msg=f)

    # prefetch on the multi-shard host-driven plan
    off = KernelKMeans(SolverConfig(jit=False, prefetch=False, **{
        k: v for k, v in base.items() if k != "jit"}),
        mesh=mesh).fit(x, key)
    on = KernelKMeans(SolverConfig(jit=False, prefetch=True, **{
        k: v for k, v in base.items() if k != "jit"}),
        mesh=mesh).fit(x, key)
    for f in ("pts", "coef", "sqnorm", "counts"):
        np.testing.assert_array_equal(np.asarray(getattr(off.state_, f)),
                                      np.asarray(getattr(on.state_, f)),
                                      err_msg=f)
    assert off.history_ == on.history_
    print("FUSED_STEP_8DEV_OK")
"""


@pytest.mark.slow
def test_fused_step_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c",
                        textwrap.dedent(FUSED_8DEV)],
                       env=env, capture_output=True, text=True,
                       timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "FUSED_STEP_8DEV_OK" in r.stdout, r.stdout[-2000:]
