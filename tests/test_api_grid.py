"""Config-grid equivalence: every SolverConfig point vs its legacy twin.

The acceptance bar is BIT-EXACT state equality for the same derived keys —
the plan executor must run the same compiled computation the legacy entry
point ran.  (The lru plan's window *indices* are bit-exact against the
uncached plan too; its Gram numerics go through tile blocks, same as the
pre-existing fit_cached tolerance.)

The multi-shard pad-and-mask equivalences need >1 data shard, so they run
in an 8-virtual-device subprocess (slow lane), like test_distributed.py.
"""
import os
import subprocess
import sys
import textwrap
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelKMeans, SolverConfig
from repro.api import keys as api_keys
from repro.core.init import draw_init
from repro.core.kernel_fns import Gaussian
from repro.data import blobs

GAUSS = Gaussian(kappa=jnp.float32(1.5))
KEY = jax.random.PRNGKey(9)


def _blobs(n=256, d=8, k=4, seed=0):
    x, _ = blobs(n=n, d=d, k=k, seed=seed)
    return jnp.asarray(x)


def _cfg(**kw):
    base = dict(k=4, batch_size=32, tau=16, max_iters=6, epsilon=-1.0,
                kernel=GAUSS)
    base.update(kw)
    return SolverConfig(**base)


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def _derived():
    """(init_key, fit_key, init_idx) the estimator derives from KEY."""
    x = _blobs()
    ik, fk = api_keys.split_init(KEY)
    return x, fk, draw_init(ik, x, 4, GAUSS, "kmeans++")


def _assert_state_equal(a, b):
    for name in ("coef", "sqnorm", "counts"):
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=name)


@pytest.fixture(autouse=True)
def _quiet_legacy():
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        yield


# ------------------------------------------------------------ single family
def test_point_single_host_vs_fit():
    from repro.core import fit

    x = _blobs()
    est = KernelKMeans(_cfg(cache="none", distribution="single",
                            jit=False)).fit(x, KEY)
    st, h = fit(x, GAUSS, est.config.mb_config(), KEY, early_stop=False)
    _assert_state_equal(est.state_, st)
    np.testing.assert_array_equal(np.asarray(est.state_.idx),
                                  np.asarray(st.idx))
    assert len(est.history_) == len(h)
    for a, b in zip(est.history_, h):
        assert a == b


def test_point_single_jit_vs_fit_jit():
    from repro.core import fit_jit

    x, fk, idx0 = _derived()
    est = KernelKMeans(_cfg(cache="none", distribution="single",
                            jit=True)).fit(x, KEY)
    st, iters = fit_jit(x, GAUSS, est.config.mb_config(), fk, idx0)
    _assert_state_equal(est.state_, st)
    assert int(est.iters_) == int(iters)


@pytest.mark.parametrize("sampler,legacy_sampler",
                         [("iid", "uniform"), ("nested", "nested")])
def test_point_single_lru_vs_fit_cached(sampler, legacy_sampler):
    from repro.core.minibatch import fit_cached

    x = _blobs()
    est = KernelKMeans(_cfg(cache="lru", distribution="single", jit=False,
                            sampler=sampler, cache_tile=32,
                            cache_capacity=8)).fit(x, KEY)
    st, h, ck = fit_cached(x, GAUSS, est.config.mb_config(), KEY, tile=32,
                           capacity=8, sampler=legacy_sampler,
                           early_stop=False)
    _assert_state_equal(est.state_, st)
    np.testing.assert_array_equal(np.asarray(est.state_.idx),
                                  np.asarray(st.idx))
    # cache telemetry carried identically
    from repro.cache import stats
    assert stats(est.cache_.cache) == stats(ck.cache)


def test_point_single_precomputed_vs_fit_on_gram():
    from repro import cache as cache_lib
    from repro.core import fit

    x = _blobs()
    est = KernelKMeans(_cfg(cache="precomputed", distribution="single",
                            jit=False)).fit(x, KEY)
    pk, xi = cache_lib.as_kernel(cache_lib.precompute_gram(GAUSS, x))
    st, h = fit(xi, pk, est.config.mb_config(), KEY, early_stop=False)
    _assert_state_equal(est.state_, st)


def test_point_single_weighted_vs_fit_weights():
    from repro.core import fit

    x = _blobs()
    w = jnp.abs(jnp.sin(jnp.arange(x.shape[0], dtype=jnp.float32))) + 0.1
    est = KernelKMeans(_cfg(cache="none", distribution="single",
                            jit=False)).fit(x, KEY, sample_weight=w)
    st, _ = fit(x, GAUSS, est.config.mb_config(), KEY, weights=w,
                early_stop=False)
    _assert_state_equal(est.state_, st)


# ----------------------------------------------------------- sharded family
def test_point_sharded_jit_vs_fit_distributed_jit():
    from repro.core.distributed import fit_distributed_jit

    x, fk, idx0 = _derived()
    mesh = _mesh1()
    est = KernelKMeans(_cfg(cache="none", distribution="sharded",
                            jit=True), mesh=mesh).fit(x, KEY)
    st, iters = fit_distributed_jit(x, x[idx0], GAUSS,
                                    est.config.mb_config(), mesh, fk)
    for name in ("pts", "coef", "sqnorm", "counts"):
        np.testing.assert_array_equal(np.asarray(getattr(est.state_, name)),
                                      np.asarray(getattr(st, name)),
                                      err_msg=name)
    assert int(est.iters_) == int(iters)


def test_point_sharded_host_vs_fit_distributed_stream():
    from repro.core.distributed import fit_distributed
    from repro.data.pipeline import ClusterBatchPipeline

    x, fk, idx0 = _derived()
    mesh = _mesh1()
    est = KernelKMeans(_cfg(cache="none", distribution="sharded",
                            jit=False), mesh=mesh).fit(x, KEY)
    pipe = ClusterBatchPipeline(np.asarray(x), batch=32, mode="keyed",
                                key=fk)
    st, h = fit_distributed(iter(pipe), x[idx0], GAUSS,
                            est.config.mb_config(), mesh,
                            early_stop=False)
    for name in ("pts", "coef", "sqnorm", "counts"):
        np.testing.assert_array_equal(np.asarray(getattr(est.state_, name)),
                                      np.asarray(getattr(st, name)),
                                      err_msg=name)
    assert len(est.history_) == len(h)


def test_point_sharded_lru_jit_vs_fit_distributed_cached_jit():
    from repro.core.distributed import fit_distributed_cached_jit

    x, fk, idx0 = _derived()
    mesh = _mesh1()
    est = KernelKMeans(_cfg(cache="lru", distribution="sharded", jit=True,
                            cache_tile=32, cache_capacity=16),
                       mesh=mesh).fit(x, KEY)
    st, caches, iters = fit_distributed_cached_jit(
        x, idx0, GAUSS, est.config.mb_config(), mesh, fk, tile=32,
        capacity=16)
    for name in ("pts", "coef", "sqnorm", "counts"):
        np.testing.assert_array_equal(np.asarray(getattr(est.state_, name)),
                                      np.asarray(getattr(st, name)),
                                      err_msg=name)
    assert int(est.iters_) == int(iters)


# ------------------------------------------------------------ restart family
def test_point_restarts_vs_fit_restarts():
    from repro.core.engine import fit_restarts

    x = _blobs()
    est = KernelKMeans(_cfg(cache="none", distribution="single",
                            restarts=3)).fit(x, KEY)
    res = fit_restarts(x, GAUSS, est.config.mb_config(), KEY, restarts=3)
    np.testing.assert_array_equal(np.asarray(est.result_.objectives),
                                  np.asarray(res.objectives))
    assert int(est.result_.best) == int(res.best)
    _assert_state_equal(est.state_, res.state)


def test_point_restarts_on_restart_mesh():
    from repro.core.engine import fit_restarts
    from repro.launch.mesh import make_restart_mesh

    x = _blobs()
    mesh = make_restart_mesh(2)
    est = KernelKMeans(_cfg(cache="none", distribution="single",
                            restarts=2), mesh=mesh).fit(x, KEY)
    res = fit_restarts(x, GAUSS, est.config.mb_config(), KEY, restarts=2,
                       mesh=mesh)
    np.testing.assert_array_equal(np.asarray(est.result_.objectives),
                                  np.asarray(res.objectives))


# -------------------------------------- fused restart x data x model family
def _fused_mesh1():
    return jax.make_mesh((1, 1, 1), ("restart", "data", "model"),
                         devices=jax.devices()[:1])


def _sequential_sharded_fits(x, mb, key, restarts, mesh2):
    """R sequential sharded fits with the fused plan's exact per-restart
    key derivation — the fused program's ground truth."""
    from repro.core.distributed import (
        init_dist_state, make_dist_sampling_step, state_shardings)
    from repro.core.engine import make_init_run
    from repro.core.minibatch import run_early_stopped_keyed
    from repro.core.state import window_size

    k_init, k_fit, k_eval = api_keys.restart_keys(key)
    init_idx = make_init_run(GAUSS, mb, "kmeans++")(
        api_keys.per_restart(k_init, restarts), x)
    fit_keys = api_keys.per_restart(k_fit, restarts)
    w = window_size(mb.batch_size, mb.tau)
    step = make_dist_sampling_step(GAUSS, mb, mesh2, n_valid=None)

    @jax.jit
    def run_one(state, xs, kk):
        def swk(st, kb):
            st, info = step(st, xs, kb)
            return st, info.improvement

        return run_early_stopped_keyed(mb, swk, state, kk)

    finals, iters = [], []
    for r in range(restarts):
        st0 = jax.device_put(init_dist_state(x[init_idx[r]], GAUSS, w),
                             state_shardings(mesh2))
        stf, it, _ = run_one(st0, x, fit_keys[r])
        finals.append(jax.device_get(stf))
        iters.append(int(it))
    return finals, iters, k_eval


def test_point_fused_restart_sharded_vs_sequential_sharded():
    """The tentpole grid point: restarts>1 x sharded resolves to the
    fused plan through the REGISTRY (no fit_* twin exists) and returns
    the best-restart state BIT-EXACTLY equal to R sequential sharded fits
    with the same per-restart keys."""
    from repro.core.kernel_fns import kernel_cross, kernel_diag
    from repro.core.minibatch import sample_batch

    R = 3
    x = _blobs()
    est = KernelKMeans(_cfg(cache="none", distribution="sharded",
                            jit=True, restarts=R),
                       mesh=_fused_mesh1()).fit(x, KEY)
    assert est.plan_.name == "fused_restart_sharded"
    res = est.result_
    assert res.objectives.shape == (R,)
    assert int(res.best) == int(np.argmin(np.asarray(res.objectives)))

    finals, iters, k_eval = _sequential_sharded_fits(
        x, est.config.mb_config(), KEY, R, _mesh1())
    assert [int(i) for i in np.asarray(res.iters)] == iters
    win = finals[int(res.best)]
    for name in ("pts", "coef", "head", "sqnorm", "counts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(est.state_, name)),
            np.asarray(getattr(win, name)), err_msg=name)

    # the sharded shared-eval-batch objectives agree with a dense
    # single-device recomputation on the same eval rows
    eb = est.plan_.executor._eval_size(x.shape[0])
    xe = x[sample_batch(k_eval, x.shape[0], eb)]
    diag_e = np.asarray(kernel_diag(GAUSS, xe))
    for r in range(R):
        st = finals[r]
        k, w, d = st.pts.shape
        cross = np.asarray(kernel_cross(GAUSS, xe,
                                        st.pts.reshape(k * w, d)))
        p = np.einsum("bkw,kw->bk", cross.reshape(-1, k, w),
                      np.asarray(st.coef))
        dist = diag_e[:, None] - 2.0 * p + np.asarray(st.sqnorm)[None, :]
        np.testing.assert_allclose(float(np.mean(dist.min(axis=1))),
                                   float(res.objectives[r]), rtol=1e-5)


def test_point_fused_restart_sharded_lru_matches_uncached():
    """cache='lru' on the fused plan (per-(restart, data-shard) tile
    caches in the while_loop carry) keeps the uncached trajectories to
    the PR-2 equivalence bar: same iteration counts, same batch counts,
    sqnorm within tile-Gram float rounding, same winner."""
    R = 2
    x = _blobs()
    base = dict(distribution="sharded", jit=True, restarts=R)
    eu = KernelKMeans(_cfg(cache="none", **base),
                      mesh=_fused_mesh1()).fit(x, KEY)
    ec = KernelKMeans(_cfg(cache="lru", cache_tile=32, cache_capacity=16,
                           **base), mesh=_fused_mesh1()).fit(x, KEY)
    assert ec.plan_.name == "fused_restart_sharded"
    np.testing.assert_array_equal(np.asarray(eu.result_.iters),
                                  np.asarray(ec.result_.iters))
    assert int(eu.result_.best) == int(ec.result_.best)
    np.testing.assert_array_equal(np.asarray(eu.state_.counts),
                                  np.asarray(ec.state_.counts))
    np.testing.assert_allclose(np.asarray(eu.state_.sqnorm),
                               np.asarray(ec.state_.sqnorm), atol=1e-5)
    np.testing.assert_allclose(np.asarray(eu.result_.objectives),
                               np.asarray(ec.result_.objectives),
                               atol=1e-5)
    # per-(restart, shard) caches saw real traffic, and serving works
    from repro.cache import stats
    for r in range(R):
        s = stats(jax.tree.map(lambda a: a[r, 0], ec._outcome.caches))
        assert s["hits"] > 0, (r, s)
    lab = ec.predict(x[:64])
    assert lab.shape == (64,) and int(jnp.max(lab)) < 4


# ------------------------------------------------------- step axis (fused)
_CS_FIELDS = ("idx", "coef", "sqnorm", "counts", "head")
_DS_FIELDS = ("pts", "coef", "sqnorm", "counts", "head")

_STEP_POINTS = {
    "single_host": (dict(cache="none", distribution="single", jit=False),
                    None, _CS_FIELDS),
    "single_jit": (dict(cache="none", distribution="single", jit=True),
                   None, _CS_FIELDS),
    "precomputed": (dict(cache="precomputed", distribution="single",
                         jit=True), None, _CS_FIELDS),
    "single_lru": (dict(cache="lru", distribution="single", jit=False,
                        cache_tile=32, cache_capacity=8), None,
                   _CS_FIELDS),
    "nested_lru": (dict(cache="lru", sampler="nested",
                        distribution="single", jit=False, cache_tile=32,
                        cache_capacity=8), None, _CS_FIELDS),
    "sharded_jit": (dict(cache="none", distribution="sharded", jit=True),
                    "mesh", _DS_FIELDS),
    "sharded_host": (dict(cache="none", distribution="sharded",
                          jit=False), "mesh", _DS_FIELDS),
    "sharded_lru": (dict(cache="lru", distribution="sharded", jit=True,
                         cache_tile=32, cache_capacity=16), "mesh",
                    _DS_FIELDS),
    "multi_restart": (dict(cache="none", distribution="single",
                           restarts=3), None, _CS_FIELDS),
    "fused_restart": (dict(cache="none", distribution="sharded", jit=True,
                           restarts=3), "fused_mesh", _DS_FIELDS),
    "fused_restart_lru": (dict(cache="lru", distribution="sharded",
                               jit=True, restarts=2, cache_tile=32,
                               cache_capacity=16), "fused_mesh",
                          _DS_FIELDS),
}


@pytest.mark.parametrize("point", sorted(_STEP_POINTS))
def test_step_fused_bit_identical_to_composed(point):
    """The PR-5 tentpole bar: `step="fused"` (streaming fused passes —
    online argmin, slab-chunked sqnorm, no materialized strip) at f32 is
    BIT-IDENTICAL to `step="composed"` on every plan family — states,
    histories and restart diagnostics alike."""
    kw, mesh_kind, fields = _STEP_POINTS[point]
    mesh = None
    if mesh_kind == "mesh":
        mesh = _mesh1()
    elif mesh_kind == "fused_mesh":
        mesh = _fused_mesh1()
    x = _blobs()
    ec = KernelKMeans(_cfg(step="composed", **kw), mesh=mesh).fit(x, KEY)
    ef = KernelKMeans(_cfg(step="fused", **kw), mesh=mesh).fit(x, KEY)
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(ec.state_, f)),
                                      np.asarray(getattr(ef.state_, f)),
                                      err_msg=f"{point}:{f}")
    if ec.history_ is not None:
        assert ec.history_ == ef.history_
    if ec.result_ is not None:
        np.testing.assert_array_equal(np.asarray(ec.result_.objectives),
                                      np.asarray(ef.result_.objectives))
        np.testing.assert_array_equal(np.asarray(ec.result_.iters),
                                      np.asarray(ef.result_.iters))


def test_step_fused_weighted_bit_identical():
    """Sample weights ride the host loop; the fused step must reproduce
    the weighted trajectories too."""
    x = _blobs()
    w = jnp.abs(jnp.sin(jnp.arange(x.shape[0], dtype=jnp.float32))) + 0.1
    ec = KernelKMeans(_cfg(cache="none", distribution="single", jit=False,
                           step="composed")).fit(x, KEY, sample_weight=w)
    ef = KernelKMeans(_cfg(cache="none", distribution="single", jit=False,
                           step="fused")).fit(x, KEY, sample_weight=w)
    _assert_state_equal(ec.state_, ef.state_)


# ------------------------------------------------------ compress axis grid
# The compress="off" side of the bar needs no new fits: every grid point
# above runs the DEFAULT config (compress="off") and is asserted BIT-EXACT
# against its pre-compression legacy twin, so "off stays pre-PR" is already
# pinned at every existing point.  These tests add (1) explicit
# off-vs-default identity (the axis default resolves to the identity
# convention, mb.compress=None -> same compiled program) and (2) the
# compress-ON points across the plan families.

_COMPRESS = {"every": 3, "m": 12}

_COMPRESS_POINTS = {
    "single_host": (dict(cache="none", distribution="single", jit=False),
                    None),
    "single_jit": (dict(cache="none", distribution="single", jit=True),
                   None),
    "precomputed": (dict(cache="precomputed", distribution="single",
                         jit=True), None),
    "single_lru": (dict(cache="lru", distribution="single", jit=False,
                        cache_tile=32, cache_capacity=8), None),
    "sharded_jit": (dict(cache="none", distribution="sharded", jit=True),
                    "mesh"),
    "sharded_host": (dict(cache="none", distribution="sharded",
                          jit=False), "mesh"),
    "sharded_lru": (dict(cache="lru", distribution="sharded", jit=True,
                         cache_tile=32, cache_capacity=16), "mesh"),
    "multi_restart": (dict(cache="none", distribution="single",
                           restarts=2), None),
    "fused_restart": (dict(cache="none", distribution="sharded", jit=True,
                           restarts=2), "fused_mesh"),
}


def _mesh_of(kind):
    if kind == "mesh":
        return _mesh1()
    if kind == "fused_mesh":
        return _fused_mesh1()
    return None


@pytest.mark.parametrize("point", ["single_host", "single_jit",
                                   "single_lru", "sharded_jit",
                                   "fused_restart"])
def test_compress_off_bit_identical_to_default(point):
    """compress='off' (explicit) vs the default config: same canonical
    axis value, mb.compress=None, and bit-equal fitted states — the axis
    is invisible until switched on."""
    kw, mesh_kind = _COMPRESS_POINTS[point]
    x = _blobs()
    ed = KernelKMeans(_cfg(**kw), mesh=_mesh_of(mesh_kind)).fit(x, KEY)
    eo = KernelKMeans(_cfg(compress="off", **kw),
                      mesh=_mesh_of(mesh_kind)).fit(x, KEY)
    assert ed.config.compress == eo.config.compress == "off"
    assert ed.config.mb_config().compress is None
    fields = ("pts" if hasattr(ed.state_, "pts") else "idx", "coef",
              "sqnorm", "counts", "head")
    for f in fields:
        np.testing.assert_array_equal(np.asarray(getattr(ed.state_, f)),
                                      np.asarray(getattr(eo.state_, f)),
                                      err_msg=f"{point}:{f}")


@pytest.mark.parametrize("point", sorted(_COMPRESS_POINTS))
def test_compress_point_in_loop(point):
    """compress={'every': 3, 'm': 12} through the plan registry on every
    family: the in-loop projection leaves only m live slots on cadence
    (max_iters=6 lands ON cadence), serving works, and the objective
    stays near the uncompressed run's (the drift bound at these shapes)."""
    kw, mesh_kind = _COMPRESS_POINTS[point]
    x = _blobs()
    off = KernelKMeans(_cfg(**kw), mesh=_mesh_of(mesh_kind)).fit(x, KEY)
    on = KernelKMeans(_cfg(compress=_COMPRESS, **kw),
                      mesh=_mesh_of(mesh_kind)).fit(x, KEY)
    assert on.plan_.name == off.plan_.name
    m = _COMPRESS["m"]
    coef = np.asarray(on.state_.coef)
    assert np.all(coef[..., m:] == 0), f"{point}: live slots past m"
    assert np.count_nonzero(coef) > 0
    lab = np.asarray(on.predict(x[:64]))
    assert lab.shape == (64,) and lab.max() < 4
    assert abs(on.score(x[:64]) - off.score(x[:64])) < 0.2
    if on.result_ is not None:
        assert np.isfinite(np.asarray(on.result_.objectives)).all()


def test_compress_jit_matches_host():
    """The in-loop hook keeps the host-loop and while_loop executors on
    the SAME trajectory: per-center selection is keyed by (step, center),
    not by executor."""
    x = _blobs()
    eh = KernelKMeans(_cfg(cache="none", distribution="single", jit=False,
                           compress=_COMPRESS)).fit(x, KEY)
    ej = KernelKMeans(_cfg(cache="none", distribution="single", jit=True,
                           compress=_COMPRESS)).fit(x, KEY)
    _assert_state_equal(eh.state_, ej.state_)
    np.testing.assert_array_equal(np.asarray(eh.state_.idx),
                                  np.asarray(ej.state_.idx))


# ----------------------------------- fit -> save -> load -> partial_fit
# The resumable family ("single", restarts=1) swept across every axis it
# composes with: jit x sampler x step x precision x prefetch x compress.
# Contract (PR-9): fit(a); save; load; partial_fit(b) is BIT-identical to
# fit(a); partial_fit(b) on every lowering — the loop core's FitCarry
# (center state, carried PRNG fit key, step cursor) survives
# serialization exactly, regardless of which driver produced it.

_RESUME_GRID = {
    "host": dict(jit=False),
    "host_noprefetch": dict(jit=False, prefetch=False),
    "host_nested": dict(jit=False, sampler="nested"),
    "host_fused": dict(jit=False, step="fused"),
    "host_bf16": dict(jit=False, precision="bf16"),
    "host_compress": dict(jit=False, compress=_COMPRESS),
    "device": dict(jit=True),
    "device_nested": dict(jit=True, sampler="nested"),
    "device_fused": dict(jit=True, step="fused"),
    "device_bf16": dict(jit=True, precision="bf16"),
    "device_compress": dict(jit=True, compress=_COMPRESS),
}

_CARRY_FIELDS = ("idx", "coef", "sqnorm", "counts", "head")


@pytest.mark.parametrize("point", sorted(_RESUME_GRID))
def test_fit_save_load_partial_fit_bit_identical(point, tmp_path):
    from repro.core.loop import FitCarry, carry_of

    kw = _RESUME_GRID[point]
    x, b = _blobs(seed=0), _blobs(seed=3)
    cfg = _cfg(cache="none", distribution="single", **kw)
    ref = KernelKMeans(cfg).fit(x, KEY)
    est = KernelKMeans(cfg).fit(x, KEY)
    p = str(tmp_path / f"{point}.npz")
    est.save(p)
    loaded = KernelKMeans.load(p)
    # the shared carry round-trips exactly: state, fit key and cursor
    ca, cb = carry_of(est._outcome), carry_of(loaded._outcome)
    assert isinstance(cb, FitCarry)
    assert (ca.steps, ca.iters) == (cb.steps, cb.iters)
    np.testing.assert_array_equal(np.asarray(ca.key), np.asarray(cb.key))
    ref.partial_fit(b, iters=4)
    loaded.partial_fit(b, iters=4)
    assert loaded.plan_.name == "single"
    for f in _CARRY_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ref.state_, f)),
                                      np.asarray(getattr(loaded.state_, f)),
                                      err_msg=f"{point}:{f}")
    assert int(ref.iters_) == int(loaded.iters_)


# Non-resumable families: the serving tuple round-trips bit-exactly and
# the loaded estimator refuses partial_fit the same way the fitted plan
# would (no carry is silently fabricated).

_SERVE_GRID = {
    "precomputed": (dict(cache="precomputed", distribution="single",
                         jit=True), None),
    "single_lru": (dict(cache="lru", distribution="single", jit=False,
                        cache_tile=32, cache_capacity=8), None),
    "sharded_jit": (dict(cache="none", distribution="sharded", jit=True),
                    "mesh"),
    "sharded_host": (dict(cache="none", distribution="sharded",
                          jit=False), "mesh"),
    "sharded_lru": (dict(cache="lru", distribution="sharded", jit=True,
                         cache_tile=32, cache_capacity=16), "mesh"),
    "multi_restart": (dict(cache="none", distribution="single",
                           restarts=2), None),
    "fused_restart": (dict(cache="none", distribution="sharded", jit=True,
                           restarts=2), "fused_mesh"),
}


@pytest.mark.parametrize("point", sorted(_SERVE_GRID))
def test_save_load_serving_roundtrip_grid(point, tmp_path):
    kw, mesh_kind = _SERVE_GRID[point]
    x = _blobs()
    est = KernelKMeans(_cfg(**kw), mesh=_mesh_of(mesh_kind)).fit(x, KEY)
    p = str(tmp_path / f"{point}.npz")
    est.save(p)
    loaded = KernelKMeans.load(p)
    np.testing.assert_array_equal(np.asarray(loaded.predict(x[:64])),
                                  np.asarray(est.predict(x[:64])),
                                  err_msg=point)
    np.testing.assert_allclose(np.asarray(loaded.transform(x[:16])),
                               np.asarray(est.transform(x[:16])),
                               atol=1e-6, err_msg=point)
    assert loaded._outcome is None      # serving-only: no resumable carry
    if mesh_kind is None:
        with pytest.raises(NotImplementedError, match="partial_fit"):
            loaded.partial_fit(x)


# -------------------------------------------------- pad-and-mask (1 device)
def test_n_valid_none_matches_legacy_bound_single_shard():
    """n_valid == full rows on a 1-shard mesh: the masked sampler bound is
    the same value as the legacy static bound -> bit-equal trajectories."""
    from repro.core.distributed import (
        fit_distributed_jit, init_dist_state, make_dist_sampling_step,
        shard_dataset, state_shardings)
    from repro.core.minibatch import run_early_stopped
    from repro.core.state import window_size

    x, fk, idx0 = _derived()
    mesh = _mesh1()
    mb = _cfg().mb_config()
    st_ref, it_ref = fit_distributed_jit(x, x[idx0], GAUSS, mb, mesh, fk)

    w = window_size(mb.batch_size, mb.tau)
    state0 = jax.device_put(init_dist_state(x[idx0], GAUSS, w),
                            state_shardings(mesh))
    xs = shard_dataset(x, mesh)
    step = make_dist_sampling_step(GAUSS, mb, mesh, n_valid=x.shape[0])

    @jax.jit
    def run(state, xs, key):
        def swk(st, kb):
            st, info = step(st, xs, kb)
            return st, info.improvement

        return run_early_stopped(mb, swk, state, key)

    st_m, it_m = run(state0, xs, fk)
    np.testing.assert_array_equal(np.asarray(st_ref.sqnorm),
                                  np.asarray(st_m.sqnorm))
    assert int(it_ref) == int(it_m)


# ------------------------------------------------- pad-and-mask (8 devices)
def _run_sub(script: str, ok_token: str, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert ok_token in r.stdout, r.stdout[-2000:]


PAD_MASK = """
    import warnings; warnings.simplefilter("ignore", DeprecationWarning)
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import KernelKMeans, SolverConfig
    from repro.core import MBConfig, Gaussian
    from repro.core.distributed import fit_distributed_jit, pad_for_mesh
    from repro.data import blobs

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg = SolverConfig(k=8, batch_size=128, tau=64, max_iters=6,
                       epsilon=-1.0, kernel=kern, cache="none",
                       distribution="sharded", jit=True)
    key = jax.random.PRNGKey(7)

    # (a) divisible rows: estimator (pad machinery armed but inactive) is
    # bit-equal to the legacy entry point
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100
    from repro.api import keys as api_keys
    fk = key   # legacy twin gets the same raw key via explicit centers
    est = KernelKMeans(cfg, mesh=mesh)
    out = est.plan_for(x.shape[0]).executor.fit(x, key,
                                                center_pts=x[init_idx],
                                                always_split=False)
    st_ref, it_ref = fit_distributed_jit(x, x[init_idx], kern,
                                         cfg.mb_config(), mesh, fk)
    np.testing.assert_array_equal(np.asarray(out.state.sqnorm),
                                  np.asarray(st_ref.sqnorm))
    assert int(out.iters) == int(it_ref)

    # (b) non-divisible rows (legacy raised ValueError): the estimator
    # pads and masks; the pad CONTENT must be invisible — two fills,
    # identical trajectories on the real rows
    xo = x[:2043]                        # 2043 % 4 != 0
    try:
        fit_distributed_jit(xo, xo[init_idx], kern, cfg.mb_config(), mesh,
                            fk)
        raise SystemExit("legacy should have raised on 2043 rows")
    except ValueError:
        pass
    ex = KernelKMeans(cfg, mesh=mesh).plan_for(xo.shape[0]).executor
    out0 = ex.fit(xo, key, center_pts=xo[init_idx], always_split=False,
                  pad_fill=0.0)
    outb = ex.fit(xo, key, center_pts=xo[init_idx], always_split=False,
                  pad_fill=1e6)
    np.testing.assert_array_equal(np.asarray(out0.state.sqnorm),
                                  np.asarray(outb.state.sqnorm))
    np.testing.assert_array_equal(np.asarray(out0.state.pts),
                                  np.asarray(outb.state.pts))
    # every window point is a REAL dataset row (no fill coordinates)
    pts = np.asarray(out0.state.pts).reshape(-1, xo.shape[1])
    assert np.abs(pts).max() < 1e5

    # (c) end-to-end: estimator fit + predict on the non-divisible set
    est2 = KernelKMeans(cfg, mesh=mesh).fit(xo, key=0)
    lab = est2.predict(xo)
    assert lab.shape == (2043,)
    assert np.isfinite(np.asarray(est2.state_.sqnorm)).all()

    # (d) batch_size that does not divide the data shards is rounded up
    cfg_odd = SolverConfig(k=8, batch_size=126, tau=64, max_iters=4,
                           epsilon=-1.0, kernel=kern, cache="none",
                           distribution="sharded", jit=True)
    est3 = KernelKMeans(cfg_odd, mesh=mesh).fit(x, key=0)
    assert est3.plan_.executor.effective_batch_size == 128
    assert float(jnp.sum(est3.state_.counts)) == 128 * 4

    # (e) cached sharded plan on the padded dataset
    cfg_c = cfg.replace(cache="lru", cache_tile=128, cache_capacity=16)
    est4 = KernelKMeans(cfg_c, mesh=mesh).fit(xo, key=0)
    assert np.isfinite(np.asarray(est4.state_.sqnorm)).all()
    from repro.cache import stats
    s0 = stats(jax.tree.map(lambda a: a[0], est4.cache_))
    assert s0["hits"] > 0

    print("PAD_MASK_OK")
"""


@pytest.mark.slow
def test_pad_and_mask_8dev():
    _run_sub(PAD_MASK, "PAD_MASK_OK")


FUSED_8DEV = """
    import warnings; warnings.simplefilter("ignore", DeprecationWarning)
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import KernelKMeans, SolverConfig
    from repro.api import keys as api_keys
    from repro.core import Gaussian
    from repro.core.distributed import (
        init_dist_state, make_dist_sampling_step, state_shardings)
    from repro.core.engine import make_init_run
    from repro.core.minibatch import run_early_stopped_keyed
    from repro.core.state import window_size
    from repro.data import blobs

    assert len(jax.devices()) == 8, jax.devices()
    R = 4
    mesh = jax.make_mesh((2, 2, 2), ("restart", "data", "model"))
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg = SolverConfig(k=8, batch_size=128, tau=64, max_iters=6,
                       epsilon=-1.0, kernel=kern, cache="none",
                       distribution="sharded", restarts=R, jit=True)
    key = jax.random.PRNGKey(7)
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    est = KernelKMeans(cfg, mesh=mesh).fit(x, key)
    assert est.plan_.name == "fused_restart_sharded"
    res = est.result_

    # ground truth: R sequential sharded fits on the (data, model)
    # submesh with the fused plan's exact per-restart keys
    mesh2 = jax.make_mesh((2, 2), ("data", "model"),
                          devices=jax.devices()[:4])
    mb = cfg.mb_config()
    k_init, k_fit, k_eval = api_keys.restart_keys(key)
    init_idx = make_init_run(kern, mb, "kmeans++")(
        api_keys.per_restart(k_init, R), x)
    fit_keys = api_keys.per_restart(k_fit, R)
    w = window_size(mb.batch_size, mb.tau)
    step = make_dist_sampling_step(kern, mb, mesh2, n_valid=None)

    @jax.jit
    def run_one(state, xs, kk):
        def swk(st, kb):
            st, info = step(st, xs, kb)
            return st, info.improvement
        return run_early_stopped_keyed(mb, swk, state, kk)

    finals = []
    for r in range(R):
        st0 = jax.device_put(init_dist_state(x[init_idx[r]], kern, w),
                             state_shardings(mesh2))
        stf, it, _ = run_one(st0, x, fit_keys[r])
        assert int(it) == int(res.iters[r]), r
        finals.append(jax.device_get(stf))
    win = finals[int(res.best)]
    for name in ("pts", "coef", "head", "sqnorm", "counts"):
        np.testing.assert_array_equal(
            np.asarray(getattr(est.state_, name)),
            np.asarray(getattr(win, name)), err_msg=name)

    # sharded serving straight off the fused mesh
    lab = est.predict(x[:999])
    assert lab.shape == (999,)
    assert 0 <= int(jnp.min(lab)) and int(jnp.max(lab)) < 8

    # cached fused plan: per-(restart, data-shard) caches, PR-2
    # equivalence bar vs the uncached fused fit
    from repro.cache import stats
    cfg_c = cfg.replace(cache="lru", cache_tile=128, cache_capacity=16)
    ec = KernelKMeans(cfg_c, mesh=mesh).fit(x, key)
    np.testing.assert_array_equal(np.asarray(ec.result_.iters),
                                  np.asarray(res.iters))
    assert int(ec.result_.best) == int(res.best)
    np.testing.assert_array_equal(np.asarray(ec.state_.counts),
                                  np.asarray(est.state_.counts))
    np.testing.assert_allclose(np.asarray(ec.state_.sqnorm),
                               np.asarray(est.state_.sqnorm), atol=1e-5)
    for r in range(R):
        for s in range(2):
            st = stats(jax.tree.map(lambda a: a[r, s], ec._outcome.caches))
            assert st["hits"] > 0 and st["misses"] >= 1, (r, s, st)
    print("FUSED_8DEV_OK")
"""


@pytest.mark.slow
def test_fused_restart_sharded_8dev():
    _run_sub(FUSED_8DEV, "FUSED_8DEV_OK")
