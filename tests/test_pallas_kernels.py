"""Pallas kernel validation: shape/dtype sweeps + hypothesis property tests,
all in interpret mode (executes the real tiling/accumulation logic on CPU)
against the ref.py pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite degrades, not errors
from hypothesis import given, settings, strategies as st

from repro.core.kernel_fns import Gaussian, Linear, Polynomial
from repro.kernels import ref
from repro.kernels.fused_assign import fused_batch_center_dots_pallas
from repro.kernels.kernel_matmul import kernel_matmul_pallas
from repro.kernels import ops

KERNELS = {
    "gaussian": (Gaussian(kappa=jnp.float32(1.3)),
                 dict(kind="gaussian", p0=1.3)),
    "linear": (Linear(), dict(kind="linear")),
    "polynomial": (Polynomial(bias=jnp.float32(1.0), scale=jnp.float32(2.0),
                              degree=2),
                   dict(kind="polynomial", p0=1.0, p1=2.0, p2=2)),
}


def _rand(shape, seed, dtype=jnp.float32):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape), dtype)


# --------------------------------------------------------- fused_assign
@pytest.mark.parametrize("kname", list(KERNELS))
@pytest.mark.parametrize("b,k,w,d", [
    (8, 3, 16, 4),      # tiny, everything unaligned
    (128, 4, 32, 8),    # b aligned, w tile-multiple
    (100, 2, 50, 130),  # d > tile, all unaligned
    (32, 16, 8, 64),    # many centers
])
def test_fused_assign_shapes(kname, b, k, w, d):
    kern, kw = KERNELS[kname]
    xb = _rand((b, d), 0)
    sup = _rand((k, w, d), 1)
    coef = jnp.abs(_rand((k, w), 2)) / w
    got = fused_batch_center_dots_pallas(xb, sup, coef, bt=16, st=16,
                                         interpret=True, **kw)
    want = ref.batch_center_dots(kern, xb, sup, coef)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_assign_dtypes(dtype):
    kern, kw = KERNELS["gaussian"]
    xb = _rand((24, 16), 0, dtype)
    sup = _rand((3, 20, 16), 1, dtype)
    coef = (jnp.abs(_rand((3, 20), 2)) / 20).astype(dtype)
    got = fused_batch_center_dots_pallas(xb, sup, coef, bt=8, st=8,
                                         interpret=True, **kw)
    want = ref.batch_center_dots(
        kern, xb.astype(jnp.float32), sup.astype(jnp.float32),
        coef.astype(jnp.float32))
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 40), st.integers(1, 5), st.integers(1, 40),
       st.integers(1, 20), st.integers(0, 2 ** 16))
def test_fused_assign_property(b, k, w, d, seed):
    kern, kw = KERNELS["gaussian"]
    xb = _rand((b, d), seed)
    sup = _rand((k, w, d), seed + 1)
    coef = jnp.abs(_rand((k, w), seed + 2)) / w
    got = fused_batch_center_dots_pallas(xb, sup, coef, bt=8, st=8,
                                         interpret=True, **kw)
    want = ref.batch_center_dots(kern, xb, sup, coef)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(max_examples=12, deadline=None)
@given(st.integers(1, 48), st.integers(1, 4), st.integers(1, 48),
       st.integers(1, 24), st.sampled_from([4, 8, 16, 32, 128]),
       st.sampled_from([4, 8, 16, 32, 128]), st.integers(0, 2 ** 16))
def test_fused_assign_tile_sweep_property(b, k, w, d, bt, st_, seed):
    """Tiling invariance: any (bt, st) tile pair gives the einsum answer —
    the property the per-shard tile clamping in ops.py relies on."""
    kern, kw = KERNELS["gaussian"]
    xb = _rand((b, d), seed)
    sup = _rand((k, w, d), seed + 1)
    coef = jnp.abs(_rand((k, w), seed + 2)) / w
    got = fused_batch_center_dots_pallas(xb, sup, coef, bt=bt, st=st_,
                                         interpret=True, **kw)
    want = ref.batch_center_dots(kern, xb, sup, coef)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


def test_fused_assign_zero_coef_padding_invariance():
    """Empty window slots (coef 0) contribute exactly nothing."""
    kern, kw = KERNELS["gaussian"]
    xb = _rand((16, 8), 0)
    sup = _rand((2, 12, 8), 1)
    coef = jnp.abs(_rand((2, 12), 2))
    coef = coef.at[:, 6:].set(0.0)
    sup_junk = sup.at[:, 6:, :].set(1e3)  # junk points behind zero coefs
    a = fused_batch_center_dots_pallas(xb, sup, coef, bt=8, st=8,
                                       interpret=True, **kw)
    bq = fused_batch_center_dots_pallas(xb, sup_junk, coef, bt=8, st=8,
                                        interpret=True, **kw)
    np.testing.assert_allclose(a, bq, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------- kernel_matmul
@pytest.mark.parametrize("kname", list(KERNELS))
@pytest.mark.parametrize("n,m,c,d", [
    (16, 16, 2, 4),
    (100, 64, 5, 16),
    (33, 70, 10, 130),
    (128, 128, 1, 32),
])
def test_kernel_matmul_shapes(kname, n, m, c, d):
    kern, kw = KERNELS[kname]
    x = _rand((n, d), 0)
    y = _rand((m, d), 1)
    v = _rand((m, c), 2)
    got = kernel_matmul_pallas(x, y, v, nt=16, mt=16, interpret=True, **kw)
    want = ref.kernel_matmul(kern, x, y, v)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 50), st.integers(1, 50), st.integers(1, 8),
       st.integers(1, 20), st.integers(0, 2 ** 16))
def test_kernel_matmul_property(n, m, c, d, seed):
    kern, kw = KERNELS["gaussian"]
    x = _rand((n, d), seed)
    y = _rand((m, d), seed + 1)
    v = _rand((m, c), seed + 2)
    got = kernel_matmul_pallas(x, y, v, nt=8, mt=8, interpret=True, **kw)
    want = ref.kernel_matmul(kern, x, y, v)
    np.testing.assert_allclose(got, want, rtol=3e-4, atol=3e-4)


# --------------------------------------------------------- ops dispatch
def test_ops_dispatch_matches_core_path():
    """ops.fused_batch_center_dots == the einsum inside minibatch.make_step."""
    from repro.core.minibatch import _batch_center_dots
    kern = Gaussian(kappa=jnp.float32(0.9))
    x = _rand((200, 8), 3)
    idx = jnp.asarray(np.random.default_rng(0).integers(0, 200, (4, 24)),
                      jnp.int32)
    coef = jnp.abs(_rand((4, 24), 4)) / 24
    xb = x[:32]
    want = _batch_center_dots(kern, xb, x, idx, coef, use_pallas=False)
    got = ops.fused_batch_center_dots(kern, xb, x[idx.reshape(-1)], coef,
                                      bt=16, st=16, interpret=True)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_minibatch_step_with_pallas_matches_xla():
    """End-to-end: Algorithm 2 step with use_pallas=True == XLA path."""
    from repro.core import MBConfig, make_step, init_state, window_size
    from repro.core.minibatch import sample_batch
    from repro.data import blobs
    x, _ = blobs(n=512, d=16, k=4, seed=0)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg_x = MBConfig(k=4, batch_size=64, tau=32, max_iters=5, epsilon=-1.0)
    cfg_p = cfg_x._replace(use_pallas=True)
    init_idx = jnp.array([0, 100, 200, 300], jnp.int32)
    w = window_size(cfg_x.batch_size, cfg_x.tau)
    s_x = init_state(x, init_idx, kern, w)
    s_p = init_state(x, init_idx, kern, w)
    step_x = jax.jit(make_step(kern, cfg_x))
    step_p = jax.jit(make_step(kern, cfg_p))
    key = jax.random.PRNGKey(0)
    for i in range(3):
        key, kb = jax.random.split(key)
        bidx = sample_batch(kb, 512, 64)
        s_x, i_x = step_x(s_x, x, bidx)
        s_p, i_p = step_p(s_p, x, bidx)
        assert float(i_x.f_before) == pytest.approx(float(i_p.f_before),
                                                    abs=1e-5)
    np.testing.assert_allclose(s_x.sqnorm, s_p.sqnorm, atol=1e-5)
