"""Distributed (shard_map) + multi-restart engine equivalence on 8 virtual
devices — runs in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single real CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(script: str, ok_token: str, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert ok_token in r.stdout, r.stdout[-2000:]
    return r.stdout


STEP_EQUIVALENCE = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import MBConfig, Gaussian, init_state, window_size, make_step
    from repro.core.distributed import (
        make_dist_step, init_dist_state, state_shardings, fit_distributed)
    from repro.core.minibatch import sample_batch
    from repro.data import blobs

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=8, epsilon=-1.0)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100
    w = window_size(cfg.batch_size, cfg.tau)

    # use_pallas=True additionally exercises the fused Pallas kernel on
    # per-shard support tiles (interpret mode on CPU) inside shard_map
    for use_pallas in (False, True):
        c = cfg._replace(use_pallas=use_pallas)
        st = init_state(x, init_idx, kern, w)
        step1 = jax.jit(make_step(kern, c))
        dst = jax.device_put(init_dist_state(x[init_idx], kern, w),
                             state_shardings(mesh))
        stepd = jax.jit(make_dist_step(kern, c, mesh))
        key = jax.random.PRNGKey(7)
        for i in range(6):
            key, kb = jax.random.split(key)
            bidx = sample_batch(kb, x.shape[0], cfg.batch_size)
            st, i1 = step1(st, x, bidx)
            dst, i2 = stepd(dst, x[bidx])
            assert abs(float(i1.f_before) - float(i2.f_before)) < 1e-5, \\
                (use_pallas, i)
            assert abs(float(i1.f_after) - float(i2.f_after)) < 1e-5, \\
                (use_pallas, i)
        np.testing.assert_allclose(np.asarray(st.sqnorm),
                                   np.asarray(dst.sqnorm), atol=1e-5)

    # multi-pod style 3-axis mesh also works
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    dst3 = jax.device_put(init_dist_state(x[init_idx], kern, w),
                          state_shardings(mesh3))
    stepd3 = jax.jit(make_dist_step(kern, cfg, mesh3,
                                    data_axes=("pod", "data")))
    dst3, i3 = stepd3(dst3, x[sample_batch(jax.random.PRNGKey(1),
                                           x.shape[0], cfg.batch_size)])
    assert np.isfinite(float(i3.f_before))

    # fit_distributed end-to-end over a host stream
    def stream():
        key = jax.random.PRNGKey(3)
        while True:
            key, kb = jax.random.split(key)
            yield x[sample_batch(kb, x.shape[0], cfg.batch_size)]
    state, hist = fit_distributed(stream(), x[init_idx], kern,
                                  cfg._replace(max_iters=10), mesh,
                                  early_stop=False)
    assert len(hist) == 10
    assert hist[-1]["f_before"] < hist[0]["f_before"]
    print("DISTRIBUTED-OK")
"""


ONDEVICE_FIT = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import MBConfig, Gaussian
    from repro.core.distributed import (
        fit_distributed_jit, predict_distributed, dist_to_center_state)
    from repro.data import blobs

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=15, epsilon=-1.0)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100

    # whole early-stopped loop on-device: dataset sharded, batches sampled
    # shard-locally, zero per-step host sync
    dst, iters = fit_distributed_jit(x, x[init_idx], kern, cfg, mesh,
                                     jax.random.PRNGKey(3))
    assert int(iters) == cfg.max_iters
    assert bool(jnp.all(jnp.isfinite(dst.sqnorm)))
    assert float(jnp.sum(dst.counts)) == cfg.batch_size * cfg.max_iters

    # early stopping still terminates the on-device loop
    dst2, iters2 = fit_distributed_jit(
        x, x[init_idx], kern, cfg._replace(max_iters=300, epsilon=0.01),
        mesh, jax.random.PRNGKey(4))
    assert int(iters2) < 300

    # sharded serving straight from the distributed state
    cs = dist_to_center_state(dst)
    sup = dst.pts.reshape(-1, dst.pts.shape[-1])
    pred = predict_distributed(cs, sup, x[:999], kern, mesh)
    assert pred.shape == (999,)
    assert int(jnp.max(pred)) < 8 and int(jnp.min(pred)) >= 0
    print("ONDEVICE-OK")
"""


ENGINE_8DEV = """
    import time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import MBConfig, Gaussian, fit_jit
    from repro.core.engine import MultiRestartEngine
    from repro.data import blobs
    from repro.launch.mesh import make_restart_mesh

    assert len(jax.devices()) == 8
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=15, epsilon=-1.0)

    # restart-sharded engine == unsharded engine, bitwise-comparable
    mesh = make_restart_mesh(4)
    assert mesh.devices.size == 4
    eng = MultiRestartEngine(kern, cfg, restarts=4, mesh=mesh)
    res = eng.fit(x, jax.random.PRNGKey(0))
    eng0 = MultiRestartEngine(kern, cfg, restarts=4)
    res0 = eng0.fit(x, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(res.objectives),
                               np.asarray(res0.objectives), atol=1e-6)
    assert int(res.best) == int(res0.best)

    # sharded predict == unsharded predict on the same winner
    p = eng.predict(x[:999])
    p0 = eng0.predict(x[:999])
    np.testing.assert_array_equal(np.asarray(p), np.asarray(p0))

    # wall-clock: best-of-4 in one compiled program stays under 2x the
    # repo's single-restart entry point (fit_jit pays a re-trace per call;
    # the engine amortizes its compile across fits)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100
    t0 = time.perf_counter()
    _, it = fit_jit(x, kern, cfg, jax.random.PRNGKey(5), init_idx)
    jax.block_until_ready(it)
    t_single = time.perf_counter() - t0
    t0 = time.perf_counter()
    r = eng.fit(x, jax.random.PRNGKey(5))
    jax.block_until_ready(r.objectives)
    t_multi = time.perf_counter() - t0
    ratio = t_multi / t_single
    print(f"R4 vs single ratio: {ratio:.2f}")
    assert ratio < 2.0, (t_multi, t_single)
    print("ENGINE-8DEV-OK")
"""


FULLY_PADDED_SHARDS = """
    import warnings; warnings.simplefilter("ignore", DeprecationWarning)
    import jax, jax.numpy as jnp, numpy as np
    from repro.api import KernelKMeans, SolverConfig
    from repro.core import Gaussian
    from repro.core.distributed import pad_for_mesh

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    kern = Gaussian(kappa=jnp.float32(1.0))
    # n=10 over 8 shards: L=2 rows per shard, so shards 5..7 are ALL
    # padding (n_valid=10 <= (8-1)*2).  The old clamped sampler bound
    # (clip(n_valid - start, 1, L)) would have drawn pad row 0 of those
    # shards into EVERY batch; pad_for_mesh used to refuse outright.
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(10, 4).astype(np.float32))
    xp, nv = pad_for_mesh(x, mesh, ("data",))
    assert xp.shape[0] == 16 and nv == 10
    cfg = SolverConfig(k=2, batch_size=16, tau=8, max_iters=5,
                       epsilon=-1.0, kernel=kern, cache="none",
                       distribution="sharded", jit=True)

    # (a) pad CONTENT is invisible: two fills, identical trajectories
    ex0 = KernelKMeans(cfg, mesh=mesh).plan_for(10).executor
    out0 = ex0.fit(x, jax.random.PRNGKey(1), pad_fill=0.0)
    exb = KernelKMeans(cfg, mesh=mesh).plan_for(10).executor
    outb = exb.fit(x, jax.random.PRNGKey(1), pad_fill=1e6)
    np.testing.assert_array_equal(np.asarray(out0.state.sqnorm),
                                  np.asarray(outb.state.sqnorm))
    np.testing.assert_array_equal(np.asarray(out0.state.pts),
                                  np.asarray(outb.state.pts))

    # (b) every window point is a REAL dataset row — zero pad rows in any
    # sampled batch
    pts = np.asarray(outb.state.pts).reshape(-1, 4)
    assert np.abs(pts).max() < 1e5

    # (c) fully-padded shards contribute ZERO batch mass: per-step batch
    # size is b_loc * ceil(n / L) = 2 * 5, not the nominal 16
    assert float(jnp.sum(out0.state.counts)) == 2 * 5 * 5

    # (d) cached sharded plan under the same layout: per-shard caches,
    # window ids all real
    cfg_c = cfg.replace(cache="lru", cache_tile=8, cache_capacity=4)
    est_c = KernelKMeans(cfg_c, mesh=mesh).fit(x, key=1)
    ids = np.asarray(est_c.state_.pts[..., 0]).astype(int)
    assert ids.max() < 10
    assert float(jnp.sum(est_c.state_.counts)) == 2 * 5 * 5
    print("FULLY_PADDED_OK")
"""


@pytest.mark.slow
def test_fully_padded_shards_masked_8dev():
    """Regression (pad-row leak): a data shard whose rows are all padding
    used to sample its pad row 0 into every batch via the bottom-clamped
    bound — it must contribute nothing instead."""
    _run(FULLY_PADDED_SHARDS, "FULLY_PADDED_OK")


@pytest.mark.slow
def test_distributed_equivalence_8dev():
    _run(STEP_EQUIVALENCE, "DISTRIBUTED-OK")


@pytest.mark.slow
def test_fit_distributed_jit_8dev():
    _run(ONDEVICE_FIT, "ONDEVICE-OK")


@pytest.mark.slow
def test_engine_8dev_equivalence_and_wallclock():
    out = _run(ENGINE_8DEV, "ENGINE-8DEV-OK")
    assert "ratio" in out
