"""Distributed (shard_map) step equivalence — runs in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single real CPU device."""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import MBConfig, Gaussian, init_state, window_size, make_step
    from repro.core.distributed import (
        make_dist_step, init_dist_state, state_shardings, fit_distributed)
    from repro.core.minibatch import sample_batch
    from repro.data import blobs

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=8, epsilon=-1.0)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100
    w = window_size(cfg.batch_size, cfg.tau)

    st = init_state(x, init_idx, kern, w)
    step1 = jax.jit(make_step(kern, cfg))
    dst = jax.device_put(init_dist_state(x[init_idx], kern, w),
                         state_shardings(mesh))
    stepd = jax.jit(make_dist_step(kern, cfg, mesh))

    key = jax.random.PRNGKey(7)
    for i in range(6):
        key, kb = jax.random.split(key)
        bidx = sample_batch(kb, x.shape[0], cfg.batch_size)
        st, i1 = step1(st, x, bidx)
        dst, i2 = stepd(dst, x[bidx])
        assert abs(float(i1.f_before) - float(i2.f_before)) < 1e-5, i
        assert abs(float(i1.f_after) - float(i2.f_after)) < 1e-5, i
    np.testing.assert_allclose(np.asarray(st.sqnorm), np.asarray(dst.sqnorm),
                               atol=1e-5)

    # multi-pod style 3-axis mesh also works
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    dst3 = jax.device_put(init_dist_state(x[init_idx], kern, w),
                          state_shardings(mesh3))
    stepd3 = jax.jit(make_dist_step(kern, cfg, mesh3,
                                    data_axes=("pod", "data")))
    dst3, i3 = stepd3(dst3, x[sample_batch(jax.random.PRNGKey(1),
                                           x.shape[0], cfg.batch_size)])
    assert np.isfinite(float(i3.f_before))

    # fit_distributed end-to-end over a stream
    def stream():
        key = jax.random.PRNGKey(3)
        while True:
            key, kb = jax.random.split(key)
            yield x[sample_batch(kb, x.shape[0], cfg.batch_size)]
    state, hist = fit_distributed(stream(), x[init_idx], kern,
                                  cfg._replace(max_iters=10), mesh,
                                  early_stop=False)
    assert len(hist) == 10
    assert hist[-1]["f_before"] < hist[0]["f_before"]
    print("DISTRIBUTED-OK")
""")


@pytest.mark.slow
def test_distributed_equivalence_8dev():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DISTRIBUTED-OK" in r.stdout
