"""Per-architecture smoke tests (instructions: REDUCED config of the same
family; one forward/train step on CPU; assert output shapes + no NaNs).
The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.configs.shapes import applicable
from repro.models import (
    ModelConfig, decode_step, forward_train, init_cache, init_params, prefill,
)
from repro.train import AdamWConfig, make_train_state, make_train_step

B, S = 2, 64


def _batch(cfg: ModelConfig, key):
    k1, k2 = jax.random.split(key)
    out = {"labels": jax.random.randint(k2, (B, S), 0, cfg.vocab)}
    if cfg.frontend == "stub":
        out["embeds"] = jax.random.normal(k1, (B, S, cfg.frontend_dim))
    else:
        out["tokens"] = jax.random.randint(k1, (B, S), 0, cfg.vocab)
    return out


@pytest.fixture(scope="module")
def arch_state():
    return {}


@pytest.mark.parametrize("arch", all_arch_names())
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits = forward_train(params, cfg, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(logits))), f"{arch}: NaN logits"

    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup=1)))
    state = make_train_state(params)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"])), f"{arch}: loss not finite"
    assert int(state.step) == 1
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(state.params)[0]
    assert not np.allclose(np.asarray(l0), np.asarray(l1))


@pytest.mark.parametrize("arch", all_arch_names())
def test_smoke_serve_paths(arch):
    cfg = get_config(arch).reduced(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    if cfg.is_encoder:
        # encode == forward; no decode (skip recorded in DESIGN.md)
        ok, reason = applicable(cfg, "decode_32k")
        assert not ok and "encoder" in reason
        return

    lg, cache = prefill(params, cfg, {k: v for k, v in batch.items()
                                      if k != "labels"}, cache_len=S + 8)
    assert lg.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))

    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.full((B,), S, jnp.int32)
    lg2, cache2 = decode_step(params, cfg, cache, tok, pos)
    assert lg2.shape == (B, 1, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg2)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", all_arch_names())
def test_full_config_exact_spec(arch):
    """The FULL config matches the assigned table exactly (no allocation)."""
    cfg = get_config(arch)
    table = {
        "h2o_danube_1_8b": (24, 2560, 32, 8, 6912, 32000),
        "qwen3_1_7b": (28, 2048, 16, 8, 6144, 151936),
        "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen2_72b": (80, 8192, 64, 8, 29568, 152064),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
        "arctic_480b": (35, 7168, 56, 8, 4864, 32000),
        "deepseek_v2_236b": (60, 5120, 128, 128, 12288, 102400),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
        "hubert_xlarge": (48, 1280, 16, 16, 5120, 504),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
    }
    ll, d, h, kv, ff, v = table[arch]
    assert cfg.n_layers == ll and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert cfg.d_ff == ff and cfg.vocab == v


def test_feature_flags_match_table():
    assert get_config("h2o-danube-1.8b").sliding_window == 4096
    assert get_config("qwen3-1.7b").qk_norm
    assert get_config("nemotron-4-340b").mlp == "sq_relu"
    assert get_config("qwen2-72b").qkv_bias
    assert get_config("zamba2-2.7b").attn_every == 6
    assert get_config("zamba2-2.7b").ssm_state == 64
    a = get_config("arctic-480b")
    assert a.n_experts == 128 and a.top_k == 2 and a.dense_residual
    d = get_config("deepseek-v2-236b")
    assert d.mla and d.kv_lora == 512 and d.n_experts == 160 \
        and d.top_k == 6 and d.n_shared_experts == 2
    assert get_config("qwen2-vl-72b").mrope_sections == (16, 24, 24)
    assert not get_config("hubert-xlarge").causal
    assert get_config("rwkv6-3b").family == "ssm"
