"""Landmark compression subsystem (repro.landmark): spec validation,
selection/solve primitives, state compression invariants, the
CompressedKernelCenters serving representation, the grow_window
no-eviction baseline, estimator integration (compress / support_stats /
format-2 save-load), and the drift-bound property across repeated
compress -> fit -> compress cycles.

Shapes are tiny (n=256, d=4, k=3, W=32) and the one mini-batch step
program is shared module-wide, so the whole file runs in the fast lane.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Gaussian, MBConfig
from repro.core.minibatch import (
    center_distances_chunked, make_step, sample_batch,
)
from repro.core.state import init_state, window_size
from repro.data import blobs
from repro.landmark import (
    CompressedKernelCenters, CompressSpec, LandmarkBasis, compress_state,
    grow_window, jittered_solve, ridge_leverage_scores, select_rows,
    spec_of, wrap_step,
)

GAUSS = Gaussian(kappa=jnp.float32(1.0))
N, D, K, B, TAU = 256, 4, 3, 16, 16
W = window_size(B, TAU)
CFG = MBConfig(k=K, batch_size=B, tau=TAU, max_iters=4, epsilon=-1.0)


@functools.lru_cache(maxsize=None)
def _data():
    x, _ = blobs(n=N, d=D, k=K, seed=0)
    return jnp.asarray(x)


@functools.lru_cache(maxsize=None)
def _step():
    return jax.jit(make_step(GAUSS, CFG))


def _fit_state(seed=0, iters=8, st=None):
    x = _data()
    if st is None:
        st = init_state(x, (jnp.arange(K, dtype=jnp.int32) * 7) % N,
                        GAUSS, W)
    step = _step()
    for i in range(iters):
        st, _ = step(st, x, sample_batch(
            jax.random.fold_in(jax.random.PRNGKey(seed), i), N, B))
    return st


def _dists(coef, sqnorm, sup, xq):
    return center_distances_chunked(GAUSS, coef, sqnorm, sup, xq, 4096)


# ------------------------------------------------------------------ spec_of
def test_spec_of_accepts_off_and_none():
    assert spec_of(None) is None
    assert spec_of("off") is None
    assert spec_of(()) is None


@pytest.mark.parametrize("val", [
    {"m": 8}, {"m": 8, "every": 3}, (("every", 3), ("m", 8)),
    CompressSpec(every=3, m=8),
])
def test_spec_of_normalizes(val):
    spec = spec_of(val)
    assert isinstance(spec, CompressSpec)
    assert spec.m == 8 and spec.selector == "uniform"


@pytest.mark.parametrize("bad", [
    {"every": 3},                      # m required
    {"m": 0},                          # m >= 1
    {"m": 8, "every": -1},             # every >= 0
    {"m": 8, "selector": "nope"},      # unknown selector
    {"m": 8, "jitter": 0.0},           # jitter > 0
    {"m": 8, "banana": 1},             # unknown key
])
def test_spec_of_rejects_malformed(bad):
    with pytest.raises((ValueError, TypeError)):
        spec_of(bad)


# ------------------------------------------------------------- primitives
def test_jittered_solve_spd_and_singular():
    rng = np.random.default_rng(0)
    a = rng.normal(size=(6, 6)).astype(np.float32)
    spd = jnp.asarray(a @ a.T + 6 * np.eye(6, dtype=np.float32))
    rhs = jnp.asarray(rng.normal(size=6).astype(np.float32))
    beta = jittered_solve(spd, rhs, 1e-6)
    np.testing.assert_allclose(np.asarray(spd @ beta), np.asarray(rhs),
                               atol=1e-3)
    # duplicated landmarks -> rank-deficient Gram: still finite
    dup = jnp.ones((6, 6), jnp.float32)
    assert bool(jnp.all(jnp.isfinite(jittered_solve(dup, rhs, 1e-6))))


def test_select_rows_uniform_distinct_and_masked():
    mask = jnp.arange(12) < 9
    sel = select_rows(jax.random.PRNGKey(0), None, mask, 6, "uniform",
                      1e-6)
    sel = np.asarray(sel)
    assert len(set(sel.tolist())) == 6          # without replacement
    assert (sel < 9).all()                      # active rows only
    # fewer active rows than m: masked rows fill the tail
    sel2 = np.asarray(select_rows(jax.random.PRNGKey(0), None,
                                  jnp.arange(12) < 4, 6, "uniform", 1e-6))
    assert set(sel2[:4].tolist()) == {0, 1, 2, 3}


def test_select_rows_leverage_prefers_informative_rows():
    # two tight duplicate clusters + distinct rows: leverage ranks the
    # distinct rows above the copies
    x = np.zeros((8, 2), np.float32)
    x[:3] = [0.0, 0.0]
    x[3:6] = [4.0, 0.0]
    x[6] = [0.0, 6.0]
    x[7] = [6.0, 6.0]
    g = jnp.asarray(np.exp(-0.5 * np.sum(
        (x[:, None] - x[None]) ** 2, -1)).astype(np.float32))
    scores = ridge_leverage_scores(g, jnp.float32(1e-3))
    assert float(scores[6]) > float(scores[0])
    sel = np.asarray(select_rows(None, g, jnp.ones(8, bool), 4,
                                 "leverage", 1e-3))
    assert {6, 7} <= set(sel.tolist())


def test_landmark_basis_projection_exact_in_span():
    # a coefficient vector supported ON the landmarks is reproduced
    from repro.core.kernel_fns import kernel_cross

    x = _data()[:10]
    basis = LandmarkBasis.build(GAUSS, x, 10, selector="uniform",
                                key=jax.random.PRNGKey(0))
    coef = jnp.asarray(np.random.default_rng(0).normal(
        size=10).astype(np.float32))
    beta = basis.project_coef(x, coef)
    xe = _data()[10:40]
    f_true = kernel_cross(GAUSS, xe, x) @ coef
    f_hat = kernel_cross(GAUSS, xe, basis.z) @ beta
    np.testing.assert_allclose(np.asarray(f_hat), np.asarray(f_true),
                               atol=1e-3)
    # Nystrom features reproduce the Gram on the landmark span
    phi = basis.features(basis.z)
    np.testing.assert_allclose(np.asarray(phi @ phi.T),
                               np.asarray(kernel_cross(GAUSS, basis.z,
                                                       basis.z)),
                               atol=1e-2)


# ------------------------------------------------------ state compression
@pytest.mark.parametrize("selector", ["uniform", "leverage"])
def test_compress_state_invariants(selector):
    x = _data()
    st = _fit_state()
    m = 10
    st2, info = compress_state(
        GAUSS, st, {"m": m, "selector": selector}, x=x)
    # shape-preserving: compiled step programs keep working
    assert st2.idx.shape == st.idx.shape
    assert st2.coef.shape == st.coef.shape
    # tail empty (the coef==0 / idx==0 empty-slot invariant)
    assert np.all(np.asarray(st2.coef[:, m:]) == 0)
    assert np.all(np.asarray(st2.idx[:, m:]) == 0)
    assert np.all(np.asarray(st2.head) == m % W)
    # projection contracts the center norm
    assert np.all(np.asarray(st2.sqnorm) <= np.asarray(st.sqnorm) + 1e-5)
    assert np.all(np.asarray(info.residual) >= 0)
    # deterministic: same state -> bit-identical compression
    st3, _ = compress_state(GAUSS, st, {"m": m, "selector": selector},
                            x=x)
    np.testing.assert_array_equal(np.asarray(st2.coef),
                                  np.asarray(st3.coef))


def test_compress_drift_bound_contains_distance_shift():
    """|d_compressed(x) - d_full(x)| <= drift_bound pointwise: the
    2*gamma*eps + eps^2 orthogonal-projection bound of
    docs/compression.md."""
    x = _data()
    st = _fit_state()
    st2, info = compress_state(GAUSS, st, {"m": 8}, x=x)
    xe = _data()[:128]
    d_full = _dists(st.coef, st.sqnorm, x[st.idx.reshape(-1)], xe)
    d_comp = _dists(st2.coef, st2.sqnorm, x[st2.idx.reshape(-1)], xe)
    shift = float(jnp.max(jnp.abs(d_comp - d_full)))
    assert shift <= float(info.drift_bound) + 1e-5
    assert float(info.drift_bound) < 4.0        # normalized kernel scale


def test_wrap_step_compresses_on_cadence_only():
    x = _data()
    spec = CompressSpec(every=4, m=8)
    step = jax.jit(wrap_step(make_step(GAUSS, CFG), GAUSS, spec))
    st = init_state(x, (jnp.arange(K, dtype=jnp.int32) * 7) % N, GAUSS, W)
    for i in range(4):
        st, _ = step(st, x, sample_batch(
            jax.random.fold_in(jax.random.PRNGKey(0), i), N, B))
        if int(st.step) % 4 == 0:
            assert np.all(np.asarray(st.coef[:, 8:]) == 0)
        else:                  # off-cadence: window fills past m as usual
            pass
    assert int(st.step) == 4
    assert np.all(np.asarray(st.coef[:, 8:]) == 0)


# ------------------------------------------------------------ grow_window
def test_grow_window_preserves_serving_and_ring_order():
    x = _data()
    st = _fit_state()
    st2 = grow_window(st, 16)
    assert st2.idx.shape == (K, W + 16)
    np.testing.assert_array_equal(np.asarray(st2.head),
                                  np.asarray(st.head))
    xe = _data()[:64]
    d0 = _dists(st.coef, st.sqnorm, x[st.idx.reshape(-1)], xe)
    d1 = _dists(st2.coef, st2.sqnorm, x[st2.idx.reshape(-1)], xe)
    np.testing.assert_allclose(np.asarray(d0), np.asarray(d1), atol=1e-5)
    # fitting continues on the grown state (new width, same program shape
    # family) and fills the inserted slots before evicting anything
    step = jax.jit(make_step(GAUSS, CFG))
    st3, _ = step(st2, x, sample_batch(jax.random.PRNGKey(9), N, B))
    assert st3.idx.shape == (K, W + 16)


def test_grow_window_zero_extra_is_identity():
    st = _fit_state()
    assert grow_window(st, 0) is st


# ---------------------------------------------- serving representation
def test_compressed_kernel_centers_roundtrip():
    x = _data()
    st = _fit_state()
    sup = x[st.idx.reshape(-1)]
    ckc, info = CompressedKernelCenters.from_serving(
        GAUSS, sup, st.coef, st.sqnorm, m=8, step=int(st.step))
    assert (ckc.k, ckc.m) == (K, 8)
    kern, sup_c, coef_c, sq_c = ckc.serving_tuple()
    assert sup_c.shape == (K * 8, D) and coef_c.shape == (K, 8)
    xe = _data()[:96]
    labels = np.asarray(ckc.predict(xe))
    assert labels.shape == (96,) and set(labels) <= set(range(K))
    # predict == argmin(transform); score consistent with transform
    dd = ckc.transform(xe)
    np.testing.assert_array_equal(labels, np.asarray(jnp.argmin(dd, 1)))
    assert ckc.score(xe) == pytest.approx(-float(jnp.mean(jnp.min(dd, 1))))
    # serving distances within the reported drift bound of the full model
    d_full = _dists(st.coef, st.sqnorm, sup, xe)
    shift = float(jnp.max(jnp.abs(dd - d_full)))
    assert shift <= float(info.drift_bound) + 1e-5


def test_from_serving_spec_or_m_required():
    st = _fit_state()
    sup = _data()[st.idx.reshape(-1)]
    with pytest.raises(ValueError):
        CompressedKernelCenters.from_serving(GAUSS, sup, st.coef,
                                             st.sqnorm)


# ------------------------------------------------- estimator integration
def _est(**kw):
    from repro.api import KernelKMeans, SolverConfig

    base = dict(k=K, batch_size=B, tau=TAU, max_iters=6, epsilon=-1.0,
                early_stop=False, kernel=GAUSS, cache="none",
                distribution="single", jit=True)
    base.update(kw)
    return KernelKMeans(SolverConfig(**base))


def test_config_compress_axis_normalization():
    from repro.api import SolverConfig

    cfg = _est(compress={"m": 8, "every": 2}).config
    spec = cfg.compress_spec()
    assert spec == CompressSpec(every=2, m=8)
    assert isinstance(cfg.compress, tuple)      # canonical + hashable
    assert hash(cfg.compress) == hash(_est(
        compress=(("every", 2), ("m", 8))).config.compress)
    assert cfg.mb_config().compress == spec
    # every=0 (round-cadence only): no in-loop hook in the step program
    assert _est(compress={"m": 8}).config.mb_config().compress is None
    assert _est().config.mb_config().compress is None
    with pytest.raises(ValueError):             # m > W
        _est(compress={"m": W + 1})


def test_estimator_compress_support_stats_and_save_load(tmp_path):
    x = np.asarray(_data())
    est = _est().fit(x, jax.random.PRNGKey(0))
    ref = np.asarray(est.predict(x[:64]))
    assert est.support_stats()["compressions"] == 0
    est.compress(m=8)
    stats = est.support_stats()
    assert stats["rows"] == K * 8 and stats["compressions"] == 1
    assert stats["m"] == 8 and 0 < stats["ratio"] < 1
    assert np.isfinite(stats["last_drift"])
    labels = np.asarray(est.predict(x[:64]))
    assert np.mean(labels == ref) > 0.9         # serving barely moves
    # format-2 round trip: compressed serving + counters survive
    p = str(tmp_path / "m.npz")
    est.save(p)
    from repro.api import KernelKMeans

    loaded = KernelKMeans.load(p)
    np.testing.assert_array_equal(np.asarray(loaded.predict(x[:64])),
                                  labels)
    assert loaded.support_stats()["compressions"] == 1
    # the carry is still the FULL window: fitting resumes after load
    loaded.partial_fit(x[:128], iters=2)
    assert loaded.support_stats()["compressions"] == 1


def test_estimator_compress_noop_when_m_covers_window():
    x = np.asarray(_data())
    est = _est().fit(x, jax.random.PRNGKey(0))
    est.compress(m=W)                           # nothing to shrink
    assert est.support_stats()["compressions"] == 0


# ------------------------------------------------ drift-bound property
def _drift_cycle_check(m: int, seed: int, cycles: int = 3):
    """compress -> fit -> compress cycles: each projection's held-out
    objective shift obeys its own reported bound, and the bound itself
    stays at the normalized-kernel scale (no drift accumulation)."""
    x = _data()
    xe = _data()[:128]
    st = _fit_state(seed=seed)
    for c in range(cycles):
        sup = x[st.idx.reshape(-1)]
        obj0 = float(jnp.mean(jnp.min(
            _dists(st.coef, st.sqnorm, sup, xe), 1)))
        st, info = compress_state(GAUSS, st, {"m": m}, x=x)
        obj1 = float(jnp.mean(jnp.min(
            _dists(st.coef, st.sqnorm, x[st.idx.reshape(-1)], xe), 1)))
        bound = float(info.drift_bound)
        assert abs(obj1 - obj0) <= bound + 1e-5, (m, seed, c)
        assert 0 <= bound < 4.0, (m, seed, c, bound)
        st = _fit_state(seed=seed + c + 1, iters=4, st=st)


try:
    from hypothesis import given, settings
    from hypothesis import strategies as hyp_st

    @settings(max_examples=8, deadline=None)
    @given(m=hyp_st.integers(4, 24), seed=hyp_st.integers(0, 2 ** 16))
    def test_drift_bounded_across_cycles(m, seed):
        _drift_cycle_check(m, seed)

except ImportError:
    # hypothesis not installed in this environment: seeded fallback sweep
    # over the same (m, seed) space
    @pytest.mark.parametrize("m,seed", [
        (4, 0), (4, 11), (8, 1), (8, 1234), (12, 7), (16, 3),
        (16, 999), (24, 5), (24, 42),
    ])
    def test_drift_bounded_across_cycles(m, seed):
        _drift_cycle_check(m, seed)
