"""Gram tile cache subsystem (repro.cache): LRU correctness, cached vs
uncached numerical equivalence for fit / predict / the distributed path,
the Pallas gather-from-cache kernel, the nested sampler, and the
deterministic-resume pipeline regression."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (
    as_kernel, create_cache, cross_update, make_cached, precompute_gram,
    predict_cached, stats, warm, warm_rows,
)
from repro.core import MBConfig, fit, predict
from repro.core.kernel_fns import (
    Gaussian, Laplacian, Linear, Polynomial, diag_is_one, kernel_cross,
    kernel_diag,
)
from repro.core.minibatch import fit_cached, sample_batch_nested
from repro.data.pipeline import ClusterBatchPipeline

KERNELS = [
    Gaussian(kappa=jnp.float32(1.7)),
    Laplacian(kappa=jnp.float32(2.3)),
    Polynomial(bias=jnp.float32(1.0), scale=jnp.float32(4.0), degree=2),
    Linear(),
]


def _data(n=64, d=5, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)),
                       jnp.float32)


# ------------------------------------------------------------- LRU mechanics
def test_lru_eviction_order():
    x = _data(48)
    base = Gaussian(kappa=jnp.float32(1.0))
    c = create_cache(48, tile=8, capacity=3)
    c = warm(c, base, x, jnp.arange(0, 8))     # block 0
    c = warm(c, base, x, jnp.arange(8, 16))    # block 1
    c = warm(c, base, x, jnp.arange(16, 24))   # block 2 -> full
    assert sorted(np.asarray(c.keys).tolist()) == [0, 1, 2]
    c = warm(c, base, x, jnp.arange(0, 8))     # touch 0: now LRU is 1
    c = warm(c, base, x, jnp.arange(24, 32))   # block 3 evicts block 1
    assert sorted(np.asarray(c.keys).tolist()) == [0, 2, 3]
    assert int(c.evictions) == 1
    assert int(c.misses) == 4 and int(c.hits) == 1


def test_capacity_one_thrash_is_correct():
    x = _data(32)
    base = Polynomial(bias=jnp.float32(0.5), scale=jnp.float32(2.0),
                      degree=2)
    ck, xi = make_cached(base, x, tile=8, capacity=1)
    ridx = jnp.asarray([0, 9, 17, 25, 3, 11], jnp.int32)  # 4 distinct blocks
    cidx = jnp.arange(32, dtype=jnp.int32)
    out, ck = cross_update(ck, xi[ridx], xi[cidx])
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(kernel_cross(base, x[ridx],
                                                       x[cidx])),
                               atol=1e-6)
    s = stats(ck.cache)
    assert s["resident"] == 1 and s["capacity"] == 1
    assert s["misses"] == 4                     # every distinct block missed
    # repeat: capacity-1 cannot retain a 4-block working set -> thrash again
    out2, ck = cross_update(ck, xi[ridx], xi[cidx])
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out), atol=0)
    assert stats(ck.cache)["misses"] >= 7


def test_tile_must_divide_rows():
    with pytest.raises(ValueError):
        create_cache(100, tile=33, capacity=2)


# -------------------------------------------------- cross-kernel equivalence
@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: type(k).__name__)
def test_cached_cross_matches_direct(kern):
    x = _data(64, 6, seed=3)
    ck, xi = make_cached(kern, x, tile=16, capacity=2)
    rng = np.random.default_rng(5)
    ridx = jnp.asarray(rng.integers(0, 64, 23), jnp.int32)
    cidx = jnp.asarray(rng.integers(0, 64, 11), jnp.int32)
    want = kernel_cross(kern, x[ridx], x[cidx])
    got_stateful, ck = cross_update(ck, xi[ridx], xi[cidx])
    got_readonly = kernel_cross(ck, xi[ridx], xi[cidx])  # dispatch adapter
    np.testing.assert_allclose(np.asarray(got_stateful), np.asarray(want),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_readonly), np.asarray(want),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(kernel_diag(ck, xi[ridx])),
                               np.asarray(kernel_diag(kern, x[ridx])),
                               atol=1e-6)


def test_cached_cross_bfloat16_store():
    kern = Gaussian(kappa=jnp.float32(1.0))
    x = _data(32, 4, seed=9)
    ck, xi = make_cached(kern, x, tile=8, capacity=4, dtype=jnp.bfloat16)
    ridx = jnp.arange(32, dtype=jnp.int32)
    got, _ = cross_update(ck, xi[ridx], xi[ridx])
    want = kernel_cross(kern, x, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=2e-2)


def test_diag_is_one_flags():
    assert diag_is_one(Gaussian(kappa=jnp.float32(1.0)))
    assert diag_is_one(Laplacian(kappa=jnp.float32(1.0)))
    assert not diag_is_one(Linear())
    x = _data(16, 3)
    ck_g, _ = make_cached(Gaussian(kappa=jnp.float32(1.0)), x, tile=4,
                          capacity=2)
    ck_l, _ = make_cached(Linear(), x, tile=4, capacity=2)
    assert diag_is_one(ck_g) and not diag_is_one(ck_l)


def test_precomputed_gram_matches_direct():
    kern = Gaussian(kappa=jnp.float32(0.8))
    x = _data(40, 7, seed=2)
    pk, xi = as_kernel(precompute_gram(kern, x, block=16))
    np.testing.assert_allclose(np.asarray(pk.gram),
                               np.asarray(kernel_cross(kern, x, x)),
                               atol=1e-6)
    ridx = jnp.asarray([3, 17, 39, 0], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(kernel_cross(pk, xi[ridx], xi)),
        np.asarray(kernel_cross(kern, x[ridx], x)), atol=1e-6)


# --------------------------------------------------------- fit / predict
@pytest.mark.parametrize("use_pallas", [False, True],
                         ids=["xla", "pallas_gather"])
def test_fit_cached_matches_fit(use_pallas):
    from repro.data import blobs

    x, _ = blobs(n=256, d=8, k=4, seed=0)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(1.5))
    cfg = MBConfig(k=4, batch_size=32, tau=16, max_iters=8, epsilon=-1.0,
                   use_pallas=use_pallas)
    init_idx = jnp.array([0, 60, 120, 180], jnp.int32)
    st_u, hu = fit(x, kern, cfg, jax.random.PRNGKey(3), init_idx=init_idx,
                   early_stop=False)
    st_c, hc, ck = fit_cached(x, kern, cfg, jax.random.PRNGKey(3),
                              tile=32, capacity=8, init_idx=init_idx,
                              early_stop=False)
    assert len(hu) == len(hc)
    np.testing.assert_array_equal(np.asarray(st_u.idx), np.asarray(st_c.idx))
    np.testing.assert_allclose(np.asarray(st_u.sqnorm),
                               np.asarray(st_c.sqnorm), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_u.coef),
                               np.asarray(st_c.coef), atol=1e-5)
    for a, b in zip(hu, hc):
        assert a["f_after"] == pytest.approx(b["f_after"], abs=1e-5)

    xi = jnp.arange(256, dtype=jnp.float32)[:, None]
    pu = np.asarray(predict(st_u, x, x, kern))
    pc = np.asarray(predict(st_c, xi, xi, ck))
    np.testing.assert_array_equal(pu, pc)
    lab, ck2 = predict_cached(ck, st_c, jnp.arange(256), chunk=64)
    np.testing.assert_array_equal(np.asarray(lab), pu)
    s = stats(ck2.cache)
    assert s["hits"] > 0 and s["hit_rate"] > 0.5


def test_predict_cached_counters_all_hits_when_warm():
    kern = Gaussian(kappa=jnp.float32(1.0))
    x = _data(64, 4, seed=7)
    ck, xi = make_cached(kern, x, tile=16, capacity=4)
    ck = warm_rows(ck, jnp.arange(64))
    from repro.core.state import init_state
    state = init_state(xi, jnp.array([1, 33], jnp.int32), ck, window=8)
    _, ck = predict_cached(ck, state, jnp.arange(64), chunk=32)
    before = stats(ck.cache)["misses"]
    _, ck = predict_cached(ck, state, jnp.arange(64), chunk=32)
    assert stats(ck.cache)["misses"] == before   # fully resident: no misses


def test_nested_sampler_reuse_and_determinism():
    key = jax.random.PRNGKey(0)
    b1 = sample_batch_nested(key, 5, 512, 64, reuse=0.5, refresh=8)
    b1b = sample_batch_nested(key, 5, 512, 64, reuse=0.5, refresh=8)
    b2 = sample_batch_nested(key, 6, 512, 64, reuse=0.5, refresh=8)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b1b))
    assert b1.shape == (64,)
    assert int(jnp.min(b1)) >= 0 and int(jnp.max(b1)) < 512
    # staggered refresh: consecutive steps share all but ~m/refresh of the
    # reused prefix
    overlap = int(jnp.sum(b1[:32] == b2[:32]))
    assert overlap >= 32 - (32 // 8) - 1


def test_engine_share_eval_gram_equivalence():
    from repro.core.engine import fit_restarts
    from repro.data import blobs

    x, _ = blobs(n=256, d=8, k=4, seed=1)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(1.0))
    cfg = MBConfig(k=4, batch_size=32, tau=16, max_iters=6, epsilon=-1.0)
    r_on = fit_restarts(x, kern, cfg, jax.random.PRNGKey(2), restarts=3,
                        share_eval_gram=True)
    r_off = fit_restarts(x, kern, cfg, jax.random.PRNGKey(2), restarts=3,
                         share_eval_gram=False)
    np.testing.assert_allclose(np.asarray(r_on.objectives),
                               np.asarray(r_off.objectives), atol=1e-5)
    assert int(r_on.best) == int(r_off.best)


def test_cached_gather_pallas_matches_ref():
    from repro.kernels import ops, ref

    rng = np.random.default_rng(11)
    for b, n, k, w, bt, st in [(5, 40, 3, 7, 8, 8), (16, 64, 2, 16, 8, 16)]:
        rows = jnp.asarray(rng.normal(size=(b, n)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, n, (k, w)), jnp.int32)
        coef = jnp.asarray(rng.normal(size=(k, w)), jnp.float32)
        want = ref.cached_assign_dots(rows, ids, coef)
        got = ops.cached_assign_dots(rows, ids, coef, bt=bt, st=st,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5)


# --------------------------------------------- pipeline deterministic resume
@pytest.mark.parametrize("mode", ["iid", "nested"])
def test_pipeline_deterministic_resume(mode):
    """Same (seed, step) -> same batch after restart: a fresh pipeline
    instance reproduces the stream exactly from any step."""
    x = np.random.default_rng(0).normal(size=(128, 4))
    p1 = ClusterBatchPipeline(x, batch=16, seed=42, mode=mode)
    want = [p1(s) for s in range(12)]
    p2 = ClusterBatchPipeline(x, batch=16, seed=42, mode=mode)  # "restart"
    for s in (11, 3, 7, 0):
        np.testing.assert_array_equal(p2(s), want[s])
    it = iter(ClusterBatchPipeline(x, batch=16, seed=42, mode=mode))
    np.testing.assert_array_equal(next(it), want[0])
    np.testing.assert_array_equal(next(it), want[1])


def test_pipeline_nested_reuses_rows():
    x = np.random.default_rng(1).normal(size=(256, 4))
    p = ClusterBatchPipeline(x, batch=32, seed=0, mode="nested",
                             reuse=0.5, refresh=8)
    i5, i6 = p.batch_indices(5), p.batch_indices(6)
    assert np.sum(i5[:16] == i6[:16]) >= 16 - (16 // 8) - 1
    uniq = {tuple(p.batch_indices(s)) for s in range(6)}
    assert len(uniq) == 6    # tails still differ every step


# ------------------------------------------------------- distributed (slow)
def _run(script: str, ok_token: str, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert ok_token in r.stdout, r.stdout[-2000:]


DIST_CACHED = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import MBConfig, Gaussian
    from repro.core.distributed import (
        fit_distributed_jit, fit_distributed_cached_jit)
    from repro.cache import stats
    from repro.data import blobs

    assert len(jax.devices()) == 8, jax.devices()
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    x, _ = blobs(n=2048, d=16, k=8, seed=0)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(2.0))
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=6, epsilon=-1.0)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100

    st_u, it_u = fit_distributed_jit(x, x[init_idx], kern, cfg, mesh,
                                     jax.random.PRNGKey(7))
    st_c, caches, it_c = fit_distributed_cached_jit(
        x, init_idx, kern, cfg, mesh, jax.random.PRNGKey(7),
        tile=128, capacity=16)   # covers batch + window working set
    assert int(it_u) == int(it_c)
    np.testing.assert_allclose(np.asarray(st_u.sqnorm),
                               np.asarray(st_c.sqnorm), atol=1e-5)
    np.testing.assert_allclose(np.asarray(st_u.counts),
                               np.asarray(st_c.counts), atol=0)
    # per-shard caches: shard-local keys, real hits on every shard
    for s in range(4):
        st = stats(jax.tree.map(lambda a: a[s], caches))
        assert st["hits"] > 0 and st["misses"] >= 1, (s, st)
    print("DIST_CACHED_OK")
"""


@pytest.mark.slow
def test_distributed_cached_fit_equivalence():
    _run(DIST_CACHED, "DIST_CACHED_OK")
