"""Structural guard: the fit loop exists EXACTLY once (PR-9 tentpole).

Every registered solver must route through :mod:`repro.core.loop` — no
executor family (nor the core modules they compose) may own a
``lax.while_loop`` / ``fori_loop`` fit loop or a hand-rolled host driver.
The scan is AST-based, so docstrings and comments mentioning while_loop
don't trip it; a regression here means someone re-inlined a loop skeleton
that PRs 5 and 7 had to thread cross-cutting axes through seven times.
"""
import ast
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import KernelKMeans, SolverConfig
from repro.api.executors import Executor
from repro.api.plan import list_solvers
from repro.core import loop as loop_lib

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"

# Modules that compose the loop core and therefore must not re-own the
# loop skeleton.  core/loop.py is the single allowed home.
GUARDED = [
    "api/executors.py",
    "api/estimator.py",
    "api/legacy.py",
    "core/minibatch.py",
    "core/distributed.py",
    "core/engine.py",
]

BANNED_CALLS = {"while_loop", "fori_loop"}


def _loop_calls(path: pathlib.Path):
    """Names of banned loop-driver calls + hand-rolled while statements."""
    tree = ast.parse(path.read_text())
    hits = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            fn = node.func
            name = fn.attr if isinstance(fn, ast.Attribute) else \
                getattr(fn, "id", None)
            if name in BANNED_CALLS:
                hits.append(f"{name} at line {node.lineno}")
        elif isinstance(node, ast.While):
            hits.append(f"while-statement at line {node.lineno}")
    return hits


@pytest.mark.parametrize("rel", GUARDED)
def test_no_fit_loop_outside_the_loop_core(rel):
    hits = _loop_calls(SRC / rel)
    assert not hits, (f"{rel} owns a loop skeleton ({hits}); lower onto "
                      "repro.core.loop instead")


def test_loop_core_owns_the_while_loop():
    hits = _loop_calls(SRC / "core" / "loop.py")
    assert any("while_loop" in h for h in hits), (
        "core/loop.py no longer owns the lax.while_loop device driver")


def test_every_executor_family_declares_a_lowering():
    """Each concrete executor must describe how it lowers onto the loop
    core (LoopSpec) — the explain()/dry-run surface."""
    def concrete(cls):
        out = []
        for sub in cls.__subclasses__():
            if getattr(sub, "name", "?") != "?":
                out.append(sub)
            out.extend(concrete(sub))
        return out

    families = concrete(Executor)
    registered = set(list_solvers())
    covered = {cls.name for cls in families}
    assert registered <= covered, registered - covered
    for cls in families:
        assert cls.loop_spec is not Executor.loop_spec, (
            f"{cls.__name__} does not declare its LoopSpec lowering")


def _data(n=256, d=4, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))


@pytest.mark.parametrize("kw", [
    dict(cache="none", distribution="single", jit=False),   # host driver
    dict(cache="none", distribution="single", jit=True),    # device driver
    dict(cache="precomputed", distribution="single", jit=True),
])
def test_fits_run_through_the_loop_core(kw):
    """Fitting any plan bumps the loop core's run counter — the drivers
    in core/loop.py are actually on the execution path, not just
    imported.  (Device drivers count at trace time, so the program cache
    is cleared and a fresh executor used.)"""
    loop_lib.clear_program_cache()
    x = _data()
    est = KernelKMeans(SolverConfig(k=4, batch_size=32, tau=16,
                                    max_iters=3, epsilon=-1.0, **kw))
    before = loop_lib.loop_runs()
    est.fit(x, key=0)
    jax.block_until_ready(est.state_.sqnorm)
    assert loop_lib.loop_runs() > before, (
        f"plan {est.plan_.name!r} fit without entering the loop core")
