"""Always-on serving split (repro.service): ingest determinism, learner
crash-recovery bit-exactness, snapshot atomicity + staleness, actor
microbatching correctness, telemetry shape.

Everything here shares one tiny shape family (capacity 128, d 8, k 4,
b 32, tau 16) so the executor's cross-estimator program cache compiles
each program once for the whole module.  The 8-virtual-device recovery
test runs in a subprocess (slow lane), like test_distributed.py.
"""
import os
import subprocess
import sys
import textwrap
import threading
import time

import jax
import numpy as np
import pytest

from repro.service import (
    Actor, Backpressure, IngestBuffer, Learner, SnapshotStore,
    StaleSnapshot, telemetry)
from repro.service.demo import build_service, make_source

K, D, CAP, B, TAU = 4, 8, 128, 32, 16


def _svc(tmpdir, **kw):
    kw.setdefault("k", K)
    kw.setdefault("d", D)
    kw.setdefault("capacity", CAP)
    kw.setdefault("batch_size", B)
    kw.setdefault("tau", TAU)
    kw.setdefault("iters_per_round", 2)
    kw.setdefault("arrivals_per_step", 64)
    kw.setdefault("buckets", (64,))
    return build_service(str(tmpdir), **kw)


def _carry_leaves(carry):
    return [np.asarray(x) for x in jax.tree.leaves(carry)]


def _assert_carries_identical(a, b):
    la, lb = _carry_leaves(a), _carry_leaves(b)
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(xa, xb)


# ------------------------------------------------------------------ ingest
@pytest.mark.parametrize("mode", ["reservoir", "nested"])
def test_buffer_pure_in_seed_and_step(mode):
    """Content after t pushes is a pure function of (seed, t): two
    independently-driven buffers agree bit-exactly; a different seed
    does not."""
    src = make_source(D, K, 64, seed=3)
    a = IngestBuffer(CAP, D, seed=7, mode=mode)
    b = IngestBuffer(CAP, D, seed=7, mode=mode)
    c = IngestBuffer(CAP, D, seed=8, mode=mode)
    for t in range(6):
        a.push(src(t))
    b.replay_to(src, 6)
    c.replay_to(src, 6)
    np.testing.assert_array_equal(a.snapshot(), b.snapshot())
    assert not np.array_equal(a.snapshot(), c.snapshot())
    assert a.pushes == b.pushes == 6
    assert a.admitted == b.admitted and a.dropped == b.dropped


@pytest.mark.parametrize("mode", ["reservoir", "nested"])
def test_buffer_replay_rewinds(mode):
    """replay_to a PAST push count rebuilds from scratch (the crash
    recovery path) and lands on the identical content."""
    src = make_source(D, K, 64, seed=0)
    buf = IngestBuffer(CAP, D, seed=1, mode=mode)
    buf.replay_to(src, 4)
    want = buf.snapshot()
    buf.replay_to(src, 9)           # advance past...
    buf.replay_to(src, 4)           # ...then rewind
    np.testing.assert_array_equal(buf.snapshot(), want)


@pytest.mark.parametrize("mode", ["reservoir", "nested"])
def test_buffer_counters_and_full(mode):
    src = make_source(D, K, 64, seed=0)
    buf = IngestBuffer(CAP, D, seed=0, mode=mode)
    assert not buf.full
    n_fill = (CAP + 63) // 64 if mode == "reservoir" else 1
    for t in range(n_fill + 2):
        buf.push(src(t))
    assert buf.full
    assert buf.pushed == (n_fill + 2) * 64
    assert 0 <= buf.admitted <= buf.pushed
    assert buf.dropped == buf.pushed - buf.admitted
    stats = buf.stats()
    assert stats["mode"] == mode and stats["full"]


def test_buffer_rejects_bad_shapes():
    buf = IngestBuffer(CAP, D)
    with pytest.raises(ValueError):
        buf.push(np.zeros((4, D + 1), np.float32))
    with pytest.raises(ValueError):
        IngestBuffer(CAP, D, mode="fifo")


# ------------------------------------------------- learner crash recovery
def test_learner_crash_recovery_bit_identical(tmp_path):
    """A learner crashed mid-stream and restored from the last published
    snapshot converges to a FitCarry BIT-IDENTICAL to an uninterrupted
    run — buffer replay + carried fit key leave nothing to drift."""
    rounds, crash_at = 8, 5

    l_a, *_ = _svc(tmp_path / "a", publish_every=2)
    carry_a = l_a.run(rounds)

    l_b, *_ = _svc(tmp_path / "b", publish_every=2)
    armed = {"on": True}

    def boom(rnd):
        if rnd == crash_at and armed["on"]:
            armed["on"] = False
            raise RuntimeError("injected learner crash")

    l_b.on_round = boom
    carry_b = l_b.run(rounds)

    assert l_b.restores == 1
    assert l_a.rounds == l_b.rounds == rounds
    _assert_carries_identical(carry_a, carry_b)


def test_learner_publishes_resumable_snapshots(tmp_path):
    learner, _, store, buf, _ = _svc(tmp_path, publish_every=2)
    learner.run(5)           # publishes v2, v4, + final v5
    assert store.versions() == [2, 4, 5]
    v, est = store.load()
    assert v == 5
    labels = np.asarray(est.predict(buf.snapshot()))
    assert labels.shape == (CAP,) and set(labels) <= set(range(K))
    assert est.snapshot_carry() is not None       # resumable, not inert


# ------------------------------------------------------- snapshot store
def test_snapshot_never_torn(tmp_path):
    """Concurrent publishes + loads: every load sees a COMPLETE snapshot
    (write-temp-then-rename), never a partial file."""
    learner, _, store, _, _ = _svc(tmp_path)
    learner.run(1)
    est = learner.est

    stop = threading.Event()
    errors = []

    def publisher():
        v = 2
        while not stop.is_set():
            store.publish(est, v)
            v += 1

    def reader():
        while not stop.is_set():
            try:
                _, loaded = store.load()
                assert loaded.config.k == K
                assert loaded.snapshot_carry() is not None
            except Exception as e:      # noqa: BLE001 — collect, don't die
                errors.append(e)

    threads = [threading.Thread(target=publisher),
               threading.Thread(target=reader),
               threading.Thread(target=reader)]
    for t in threads:
        t.start()
    time.sleep(1.5)
    stop.set()
    for t in threads:
        t.join(10)
    assert not errors, errors[:3]
    assert store.publishes > 2
    # prune keeps disk bounded
    assert len(store.versions()) <= store.keep


def test_snapshot_staleness_bound(tmp_path):
    learner, _, store, _, _ = _svc(tmp_path)
    learner.run(1)
    store.publish(learner.est, 1)
    # generous bound: loads fine
    v, _ = store.load(max_age_s=60.0)
    assert v == 1
    # make the snapshot look old, then a tight bound must refuse it
    old = time.time() - 30.0
    os.utime(store.path_for(1), (old, old))
    with pytest.raises(StaleSnapshot):
        store.load(max_age_s=5.0)
    assert store.age_s(1) > 25.0


def test_actor_keeps_model_on_stale_snapshot(tmp_path):
    learner, _, store, _, _ = _svc(tmp_path)
    learner.run(1)
    actor = Actor(store, buckets=(64,), max_staleness_s=60.0)
    assert actor.try_swap(force=True)
    v0 = actor.version
    # a NEWER but too-old version must be refused, model kept, flagged
    store.publish(learner.est, v0 + 1)
    old = time.time() - 120.0
    os.utime(store.path_for(v0 + 1), (old, old))
    assert not actor.try_swap()
    assert actor.version == v0 and actor.stale
    # a fresh version clears the flag
    store.publish(learner.est, v0 + 2)
    assert actor.try_swap()
    assert actor.version == v0 + 2 and not actor.stale


# ------------------------------------------------------------------ actor
def test_actor_microbatch_matches_direct(tmp_path):
    """Ragged concurrent requests, coalesced and padded to buckets, must
    return exactly what a direct predict/transform on each block gives."""
    learner, actor, store, _, _ = _svc(tmp_path, max_wait_ms=5.0)
    learner.run(1)
    _, est = store.load()
    actor.start()
    try:
        rng = np.random.default_rng(5)
        blocks = [rng.normal(0, 1, (m, D)).astype(np.float32)
                  for m in (3, 17, 64, 1, 150)]
        reqs = [actor.submit(xb) for xb in blocks]
        for xb, req in zip(blocks, reqs):
            got = np.asarray(req.wait(60.0))
            np.testing.assert_array_equal(got, np.asarray(est.predict(xb)))
        d = np.asarray(actor.transform(blocks[1], timeout=60.0))
        np.testing.assert_allclose(
            d, np.asarray(est.transform(blocks[1])), rtol=1e-6)
        # steady state: compile counters flat from here on
        warm = actor.serve_compiles
        for xb in blocks:
            actor.predict(xb, timeout=60.0)
        assert actor.serve_compiles == warm
        assert actor.served == 2 * len(blocks) + 1
    finally:
        actor.stop()


def test_actor_backpressure(tmp_path):
    learner, _, store, _, _ = _svc(tmp_path)
    learner.run(1)
    actor = Actor(store, buckets=(64,), queue_depth=2)   # worker NOT started
    actor.try_swap(force=True)
    actor.submit(np.zeros((4, D), np.float32))
    actor.submit(np.zeros((4, D), np.float32))
    with pytest.raises(Backpressure):
        actor.submit(np.zeros((4, D), np.float32))
    assert actor.rejected == 1
    assert actor.queue_stats()["depth"] == 2


def test_actor_swap_is_atomic_under_load(tmp_path):
    """Serving never observes a half-loaded model: requests issued across
    repeated snapshot swaps all complete with valid labels."""
    learner, actor, store, _, _ = _svc(tmp_path)
    learner.run(1)
    actor.poll_every_s = 0.02
    actor.start()
    try:
        rng = np.random.default_rng(9)
        xq = rng.normal(0, 1, (64, D)).astype(np.float32)
        for v in range(2, 8):
            store.publish(learner.est, v)
            labels = np.asarray(actor.predict(xq, timeout=60.0))
            assert labels.shape == (64,) and set(labels) <= set(range(K))
        deadline = time.time() + 10
        while actor.swaps < 2 and time.time() < deadline:
            time.sleep(0.02)
        assert actor.swaps >= 2
        assert actor.last_swap_pause_ms is not None
    finally:
        actor.stop()


# -------------------------------------------------------------- telemetry
def test_telemetry_poll_shape(tmp_path):
    learner, actor, store, buf, _ = _svc(tmp_path)
    learner.run(1)
    actor.try_swap(force=True)
    t = telemetry.poll(buffer=buf, learner=learner, actor=actor)
    assert t["programs"]["fit_builds"] >= 1
    assert t["programs"]["serve_compiles"] == actor.serve_compiles
    assert t["ingest"]["pushes"] == buf.pushes
    assert t["learner"]["rounds"] == 1
    assert t["snapshot"]["version"] == actor.version
    assert t["queue"]["capacity"] == actor._queue.maxsize
    assert t["latency_ms"]["count"] == 0 and t["latency_ms"]["p99"] is None
    assert t["cache"] is None
    # the serving-cost gauge is reported even with compress="off"
    sup = t["support"]
    assert sup is not None
    assert sup["rows"] == sup["k"] * sup["window"]
    assert 0 < sup["active"] <= sup["rows"]
    assert sup["compressions"] == 0
    line = telemetry.format_line(t)
    assert line.startswith("svc | ") and "builds fit=" in line
    assert "support rows=" in line


def test_telemetry_without_actor_sections_none():
    t = telemetry.poll()
    assert t["queue"] is None and t["snapshot"] is None
    assert t["programs"]["serve_compiles"] is None
    assert isinstance(t["programs"]["fit_builds"], int)


# ----------------------------------------------- compressed serving path
def test_compressed_snapshots_swap_without_recompiles(tmp_path):
    """With the compress axis on, every published snapshot serves at the
    same (k*m) shape, so snapshot swaps after the first warmup trace
    nothing new — the landmark extension of the zero-recompile gate."""
    M = 8
    learner, actor, store, buf, _ = _svc(tmp_path, compress={"m": M},
                                         publish_every=1)
    learner.run(4)
    sup = learner.est.support_stats()
    assert sup["rows"] == K * M and sup["compressions"] == 4
    assert sup["window"] == M           # the serving window is now m
    assert sup["ratio"] == pytest.approx(M / (B + TAU))
    assert actor.try_swap(force=True)
    assert actor.support_stats()["rows"] == K * M
    warm = actor.serve_compiles
    assert warm > 0
    v0 = actor.version
    # further compressed snapshots: swaps re-warm at the SAME (k*m)
    # serving shapes, so the compile counter must not move
    for j in range(2):
        store.publish(learner.est, v0 + j + 1)
        assert actor.try_swap()
    assert actor.version == v0 + 2
    assert actor.serve_compiles == warm
    # the actor's padded predict serves from the compressed model
    actor.start()
    try:
        labels = actor.predict(buf.snapshot()[:40])
        assert np.asarray(labels).shape == (40,)
    finally:
        actor.stop()
    assert actor.serve_compiles == warm


def test_uncompressed_service_unchanged_by_compress_axis(tmp_path):
    """compress='off' (the default) publishes the full-window serving
    tuple exactly as before the axis existed."""
    learner, actor, store, buf, _ = _svc(tmp_path)
    learner.run(1)
    sup = learner.est.support_stats()
    assert sup["compressions"] == 0 and sup["m"] is None
    assert sup["rows"] == K * sup["window"]


# -------------------------------------------- serve.py snapshot round-trip
def test_save_atomic_snapshot_roundtrip(tmp_path):
    """The --save-snapshot / --snapshot serve path: save_atomic never
    leaves a temp file behind and the loaded estimator serves
    identically."""
    from repro.api import KernelKMeans

    learner, _, _, buf, _ = _svc(tmp_path / "svc")
    learner.run(1)
    est = learner.est
    path = str(tmp_path / "model.npz")
    est.save_atomic(path)
    assert os.path.exists(path)
    assert [f for f in os.listdir(tmp_path) if ".tmp." in f] == []
    loaded = KernelKMeans.load(path)
    xq = buf.snapshot()[:50]
    np.testing.assert_array_equal(np.asarray(est.predict(xq)),
                                  np.asarray(loaded.predict(xq)))


# ------------------------------------------------- 8 virtual devices (slow)
def _run(script: str, ok_token: str, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                       env=env, capture_output=True, text=True,
                       timeout=timeout,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    assert ok_token in r.stdout, r.stdout[-2000:]
    return r.stdout


RESILIENT_8DEV = """
    import tempfile
    import jax, numpy as np
    from repro.service.demo import build_service

    assert len(jax.devices()) == 8, jax.devices()

    def run(crash_at):
        with tempfile.TemporaryDirectory() as d:
            learner, _, store, _, _ = build_service(
                d, k=4, d=8, capacity=128, batch_size=32, tau=16,
                iters_per_round=2, publish_every=2, arrivals_per_step=64)
            if crash_at is not None:
                armed = [True]
                def boom(rnd):
                    if rnd == crash_at and armed[0]:
                        armed[0] = False
                        raise RuntimeError("injected crash")
                learner.on_round = boom
            carry = learner.run(8)
            return carry, learner.restores

    a, r_a = run(None)
    b, r_b = run(5)
    assert r_a == 0 and r_b == 1, (r_a, r_b)
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    print("SERVICE-RESILIENT-OK")
"""


@pytest.mark.slow
def test_learner_recovery_bit_identical_8dev():
    """The determinism contract holds under 8 virtual devices: a crashed
    + restored learner's FitCarry is bit-identical to an uninterrupted
    run's."""
    _run(RESILIENT_8DEV, "SERVICE-RESILIENT-OK")


RESILIENT_COMPRESSED_8DEV = """
    import tempfile
    import jax, numpy as np
    from repro.service.demo import build_service

    assert len(jax.devices()) == 8, jax.devices()

    def run(crash_at):
        with tempfile.TemporaryDirectory() as d:
            learner, _, store, _, _ = build_service(
                d, k=4, d=8, capacity=128, batch_size=32, tau=16,
                iters_per_round=2, publish_every=2, arrivals_per_step=64,
                compress={"m": 8})
            if crash_at is not None:
                armed = [True]
                def boom(rnd):
                    if rnd == crash_at and armed[0]:
                        armed[0] = False
                        raise RuntimeError("injected crash")
                learner.on_round = boom
            carry = learner.run(8)
            _, sup, coef, sq = learner.est._serving
            return (carry, learner.restores,
                    tuple(np.asarray(a) for a in (sup, coef, sq)))

    a, r_a, s_a = run(None)
    b, r_b, s_b = run(5)
    assert r_a == 0 and r_b == 1, (r_a, r_b)
    for xa, xb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))
    # the published COMPRESSED serving model is bit-identical too: the
    # landmark selection is keyed by the carried step counter
    assert s_a[1].shape == (4, 8), s_a[1].shape
    for xa, xb in zip(s_a, s_b):
        np.testing.assert_array_equal(xa, xb)
    print("SERVICE-COMPRESSED-RESILIENT-OK")
"""


@pytest.mark.slow
def test_compressed_learner_recovery_bit_identical_8dev():
    """Crash recovery through run_resilient restores a COMPRESSED learner
    bit-identically: same carry AND same published landmark serving
    model (selection is keyed by the carried step counter)."""
    _run(RESILIENT_COMPRESSED_8DEV, "SERVICE-COMPRESSED-RESILIENT-OK")
