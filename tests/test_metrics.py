import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite degrades, not errors
from hypothesis import given, settings, strategies as st

from repro.core.metrics import adjusted_rand_index, normalized_mutual_info


def test_perfect_match():
    y = np.array([0, 0, 1, 1, 2, 2])
    assert adjusted_rand_index(y, y) == pytest.approx(1.0)
    assert normalized_mutual_info(y, y) == pytest.approx(1.0)


def test_permutation_invariant():
    y = np.array([0, 0, 1, 1, 2, 2])
    p = np.array([2, 2, 0, 0, 1, 1])  # same clustering, relabeled
    assert adjusted_rand_index(y, p) == pytest.approx(1.0)
    assert normalized_mutual_info(y, p) == pytest.approx(1.0)


def test_known_ari_value():
    # hand-checked example (matches sklearn.adjusted_rand_score)
    a = np.array([0, 0, 1, 1])
    b = np.array([0, 0, 1, 2])
    assert adjusted_rand_index(a, b) == pytest.approx(0.5714285714, rel=1e-6)


def test_random_labels_near_zero():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 5, 5000)
    b = rng.integers(0, 5, 5000)
    assert abs(adjusted_rand_index(a, b)) < 0.02
    assert normalized_mutual_info(a, b) < 0.02


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 6), st.integers(10, 200), st.integers(0, 2 ** 16))
def test_ari_bounds_property(k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, k, n)
    b = rng.integers(0, k, n)
    ari = adjusted_rand_index(a, b)
    nmi = normalized_mutual_info(a, b)
    assert -1.0 <= ari <= 1.0
    assert 0.0 <= nmi <= 1.0 + 1e-9
