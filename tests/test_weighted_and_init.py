"""Weighted points (paper footnote 1) + subsampled k-means++ coverage."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Gaussian, MBConfig, adjusted_rand_index, fit, predict
from repro.core.init import kmeans_plus_plus_subsampled
from repro.core.minibatch import sample_batch_weighted
from repro.data import blobs

GAUSS = Gaussian(kappa=jnp.float32(1.0))


def test_weighted_sampling_follows_weights():
    probs = jnp.asarray([0.7, 0.1, 0.1, 0.1])
    idx = sample_batch_weighted(jax.random.PRNGKey(0), probs, 4000)
    frac0 = float(jnp.mean((idx == 0).astype(jnp.float32)))
    assert abs(frac0 - 0.7) < 0.05


def test_weighted_fit_prioritizes_heavy_region():
    """Two far blobs, k=1: with weight ~100x on blob B, the single center
    must land in B (the weighted objective says so)."""
    rng = np.random.default_rng(0)
    a = rng.normal(size=(300, 4)).astype(np.float32) * 0.1
    b = (rng.normal(size=(300, 4)) * 0.1 + 5.0).astype(np.float32)
    x = jnp.asarray(np.concatenate([a, b]))
    w = np.ones(600, np.float32)
    w[300:] = 100.0
    cfg = MBConfig(k=1, batch_size=64, tau=64, max_iters=30, epsilon=-1.0)
    state, _ = fit(x, GAUSS, cfg, jax.random.PRNGKey(1),
                   weights=jnp.asarray(w), init="random")
    # center support must be dominated by points from blob B (idx >= 300)
    sup = np.asarray(state.idx[0])
    coef = np.asarray(state.coef[0])
    heavy_mass = coef[sup >= 300].sum() / max(coef.sum(), 1e-9)
    assert heavy_mass > 0.9


def test_weighted_uniform_equals_quality_of_unweighted():
    x, y = blobs(n=1200, d=8, k=4, seed=0)
    x = jnp.asarray(x)
    cfg = MBConfig(k=4, batch_size=128, tau=128, max_iters=40,
                   epsilon=-1.0)
    sw, _ = fit(x, GAUSS, cfg, jax.random.PRNGKey(2),
                weights=jnp.ones((1200,)))
    ari = adjusted_rand_index(y, np.asarray(predict(sw, x, x, GAUSS)))
    assert ari > 0.5


def test_kmeanspp_subsampled():
    x, _ = blobs(n=2000, d=8, k=6, seed=1)
    x = jnp.asarray(x)
    idx = kmeans_plus_plus_subsampled(jax.random.PRNGKey(0), x, 6, GAUSS,
                                      m=256)
    assert idx.shape == (6,)
    assert len(set(np.asarray(idx).tolist())) == 6
    assert int(jnp.max(idx)) < 2000
