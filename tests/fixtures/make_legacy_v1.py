"""One-shot generator for ``legacy_snapshot_v1.npz`` — a PINNED
pre-compressed-format (format 1) estimator snapshot for the version-skew
test (tests/test_save_load_skew.py).

Format-1 files have no ``format`` / ``compress`` meta keys and no
``compress`` config field; this script saves a fitted estimator with the
current code and strips the format-2 additions back out, exactly
reproducing what a pre-landmark build wrote.  The fixture also embeds a
query block and its expected labels (``fixture_*`` arrays, ignored by
``KernelKMeans.load``) so the test pins serving behavior, not just
loadability.

Run from the repo root (writes next to this file):

    PYTHONPATH=src python tests/fixtures/make_legacy_v1.py
"""
import json
import os
import tempfile

import jax
import numpy as np

from repro.api import KernelKMeans, SolverConfig
from repro.data import blobs

HERE = os.path.dirname(os.path.abspath(__file__))
OUT = os.path.join(HERE, "legacy_snapshot_v1.npz")


def main() -> None:
    cfg = SolverConfig(k=4, batch_size=32, tau=16, max_iters=6,
                       epsilon=-1.0, early_stop=False, kernel="rbf",
                       kernel_params={"kappa": 1.0}, cache="none",
                       distribution="single", jit=True)
    x, _ = blobs(n=512, d=8, k=4, seed=0)
    x = np.asarray(x, np.float32)
    est = KernelKMeans(cfg).fit(x, jax.random.PRNGKey(0))

    with tempfile.TemporaryDirectory() as tmp:
        p = os.path.join(tmp, "v2.npz")
        est.save(p)
        with np.load(p) as data:
            arrays = {k: np.asarray(data[k]) for k in data.files}

    meta = json.loads(bytes(arrays.pop("meta")).decode())
    assert meta.pop("format") == 2
    meta.pop("compress")
    meta["config"].pop("compress")
    arrays["meta"] = np.frombuffer(json.dumps(meta).encode(), np.uint8)

    xq = x[:64]
    arrays["fixture_xq"] = xq
    arrays["fixture_labels"] = np.asarray(est.predict(xq))
    with open(OUT, "wb") as f:
        np.savez(f, **arrays)
    print(f"wrote {OUT} ({os.path.getsize(OUT)} bytes)")


if __name__ == "__main__":
    main()
