"""Multi-restart engine + single-device shard_map foundation.

Everything here runs in the main pytest process on the single real CPU
device (a 1-device mesh exercises the full shard_map machinery — specs,
collectives over size-1 axes, compat shim); the 8-virtual-device variants
live in test_distributed.py subprocesses.  No hypothesis dependency: these
parametrized sweeps are the always-on fast lane of the invariant coverage.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Gaussian, MBConfig, MultiRestartEngine, batch_objective, fit, fit_jit,
    fit_restarts, init_state, make_step, predict, window_size,
)
from repro.core.distributed import (
    fit_distributed_jit, init_dist_state, make_dist_step,
    predict_distributed, state_shardings,
)
from repro.core.engine import make_restart_run
from repro.core.minibatch import sample_batch
from repro.data import blobs

GAUSS = Gaussian(kappa=jnp.float32(2.0))


def _blobs(n=1024, d=16, k=8, seed=0):
    x, _ = blobs(n=n, d=d, k=k, seed=seed)
    return jnp.asarray(x)


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


# ------------------------------------------------- shard_map on one device
def test_single_device_shardmap_step_matches_make_step():
    """compat.shard_map on a (1,1) mesh == the plain single-device step,
    trajectory-for-trajectory."""
    x = _blobs()
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=8, epsilon=-1.0)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100
    w = window_size(cfg.batch_size, cfg.tau)
    mesh = _mesh1()

    st = init_state(x, init_idx, GAUSS, w)
    step1 = jax.jit(make_step(GAUSS, cfg))
    dst = jax.device_put(init_dist_state(x[init_idx], GAUSS, w),
                         state_shardings(mesh))
    stepd = jax.jit(make_dist_step(GAUSS, cfg, mesh))

    key = jax.random.PRNGKey(7)
    for i in range(5):
        key, kb = jax.random.split(key)
        bidx = sample_batch(kb, x.shape[0], cfg.batch_size)
        st, i1 = step1(st, x, bidx)
        dst, i2 = stepd(dst, x[bidx])
        assert float(i1.f_before) == pytest.approx(float(i2.f_before),
                                                   abs=1e-5), i
        assert float(i1.f_after) == pytest.approx(float(i2.f_after),
                                                  abs=1e-5), i
    np.testing.assert_allclose(np.asarray(st.sqnorm), np.asarray(dst.sqnorm),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(st.counts), np.asarray(dst.counts),
                               atol=0)


def test_single_device_shardmap_fit_matches_fit_jit():
    """Driving the 1-device-mesh dist step with fit_jit's exact PRNG stream
    reproduces fit_jit's final state."""
    x = _blobs(n=800)
    cfg = MBConfig(k=4, batch_size=64, tau=32, max_iters=10, epsilon=-1.0)
    init_idx = jnp.array([0, 100, 200, 300], jnp.int32)
    w = window_size(cfg.batch_size, cfg.tau)
    mesh = _mesh1()

    st_jit, iters = fit_jit(x, GAUSS, cfg, jax.random.PRNGKey(11), init_idx)
    assert int(iters) == cfg.max_iters

    dst = jax.device_put(init_dist_state(x[init_idx], GAUSS, w),
                         state_shardings(mesh))
    stepd = jax.jit(make_dist_step(GAUSS, cfg, mesh))
    key = jax.random.PRNGKey(11)
    for _ in range(cfg.max_iters):
        key, kb = jax.random.split(key)
        bidx = sample_batch(kb, x.shape[0], cfg.batch_size)
        dst, _ = stepd(dst, x[bidx])
    np.testing.assert_allclose(np.asarray(st_jit.sqnorm),
                               np.asarray(dst.sqnorm), atol=1e-5)


def test_fit_distributed_jit_single_device_runs_and_improves():
    x = _blobs()
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=15, epsilon=-1.0)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100
    mesh = _mesh1()
    dst, iters = fit_distributed_jit(x, x[init_idx], GAUSS, cfg, mesh,
                                     jax.random.PRNGKey(3))
    assert int(iters) == cfg.max_iters
    assert bool(jnp.all(jnp.isfinite(dst.sqnorm)))
    assert float(jnp.sum(dst.counts)) == cfg.batch_size * cfg.max_iters


# --------------------------------------------------------------- the engine
def test_engine_selects_argmin_restart():
    x = _blobs()
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=10, epsilon=-1.0)
    res = fit_restarts(x, GAUSS, cfg, jax.random.PRNGKey(0), restarts=3)
    assert res.objectives.shape == (3,)
    assert int(res.best) == int(jnp.argmin(res.objectives))
    assert float(res.objective) == pytest.approx(
        float(jnp.min(res.objectives)))
    assert res.state.idx.shape == (8, window_size(128, 64))
    # all restarts ran to the (no-early-stop) limit
    np.testing.assert_array_equal(np.asarray(res.iters), 10)


def test_engine_deterministic_and_cached_program_consistent():
    x = _blobs(n=512, d=8, k=4)
    cfg = MBConfig(k=4, batch_size=64, tau=32, max_iters=8, epsilon=-1.0)
    eng = MultiRestartEngine(GAUSS, cfg, restarts=2)
    r1 = eng.fit(x, jax.random.PRNGKey(5))
    r2 = eng.fit(x, jax.random.PRNGKey(5))  # second call: cached program
    np.testing.assert_allclose(np.asarray(r1.objectives),
                               np.asarray(r2.objectives), atol=0)
    run = make_restart_run(GAUSS, cfg)
    r3 = fit_restarts(x, GAUSS, cfg, jax.random.PRNGKey(5), restarts=2,
                      _run=run)
    np.testing.assert_allclose(np.asarray(r1.objectives),
                               np.asarray(r3.objectives), atol=1e-7)


def test_engine_restart_quality_monotone_vs_single():
    """Best-of-R can only improve on the mean single restart (same cfg)."""
    x = _blobs(n=2000, seed=3)
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=25, epsilon=-1.0)
    res = fit_restarts(x, GAUSS, cfg, jax.random.PRNGKey(1), restarts=4)
    assert float(res.objective) <= float(jnp.mean(res.objectives)) + 1e-7


def test_engine_early_stop_per_restart():
    """epsilon > 0: restarts terminate independently inside the vmapped
    while_loop (iters may differ per lane, all <= max_iters)."""
    x = _blobs(n=2000)
    cfg = MBConfig(k=8, batch_size=512, tau=128, max_iters=200, epsilon=0.01)
    res = fit_restarts(x, Gaussian(kappa=jnp.float32(1.0)), cfg,
                       jax.random.PRNGKey(2), restarts=3)
    iters = np.asarray(res.iters)
    assert (iters < 200).all()
    assert (iters >= 1).all()


def test_engine_random_init_and_explicit_init_idx():
    x = _blobs(n=512, d=8, k=4)
    cfg = MBConfig(k=4, batch_size=64, tau=32, max_iters=5, epsilon=-1.0)
    r_rand = fit_restarts(x, GAUSS, cfg, jax.random.PRNGKey(0), restarts=2,
                          init="random")
    assert np.isfinite(float(r_rand.objective))
    init_idx = jnp.stack([jnp.arange(4), jnp.arange(4) * 100]).astype(
        jnp.int32)
    r_exp = fit_restarts(x, GAUSS, cfg, jax.random.PRNGKey(0), restarts=2,
                         init_idx=init_idx)
    assert np.isfinite(float(r_exp.objective))
    with pytest.raises(ValueError):
        fit_restarts(x, GAUSS, cfg, jax.random.PRNGKey(0), restarts=3,
                     init_idx=init_idx)


def test_engine_predict_matches_minibatch_predict():
    x = _blobs()
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=10, epsilon=-1.0)
    eng = MultiRestartEngine(GAUSS, cfg, restarts=2)
    res = eng.fit(x, jax.random.PRNGKey(0))
    p_eng = eng.predict(x[:200])
    p_ref = predict(res.state, x, x[:200], GAUSS)
    np.testing.assert_array_equal(np.asarray(p_eng), np.asarray(p_ref))


def test_predict_distributed_single_device_matches_predict():
    """Sharded serving on a 1-device mesh == plain predict, including the
    non-divisible padding path."""
    x = _blobs()
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=8, epsilon=-1.0)
    state, _ = fit(x, GAUSS, cfg, jax.random.PRNGKey(0), early_stop=False)
    mesh = _mesh1()
    for nq in (64, 777):
        got = predict_distributed(state, x, x[:nq], GAUSS, mesh)
        want = predict(state, x, x[:nq], GAUSS)
        assert got.shape == (nq,)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batch_objective_matches_step_f_before():
    x = _blobs()
    cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=3, epsilon=-1.0)
    init_idx = jnp.arange(8, dtype=jnp.int32) * 100
    state = init_state(x, init_idx, GAUSS, window_size(128, 64))
    step = jax.jit(make_step(GAUSS, cfg))
    bidx = sample_batch(jax.random.PRNGKey(1), x.shape[0], 128)
    _, info = step(state, x, bidx)
    obj = batch_objective(GAUSS, state, x, bidx)
    assert float(obj) == pytest.approx(float(info.f_before), abs=1e-6)


# ---------------------------------------- mode invariants, hypothesis-free
@pytest.mark.parametrize("b,tau", [(32, 16), (96, 48), (64, 128)])
def test_sqnorm_incremental_matches_recompute_sweep(b, tau):
    x = _blobs(n=384, d=8, k=3, seed=1)
    base = MBConfig(k=3, batch_size=b, tau=tau, max_iters=8, epsilon=-1.0)
    init_idx = jnp.array([0, 50, 100], jnp.int32)
    s_rec, _ = fit(x, GAUSS, base, jax.random.PRNGKey(2), init_idx=init_idx,
                   early_stop=False)
    s_inc, _ = fit(x, GAUSS, base._replace(sqnorm_mode="incremental"),
                   jax.random.PRNGKey(2), init_idx=init_idx,
                   early_stop=False)
    np.testing.assert_allclose(np.asarray(s_inc.sqnorm),
                               np.asarray(s_rec.sqnorm), atol=3e-4)


@pytest.mark.parametrize("b,tau", [(32, 16), (96, 48), (64, 128)])
def test_eval_delta_matches_direct_sweep(b, tau):
    x = _blobs(n=384, d=8, k=3, seed=1)
    base = MBConfig(k=3, batch_size=b, tau=tau, max_iters=8, epsilon=-1.0)
    init_idx = jnp.array([0, 50, 100], jnp.int32)
    _, h_dir = fit(x, GAUSS, base, jax.random.PRNGKey(2), init_idx=init_idx,
                   early_stop=False)
    _, h_del = fit(x, GAUSS, base._replace(eval_mode="delta"),
                   jax.random.PRNGKey(2), init_idx=init_idx,
                   early_stop=False)
    for a, c in zip(h_del, h_dir):
        assert a["f_after"] == pytest.approx(c["f_after"], abs=3e-4)


@pytest.mark.parametrize("b,k,w,d,bt,st", [
    (27, 3, 37, 11, 8, 8),
    (16, 2, 24, 8, 128, 128),   # tiles larger than the problem: clamped
    (64, 4, 48, 16, 16, 32),
])
def test_ops_tile_clamp_matches_reference(b, k, w, d, bt, st):
    """ops.fused_batch_center_dots with clamped per-shard tiles == einsum."""
    from repro.core.minibatch import _batch_center_dots
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(200, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 200, (k, w)), jnp.int32)
    coef = jnp.abs(jnp.asarray(rng.normal(size=(k, w)), jnp.float32)) / w
    xb = x[:b]
    want = _batch_center_dots(GAUSS, xb, x, idx, coef, use_pallas=False)
    got = ops.fused_batch_center_dots(GAUSS, xb, x[idx.reshape(-1)], coef,
                                      bt=bt, st=st, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
