"""Hypothesis property tests for the Gram tile cache — cached vs direct
cross-kernel equivalence across kernels / tile sizes / capacities, plus the
LRU structural invariants.  Separate module so the importorskip degrades
only these (test_cache.py stays hypothesis-free, like test_engine.py)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite degrades, not errors
from hypothesis import given, settings, strategies as st

from repro.cache import cross_update, make_cached, stats
from repro.core.kernel_fns import (
    Gaussian, Laplacian, Linear, Polynomial, kernel_cross,
)

KERNELS = [
    Gaussian(kappa=jnp.float32(1.7)),
    Laplacian(kappa=jnp.float32(2.3)),
    Polynomial(bias=jnp.float32(1.0), scale=jnp.float32(4.0), degree=2),
    Linear(),
]


def _data(n, d, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)),
                       jnp.float32)

@settings(max_examples=15, deadline=None)
@given(st.integers(0, 3), st.sampled_from([4, 8, 16]),
       st.integers(1, 6), st.integers(1, 40), st.integers(0, 2 ** 31 - 1))
def test_cached_cross_equivalence_property(kidx, tile, capacity, m, seed):
    kern = KERNELS[kidx]
    n = 48
    x = _data(n, 4, seed=seed % 7)
    ck, xi = make_cached(kern, x, tile=tile, capacity=capacity)
    rng = np.random.default_rng(seed)
    ridx = jnp.asarray(rng.integers(0, n, m), jnp.int32)
    cidx = jnp.asarray(rng.integers(0, n, max(m // 2, 1)), jnp.int32)
    got, ck = cross_update(ck, xi[ridx], xi[cidx])
    want = kernel_cross(kern, x[ridx], x[cidx])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    # LRU invariants: resident keys unique + within capacity + valid ids
    keys = np.asarray(ck.cache.keys)
    resident = keys[keys >= 0]
    assert len(resident) <= capacity
    assert len(set(resident.tolist())) == len(resident)
    assert (resident < n // tile).all()
    s = stats(ck.cache)
    assert s["hits"] + s["misses"] >= 1
