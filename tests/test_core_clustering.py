"""Integration + property tests for the paper's algorithms (repro.core)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite degrades, not errors
from hypothesis import given, settings, strategies as st

from repro.core import (
    Gaussian, Linear, MBConfig, Polynomial, adjusted_rand_index, fit,
    fit_jit, gamma_of, init_state, make_step, predict, sample_batch,
    window_size,
)
from repro.core import fullbatch, lloyd, untruncated
from repro.core.init import kmeans_plus_plus
from repro.data import blobs, circles, moons
from repro.data.graph_kernels import heat_kernel, knn_kernel

KEY = jax.random.PRNGKey(0)
GAUSS = Gaussian(kappa=jnp.float32(2.0))


def _blobs(n=1024, d=16, k=8, seed=0):
    x, y = blobs(n=n, d=d, k=k, seed=seed)
    return jnp.asarray(x), y


# ---------------------------------------------------------------- exactness
def test_truncated_equals_untruncated_before_eviction():
    """While the ring never evicts, Algorithm 2 == Algorithm 1 exactly."""
    x, _ = _blobs()
    cfg = MBConfig(k=4, batch_size=64, tau=64 * 12, max_iters=10,
                   epsilon=-1.0)
    init_idx = jnp.array([1, 100, 200, 300], jnp.int32)
    s2, h2 = fit(x, GAUSS, cfg, KEY, init_idx=init_idx, early_stop=False)
    s1, h1 = untruncated.fit(x, GAUSS, cfg, KEY, init_idx=init_idx,
                             early_stop=False)
    for a, b in zip(h2, h1):
        assert a["f_before"] == pytest.approx(b["f_before"], abs=1e-5)
        assert a["f_after"] == pytest.approx(b["f_after"], abs=1e-5)
    np.testing.assert_allclose(s2.sqnorm, s1.sqnorm, atol=1e-5)


def test_incremental_sqnorm_matches_recompute():
    x, _ = _blobs()
    init_idx = jnp.array([1, 100, 200, 300], jnp.int32)
    base = MBConfig(k=4, batch_size=96, tau=48, max_iters=25, epsilon=-1.0)
    s_rec, h_rec = fit(x, GAUSS, base, KEY, init_idx=init_idx,
                       early_stop=False)
    s_inc, h_inc = fit(
        x, GAUSS, base._replace(sqnorm_mode="incremental", eval_mode="delta"),
        KEY, init_idx=init_idx, early_stop=False)
    np.testing.assert_allclose(s_inc.sqnorm, s_rec.sqnorm, atol=2e-4)
    for a, b in zip(h_inc, h_rec):
        assert a["f_after"] == pytest.approx(b["f_after"], abs=2e-4)


# ---------------------------------------------------------------- quality
def test_quality_blobs_gaussian():
    x, y = _blobs(n=2000, d=16, k=8)
    cfg = MBConfig(k=8, batch_size=256, tau=256, max_iters=80, epsilon=-1.0)
    st_, _ = fit(x, Gaussian(kappa=jnp.float32(1.0)), cfg,
                 jax.random.PRNGKey(1), early_stop=False)
    pred = predict(st_, x, x, Gaussian(kappa=jnp.float32(1.0)))
    assert adjusted_rand_index(y, np.asarray(pred)) > 0.55


def test_kernel_beats_plain_kmeans_on_circles():
    """The paper's motivation: non-linearly-separable data."""
    x, y = circles(n=1000, seed=0)
    kern, xi = heat_kernel(x, k=10, t=2000.0)
    xi = jnp.asarray(xi)
    kern = jax.tree.map(jnp.asarray, kern)
    cfg = MBConfig(k=2, batch_size=256, tau=256, max_iters=80, epsilon=-1.0)
    st_, _ = fit(xi, kern, cfg, jax.random.PRNGKey(1), early_stop=False)
    ari_kernel = adjusted_rand_index(
        y, np.asarray(predict(st_, xi, xi, kern)))
    _, assign, _ = lloyd.kmeans_fit(jnp.asarray(x), 2, jax.random.PRNGKey(1))
    ari_plain = adjusted_rand_index(y, np.asarray(assign))
    assert ari_kernel > 0.9
    assert ari_plain < 0.3
    assert ari_kernel > ari_plain + 0.5


def test_moons_heat_kernel():
    x, y = moons(n=1000, seed=0)
    kern, xi = heat_kernel(x, k=10, t=2000.0)
    xi = jnp.asarray(xi)
    kern = jax.tree.map(jnp.asarray, kern)
    cfg = MBConfig(k=2, batch_size=256, tau=200, max_iters=80, epsilon=-1.0)
    st_, _ = fit(xi, kern, cfg, jax.random.PRNGKey(2), early_stop=False)
    assert adjusted_rand_index(
        y, np.asarray(predict(st_, xi, xi, kern))) > 0.9


def test_gamma_table_matches_paper_scales():
    """Paper Table 1: gamma = 1 for gaussian; gamma << 1 for knn/heat."""
    x, _ = circles(n=600, seed=0)
    assert float(gamma_of(GAUSS, jnp.asarray(x))) == pytest.approx(1.0)
    kk, xi = knn_kernel(x, k=10)
    g_knn = float(gamma_of(jax.tree.map(jnp.asarray, kk), jnp.asarray(xi)))
    kh, xih = heat_kernel(x, k=10, t=2000.0)
    g_heat = float(gamma_of(jax.tree.map(jnp.asarray, kh), jnp.asarray(xih)))
    assert g_knn < 0.5
    assert g_heat < 0.5


# ------------------------------------------------------------- termination
def test_early_stopping_terminates_quickly():
    """Theorem 1(2): with gamma=1 and moderate eps, few iterations."""
    x, _ = _blobs(n=2000)
    cfg = MBConfig(k=8, batch_size=512, tau=256, max_iters=200, epsilon=0.01)
    _, hist = fit(x, Gaussian(kappa=jnp.float32(1.0)), cfg,
                  jax.random.PRNGKey(3))
    assert len(hist) < 100  # far below max_iters; O(gamma^2/eps) regime
    assert hist[-1]["improvement"] < cfg.epsilon


def test_fit_jit_matches_host_loop_iterations():
    x, _ = _blobs(n=1000)
    cfg = MBConfig(k=4, batch_size=256, tau=128, max_iters=50, epsilon=0.005)
    init_idx = jnp.array([0, 10, 20, 30], jnp.int32)
    _, hist = fit(x, GAUSS, cfg, jax.random.PRNGKey(5), init_idx=init_idx)
    _, iters = fit_jit(x, GAUSS, cfg, jax.random.PRNGKey(5), init_idx)
    # identical PRNG stream -> identical termination step
    assert int(iters) == len(hist)


# --------------------------------------------------------------- learning rates
@pytest.mark.parametrize("rate", ["beta", "sklearn"])
def test_rates_run_and_improve(rate):
    x, _ = _blobs(n=1500)
    cfg = MBConfig(k=8, batch_size=256, tau=128, max_iters=40, epsilon=-1.0,
                   rate=rate)
    _, hist = fit(x, Gaussian(kappa=jnp.float32(1.0)), cfg,
                  jax.random.PRNGKey(4), early_stop=False)
    assert hist[-1]["f_before"] < hist[0]["f_before"]


# ------------------------------------------------------------------ k-means++
def test_kmeanspp_deterministic_and_distinct():
    x, _ = _blobs(n=800, k=8)
    idx1 = kmeans_plus_plus(jax.random.PRNGKey(9), x, 8, GAUSS)
    idx2 = kmeans_plus_plus(jax.random.PRNGKey(9), x, 8, GAUSS)
    np.testing.assert_array_equal(idx1, idx2)
    assert len(set(np.asarray(idx1).tolist())) == 8


def test_kmeanspp_better_than_random_init():
    x, y = _blobs(n=2000, d=16, k=8, seed=3)
    kern = Gaussian(kappa=jnp.float32(1.0))
    cfg = MBConfig(k=8, batch_size=256, tau=128, max_iters=40, epsilon=-1.0)
    objs = {}
    for init in ["kmeans++", "random"]:
        vals = []
        for s in range(3):
            _, h = fit(x, kern, cfg, jax.random.PRNGKey(s), init=init,
                       early_stop=False)
            vals.append(h[-1]["f_after"])
        objs[init] = np.mean(vals)
    assert objs["kmeans++"] <= objs["random"] + 0.01


# ------------------------------------------------------------------ full batch
def test_fullbatch_lloyd_monotone_objective():
    x, y = _blobs(n=1200, k=6)
    kern = Gaussian(kappa=jnp.float32(1.0))
    assign, hist = fullbatch.fit(x, kern, 6, jax.random.PRNGKey(0),
                                 max_iters=30)
    objs = [h["objective"] for h in hist]
    assert all(b <= a + 1e-5 for a, b in zip(objs, objs[1:]))
    assert adjusted_rand_index(y, np.asarray(assign)) > 0.5


# ------------------------------------------------------------------ properties
@settings(max_examples=10, deadline=None)
@given(st.integers(2, 5), st.integers(16, 48), st.integers(8, 64),
       st.integers(0, 2 ** 16))
def test_center_invariants_property(k, b, tau, seed):
    """Lemma 4 / Observation 10 invariants after arbitrary steps:
    centers stay convex combinations => sum(coef) <= 1 and
    ||C||^2 <= gamma^2 (=1 for Gaussian)."""
    x, _ = _blobs(n=512, d=8, k=k, seed=seed % 7)
    cfg = MBConfig(k=k, batch_size=b, tau=tau, max_iters=6, epsilon=-1.0)
    key = jax.random.PRNGKey(seed)
    state, _ = fit(x, GAUSS, cfg, key, early_stop=False)
    coef_sums = np.asarray(jnp.sum(state.coef, axis=1))
    assert (coef_sums <= 1.0 + 1e-4).all()
    assert (coef_sums >= 0.0).all()
    assert (np.asarray(state.sqnorm) <= 1.0 + 1e-4).all()
    assert (np.asarray(state.coef) >= -1e-7).all()


@settings(max_examples=5, deadline=None)
@given(st.integers(0, 2 ** 16))
def test_truncation_error_bounded_property(seed):
    """Lemma 3: ||C_hat - C|| <= eps/28 when tau = ceil(b ln^2(28 gamma/eps)).
    We verify the *observable* consequence: truncated and untruncated runs
    driven by the same batches have close batch objectives.  (Incremental
    sqnorm mode — O(kWb) — keeps the theory-sized tau tractable on CPU; its
    equivalence to the paper's recompute is asserted separately above.)"""
    x, _ = _blobs(n=512, d=8, k=3, seed=seed % 5)
    eps = 0.05
    b = 64
    tau = int(np.ceil(b * np.log(28.0 / eps) ** 2))  # gamma = 1
    cfg = MBConfig(k=3, batch_size=b, tau=tau, max_iters=12, epsilon=-1.0,
                   sqnorm_mode="incremental", eval_mode="delta")
    init_idx = jnp.array([0, 50, 100], jnp.int32)
    key = jax.random.PRNGKey(seed)
    _, h2 = fit(x, GAUSS, cfg, key, init_idx=init_idx, early_stop=False)
    _, h1 = untruncated.fit(x, GAUSS, cfg, key, init_idx=init_idx,
                            early_stop=False)
    for a, c in zip(h2, h1):
        # |f_B(C_hat) - f_B(C)| <= 4*gamma*||C_hat - C|| <= eps/7 (Lemma 13)
        assert abs(a["f_after"] - c["f_after"]) <= eps / 7 + 1e-4


_PROP_KERNELS = {
    "gaussian": lambda p: Gaussian(kappa=jnp.float32(0.5 + 3.0 * p)),
    "linear": lambda p: Linear(),
    "polynomial": lambda p: Polynomial(
        bias=jnp.float32(1.0), scale=jnp.float32(1.0 + 3.0 * p), degree=2),
}


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(sorted(_PROP_KERNELS)), st.floats(0.0, 1.0),
       st.integers(16, 96), st.integers(8, 64), st.integers(0, 2 ** 16))
def test_sqnorm_incremental_matches_recompute_property(kname, kp, b, tau,
                                                       seed):
    """O(kWb) incremental <C,C> maintenance == the paper's O(kW^2) recompute
    across random kernels / batch sizes / window sizes."""
    x, _ = _blobs(n=384, d=8, k=3, seed=seed % 5)
    kern = _PROP_KERNELS[kname](kp)
    base = MBConfig(k=3, batch_size=b, tau=tau, max_iters=6, epsilon=-1.0)
    init_idx = jnp.array([0, 50, 100], jnp.int32)
    key = jax.random.PRNGKey(seed)
    s_rec, _ = fit(x, kern, base, key, init_idx=init_idx, early_stop=False)
    s_inc, _ = fit(x, kern, base._replace(sqnorm_mode="incremental"), key,
                   init_idx=init_idx, early_stop=False)
    scale = float(jnp.max(jnp.abs(s_rec.sqnorm))) + 1.0
    np.testing.assert_allclose(s_inc.sqnorm, s_rec.sqnorm,
                               atol=3e-4 * scale)


@settings(max_examples=8, deadline=None)
@given(st.sampled_from(sorted(_PROP_KERNELS)), st.floats(0.0, 1.0),
       st.integers(16, 96), st.integers(8, 64), st.integers(0, 2 ** 16))
def test_eval_delta_matches_direct_property(kname, kp, b, tau, seed):
    """O(kb^2) delta objective evaluation == the paper's direct O(kbW) pass
    across random kernels / batch sizes."""
    x, _ = _blobs(n=384, d=8, k=3, seed=seed % 5)
    kern = _PROP_KERNELS[kname](kp)
    base = MBConfig(k=3, batch_size=b, tau=tau, max_iters=6, epsilon=-1.0)
    init_idx = jnp.array([0, 50, 100], jnp.int32)
    key = jax.random.PRNGKey(seed)
    _, h_dir = fit(x, kern, base, key, init_idx=init_idx, early_stop=False)
    _, h_del = fit(x, kern, base._replace(eval_mode="delta"), key,
                   init_idx=init_idx, early_stop=False)
    scale = max(abs(h["f_after"]) for h in h_dir) + 1.0
    for a, c in zip(h_del, h_dir):
        assert a["f_after"] == pytest.approx(c["f_after"],
                                             abs=3e-4 * scale)


def test_predict_self_consistent():
    x, _ = _blobs(n=600)
    cfg = MBConfig(k=4, batch_size=128, tau=64, max_iters=15, epsilon=-1.0)
    state, _ = fit(x, GAUSS, cfg, KEY, early_stop=False)
    p1 = predict(state, x, x[:100], GAUSS)
    assert p1.shape == (100,)
    assert int(jnp.max(p1)) < 4 and int(jnp.min(p1)) >= 0


def test_weighted_objective_via_duplication_equivalence():
    """Footnote 1: the weighted case == duplicating points.  Sampling is
    uniform-with-replacement, so duplicated datasets shift the stationary
    distribution; we check the mechanism runs and improves."""
    x, _ = _blobs(n=400)
    xd = jnp.concatenate([x, x[:100]])  # duplicate 100 points (weight 2)
    cfg = MBConfig(k=4, batch_size=128, tau=64, max_iters=20, epsilon=-1.0)
    _, h = fit(xd, GAUSS, cfg, KEY, early_stop=False)
    assert h[-1]["f_after"] < h[0]["f_before"]
