"""Legacy ``fit_*`` shims vs the refactored loop-core drivers (PR-9).

One pin per executor family.  Each deprecated core-level entry point must

1. warn EXACTLY ONCE per process with a DeprecationWarning that names its
   :class:`repro.api.SolverConfig` replacement (repeat calls are silent),
2. stay deterministic across calls, and
3. return BIT-exactly what the refactored executor produces for the same
   keys under the shims' historical ``always_split=False`` contract —
   the PR-9 refactor moved the loop skeleton into ``repro.core.loop``,
   and the shims must not have drifted off the new drivers.

The ``repro.api.legacy`` adapters are exercised implicitly (every core
shim delegates through them); the direct-executor twin is the
non-tautological side of the pin.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SolverConfig
from repro.api.deprecation import reset_warnings
from repro.api.plan import resolve_plan
from repro.core import MBConfig
from repro.core.kernel_fns import Gaussian
from repro.data import blobs

GAUSS = Gaussian(kappa=jnp.float32(1.5))
KEY = jax.random.PRNGKey(21)
MB = MBConfig(k=4, batch_size=32, tau=16, epsilon=-1.0, max_iters=6)
IDX0 = jnp.asarray([5, 60, 120, 200], dtype=jnp.int32)

_CS_FIELDS = ("idx", "coef", "sqnorm", "counts", "head")
_DS_FIELDS = ("pts", "coef", "sqnorm", "counts", "head")


def _blobs(n=256, d=8, k=4, seed=0):
    x, _ = blobs(n=n, d=d, k=k, seed=seed)
    return jnp.asarray(x)


def _scfg(**axes):
    return SolverConfig(k=MB.k, batch_size=MB.batch_size, tau=MB.tau,
                        epsilon=MB.epsilon, max_iters=MB.max_iters,
                        kernel=GAUSS, **axes)


def _mesh1():
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def _assert_fields_equal(a, b, fields, ctx):
    for name in fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, name)),
                                      np.asarray(getattr(b, name)),
                                      err_msg=f"{ctx}:{name}")


@pytest.fixture(autouse=True)
def _fresh_warning_state():
    """Each pin observes the warn-once behavior from a clean slate (other
    test modules may already have warmed the per-process set)."""
    reset_warnings()
    yield
    reset_warnings()


def _call_twice_warns_once(shim_name, fn, *args, **kwargs):
    """Run the shim twice; assert exactly one DeprecationWarning naming
    the replacement surface.  Returns (first_result, second_result)."""
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        out1 = fn(*args, **kwargs)
        out2 = fn(*args, **kwargs)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and shim_name in str(w.message)]
    assert len(dep) == 1, (shim_name,
                           [str(w.message) for w in rec])
    assert "repro.api" in str(dep[0].message)
    return out1, out2


# ------------------------------------------------------------------ single
def test_shim_fit_single_host():
    from repro.core import fit as core_fit

    x = _blobs()
    (st1, h1), (st2, h2) = _call_twice_warns_once(
        "repro.core.fit", core_fit, x, GAUSS, MB, KEY, early_stop=False)
    _assert_fields_equal(st1, st2, _CS_FIELDS, "repeat")
    assert h1 == h2
    ex = resolve_plan(_scfg(cache="none", distribution="single", jit=False,
                            early_stop=False),
                      n=x.shape[0], solver="single").executor
    out = ex.fit(x, KEY, always_split=False)
    _assert_fields_equal(st1, out.state, _CS_FIELDS, "executor")
    assert h1 == out.history


def test_shim_fit_jit():
    from repro.core import fit_jit as core_fit_jit

    x = _blobs()
    (st1, it1), (st2, it2) = _call_twice_warns_once(
        "repro.core.fit_jit", core_fit_jit, x, GAUSS, MB, KEY, IDX0)
    _assert_fields_equal(st1, st2, _CS_FIELDS, "repeat")
    assert int(it1) == int(it2)
    ex = resolve_plan(_scfg(cache="none", distribution="single", jit=True),
                      n=x.shape[0], solver="single").executor
    out = ex.fit(x, KEY, init_idx=IDX0, always_split=False)
    _assert_fields_equal(st1, out.state, _CS_FIELDS, "executor")
    assert int(it1) == int(out.iters)


# -------------------------------------------------------------- single_lru
def test_shim_fit_cached():
    from repro.cache import stats
    from repro.core.minibatch import fit_cached as core_fit_cached

    x = _blobs()
    (st1, h1, ck1), (st2, h2, ck2) = _call_twice_warns_once(
        "repro.core.fit_cached", core_fit_cached, x, GAUSS, MB, KEY,
        tile=32, capacity=8, early_stop=False)
    _assert_fields_equal(st1, st2, _CS_FIELDS, "repeat")
    ex = resolve_plan(_scfg(cache="lru", distribution="single", jit=False,
                            early_stop=False, cache_tile=32,
                            cache_capacity=8),
                      n=x.shape[0], solver="single_lru").executor
    out = ex.fit(x, KEY, always_split=False)
    _assert_fields_equal(st1, out.state, _CS_FIELDS, "executor")
    assert h1 == out.history
    assert stats(ck1.cache) == stats(out.cache.cache)


# ----------------------------------------------------------------- sharded
def test_shim_fit_distributed_stream():
    from repro.core.distributed import fit_distributed as core_fd

    x = _blobs()
    batches = [np.asarray(x[i * 32:(i + 1) * 32]) for i in range(6)]
    mesh = _mesh1()
    # the stream is consumed per call: hand each call a fresh iterator
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        st1, h1 = core_fd(iter(list(batches)), x[IDX0], GAUSS, MB, mesh,
                          early_stop=False)
        st2, h2 = core_fd(iter(list(batches)), x[IDX0], GAUSS, MB, mesh,
                          early_stop=False)
    dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
           and "fit_distributed" in str(w.message)]
    assert len(dep) == 1, [str(w.message) for w in rec]
    _assert_fields_equal(st1, st2, _DS_FIELDS, "repeat")
    # the shim pins prefetch=False (caller-owned iterator advance
    # contract); the executor twin must match under the same axis
    ex = resolve_plan(_scfg(cache="none", distribution="sharded",
                            jit=False, early_stop=False, prefetch=False),
                      mesh=mesh, solver="sharded").executor
    st3, h3 = ex.fit_stream(iter(list(batches)), x[IDX0], mb=MB)
    _assert_fields_equal(st1, st3, _DS_FIELDS, "executor")
    assert len(h1) == len(h3)
    for a, b in zip(h1, h3):
        assert a == b


def test_shim_fit_distributed_jit():
    from repro.core.distributed import fit_distributed_jit as core_fdj

    x = _blobs()
    mesh = _mesh1()
    (st1, it1), (st2, it2) = _call_twice_warns_once(
        "repro.core.distributed.fit_distributed_jit", core_fdj,
        x, x[IDX0], GAUSS, MB, mesh, KEY)
    _assert_fields_equal(st1, st2, _DS_FIELDS, "repeat")
    assert int(it1) == int(it2)
    ex = resolve_plan(_scfg(cache="none", distribution="sharded",
                            jit=True),
                      n=x.shape[0], mesh=mesh, solver="sharded").executor
    out = ex.fit(x, KEY, center_pts=x[IDX0], always_split=False,
                 strict=True)
    _assert_fields_equal(st1, out.state, _DS_FIELDS, "executor")
    assert int(it1) == int(out.iters)


# ------------------------------------------------------------- sharded_lru
def test_shim_fit_distributed_cached_jit():
    from repro.core.distributed import (
        fit_distributed_cached_jit as core_fdcj)

    x = _blobs()
    mesh = _mesh1()
    (st1, caches1, it1), (st2, _, it2) = _call_twice_warns_once(
        "repro.core.distributed.fit_distributed_cached_jit", core_fdcj,
        x, IDX0, GAUSS, MB, mesh, KEY, tile=32, capacity=16)
    _assert_fields_equal(st1, st2, _DS_FIELDS, "repeat")
    assert int(it1) == int(it2)
    ex = resolve_plan(_scfg(cache="lru", distribution="sharded", jit=True,
                            cache_tile=32, cache_capacity=16),
                      n=x.shape[0], mesh=mesh,
                      solver="sharded_lru").executor
    out = ex.fit(x, KEY, init_idx=IDX0, always_split=False, strict=True)
    _assert_fields_equal(st1, out.state, _DS_FIELDS, "executor")
    assert int(it1) == int(out.iters)
    from repro.cache import stats
    s1 = stats(jax.tree.map(lambda a: a[0], caches1))
    s2 = stats(jax.tree.map(lambda a: a[0], out.caches))
    assert s1 == s2


# ----------------------------------------------------------- multi_restart
def test_shim_fit_restarts():
    from repro.core.engine import fit_restarts as core_fr

    x = _blobs()
    res1, res2 = _call_twice_warns_once(
        "repro.core.fit_restarts", core_fr, x, GAUSS, MB, KEY, 2)
    np.testing.assert_array_equal(np.asarray(res1.objectives),
                                  np.asarray(res2.objectives))
    assert int(res1.best) == int(res2.best)
    ex = resolve_plan(_scfg(cache="none", distribution="single", jit=True,
                            restarts=2),
                      n=x.shape[0], solver="multi_restart").executor
    res3 = ex.fit(x, KEY).engine
    np.testing.assert_array_equal(np.asarray(res1.objectives),
                                  np.asarray(res3.objectives))
    assert int(res1.best) == int(res3.best)
    _assert_fields_equal(res1.state, res3.state, _CS_FIELDS, "executor")
