"""repro.api.keys — the ONE audited PRNG derivation tree (PR-9 satellite).

Three layers of protection:

1. every helper is pinned to its documented primitive (``split_init`` IS
   ``jax.random.split``'s pair, ``shard_key`` IS ``fold_in``, ...) so a
   refactor cannot silently change any plan's batch sequence;
2. ``derive_fit_keys`` (formerly ``executors._derive_keys``) is pinned to
   its three documented branches, including the legacy
   ``always_split=False`` bit-exactness contract;
3. a source audit asserts the fit-loop modules contain NO raw
   ``jax.random.split`` call — every fit path derives its keys through
   this module, so the derivation exists exactly once.
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import keys as api_keys

SRC = pathlib.Path(__file__).resolve().parent.parent / "src" / "repro"


def _eq(a, b):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_as_key_coerces_seed_and_passes_keys_through():
    k = api_keys.as_key(7)
    _eq(k, jax.random.PRNGKey(7))
    _eq(api_keys.as_key(k), k)


def test_split_init_is_one_split():
    key = jax.random.PRNGKey(3)
    init_key, fit_key = api_keys.split_init(key)
    ref = jax.random.split(key)
    _eq(init_key, ref[0])
    _eq(fit_key, ref[1])


def test_next_batch_key_is_one_split_and_deterministic():
    key = jax.random.PRNGKey(11)
    k1, kb1 = api_keys.next_batch_key(key)
    ref = jax.random.split(key)
    _eq(k1, ref[0])
    _eq(kb1, ref[1])
    k2, kb2 = api_keys.next_batch_key(key)
    _eq(k1, k2)
    _eq(kb1, kb2)


def test_shard_key_is_fold_in():
    kb = jax.random.PRNGKey(5)
    for r in (0, 1, 7):
        _eq(api_keys.shard_key(kb, jnp.int32(r)),
            jax.random.fold_in(kb, jnp.int32(r)))


def test_restart_keys_is_three_way_split():
    key = jax.random.PRNGKey(13)
    ki, kf, ke = api_keys.restart_keys(key)
    ref = jax.random.split(key, 3)
    _eq(ki, ref[0])
    _eq(kf, ref[1])
    _eq(ke, ref[2])


def test_per_restart_is_r_way_split():
    key = jax.random.PRNGKey(17)
    _eq(api_keys.per_restart(key, 4), jax.random.split(key, 4))


def test_batch_key_at_replays_the_stream():
    """batch_key_at(fit_key, t) == the t-th kb of the next_batch_key
    stream — the resumable-pipeline contract."""
    fit_key = api_keys.split_init(jax.random.PRNGKey(23))[1]
    key = fit_key
    for t in range(6):
        key, kb = api_keys.next_batch_key(key)
        _eq(api_keys.batch_key_at(fit_key, t), kb)


@pytest.mark.parametrize("always_split", [True, False])
def test_derive_fit_keys_no_init_splits_once(always_split):
    key = jax.random.PRNGKey(29)
    init_key, fit_key = api_keys.derive_fit_keys(key, False, always_split)
    ref_i, ref_f = api_keys.split_init(key)
    _eq(init_key, ref_i)
    _eq(fit_key, ref_f)


def test_derive_fit_keys_init_given_estimator_branch():
    """always_split=True still burns the init split: the batch stream is
    identical whether the caller or the estimator drew the init."""
    key = jax.random.PRNGKey(31)
    init_key, fit_key = api_keys.derive_fit_keys(key, True, True)
    assert init_key is None
    _eq(fit_key, api_keys.split_init(key)[1])
    _eq(fit_key, api_keys.derive_fit_keys(key, False, True)[1])


def test_derive_fit_keys_legacy_branch_is_identity():
    """always_split=False with an explicit init: the root key IS the fit
    key — the historical shims' bit-exactness contract."""
    key = jax.random.PRNGKey(37)
    init_key, fit_key = api_keys.derive_fit_keys(key, True, False)
    assert init_key is None
    _eq(fit_key, key)


# ---------------------------------------------------------------- audit
FIT_LOOP_MODULES = [
    "api/executors.py",
    "core/loop.py",
    "core/minibatch.py",
    "core/distributed.py",
    "core/engine.py",
]


def test_keys_module_owns_the_split():
    assert "jax.random.split(" in (SRC / "api" / "keys.py").read_text()


@pytest.mark.parametrize("rel", FIT_LOOP_MODULES)
def test_no_raw_key_split_in_fit_loop_modules(rel):
    """The fit-loop layers never call jax.random.split directly — all key
    derivation routes through repro.api.keys (one audited tree; a stray
    split would silently fork a plan's batch sequence)."""
    text = (SRC / rel).read_text()
    assert "jax.random.split(" not in text, (
        f"{rel} derives keys outside repro.api.keys")
