"""Correctness of the §Perf beyond-paper variants against their baselines."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig, forward_train, init_params
from repro.models.rwkv6 import _wkv_chunked, _wkv_sequential

COMMON = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
              vocab=128, head_dim=16, dtype="float32", remat=False)


def test_moe_group_dispatch_matches_baseline():
    cfg0 = ModelConfig(name="moe", family="moe", moe=True, n_experts=4,
                       top_k=2, moe_d_ff=64, n_shared_experts=1,
                       dense_residual=True, capacity_factor=8.0, **COMMON)
    cfg1 = dataclasses.replace(cfg0, moe_group_dispatch=True)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, 128)}
    l0 = forward_train(params, cfg0, batch)
    l1 = forward_train(params, cfg1, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-5)


def test_rwkv_chunked_matches_sequential_oracle():
    b, s, d, nh, hd = 2, 96, 64, 4, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, d)) * 0.5 for i in range(3))
    u = jax.random.normal(ks[3], (nh, hd)) * 0.1
    for scale in (0.003, 1.0):   # typical + harsh decay
        w = jnp.exp(-scale * jnp.exp(
            jax.random.normal(ks[4], (b, s, d)) * 0.3))
        o_seq, s_seq = _wkv_sequential(r, k, v, w, u, nh, hd, b)
        o_ch, s_ch = _wkv_chunked(r, k, v, w, u, nh, hd, 32)
        np.testing.assert_allclose(np.asarray(o_ch),
                                   np.asarray(o_seq.reshape(b, s, d)),
                                   atol=5e-4)
        np.testing.assert_allclose(np.asarray(s_ch), np.asarray(s_seq),
                                   atol=5e-4)


def test_rwkv_chunked_model_forward_matches():
    cfg0 = ModelConfig(name="rwkv", family="ssm", ssm_head_dim=16, **COMMON)
    cfg1 = dataclasses.replace(cfg0, rwkv_chunked=True, rwkv_chunk=16)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, 128)}
    l0 = forward_train(params, cfg0, batch)
    l1 = forward_train(params, cfg1, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=2e-3)


def test_attn_bf16_scores_close_to_f32():
    cfg0 = ModelConfig(name="d", **COMMON)
    cfg1 = dataclasses.replace(cfg0, attn_scores_bf16=True)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64),
                                          0, 128)}
    l0 = forward_train(params, cfg0, batch)
    l1 = forward_train(params, cfg1, batch)
    # bf16 score accumulation: small relative error only
    denom = float(jnp.max(jnp.abs(l0))) + 1e-6
    assert float(jnp.max(jnp.abs(l1 - l0))) / denom < 0.05


def test_scan_unroll_is_numerically_identical():
    cfg0 = ModelConfig(name="d", **COMMON)
    cfg1 = dataclasses.replace(cfg0, scan_unroll=True)
    params = init_params(cfg0, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 32),
                                          0, 128)}
    l0 = forward_train(params, cfg0, batch)
    l1 = forward_train(params, cfg1, batch)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-6)


def test_sharded_gram_matches_baseline_subprocess():
    """recompute_sharded == recompute on a multi-device CPU mesh."""
    import os
    import subprocess
    import sys
    import textwrap

    script = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import MBConfig, Gaussian
        from repro.core.distributed import (
            make_dist_step, init_dist_state, state_shardings)
        from repro.core.state import window_size
        from repro.data import blobs
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        x, _ = blobs(n=1024, d=16, k=8, seed=0)
        x = jnp.asarray(x)
        kern = Gaussian(kappa=jnp.float32(2.0))
        base = MBConfig(k=8, batch_size=64, tau=64, max_iters=4,
                        epsilon=-1.0)
        w = window_size(base.batch_size, base.tau)   # 128 % 4 == 0
        init_pts = x[jnp.arange(8) * 100]
        outs = []
        for mode in ["recompute", "recompute_sharded"]:
            cfg = base._replace(sqnorm_mode=mode)
            st = jax.device_put(init_dist_state(init_pts, kern, w),
                                state_shardings(mesh))
            step = jax.jit(make_dist_step(kern, cfg, mesh))
            key = jax.random.PRNGKey(0)
            for i in range(4):
                key, kb = jax.random.split(key)
                idx = jax.random.randint(kb, (64,), 0, 1024)
                st, info = step(st, x[idx])
            outs.append((np.asarray(st.sqnorm), float(info.f_after)))
        np.testing.assert_allclose(outs[0][0], outs[1][0], atol=1e-5)
        assert abs(outs[0][1] - outs[1][1]) < 1e-5
        print("SHARDED-GRAM-OK")
    """)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=600,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SHARDED-GRAM-OK" in r.stdout
