"""Unit + property tests for repro.core.kernel_fns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests; suite degrades, not errors
from hypothesis import given, settings, strategies as st

from repro.core.kernel_fns import (
    Gaussian, Laplacian, Linear, Polynomial, Precomputed,
    gamma_of, kernel_cross, kernel_diag, median_sq_dist_heuristic,
)

KERNELS = [
    Gaussian(kappa=jnp.float32(1.7)),
    Laplacian(kappa=jnp.float32(2.3)),
    Polynomial(bias=jnp.float32(1.0), scale=jnp.float32(4.0), degree=2),
    Linear(),
]


@pytest.mark.parametrize("kern", KERNELS, ids=lambda k: type(k).__name__)
def test_symmetry_and_diag(kern):
    x = jnp.asarray(np.random.default_rng(0).normal(size=(17, 5)), jnp.float32)
    k_xx = kernel_cross(kern, x, x)
    np.testing.assert_allclose(k_xx, k_xx.T, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.diag(k_xx), kernel_diag(kern, x),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("kern", KERNELS[:2], ids=["gauss", "laplace"])
def test_normalized_kernels_gamma_one(kern):
    """Paper: for normalized kernels gamma = 1."""
    x = jnp.asarray(np.random.default_rng(1).normal(size=(50, 8)), jnp.float32)
    assert float(gamma_of(kern, x)) == pytest.approx(1.0)


def test_gaussian_psd():
    x = jnp.asarray(np.random.default_rng(2).normal(size=(40, 6)), jnp.float32)
    g = np.asarray(kernel_cross(Gaussian(kappa=jnp.float32(1.0)), x, x),
                   np.float64)
    w = np.linalg.eigvalsh((g + g.T) / 2)
    assert w.min() > -1e-5


def test_precomputed_lookup():
    gram = jnp.asarray(np.arange(25, dtype=np.float32).reshape(5, 5))
    kern = Precomputed(gram=gram)
    idx = jnp.arange(5, dtype=jnp.float32)[:, None]
    sub = kernel_cross(kern, idx[1:3], idx[3:5])
    np.testing.assert_array_equal(sub, gram[1:3][:, 3:5])
    np.testing.assert_array_equal(kernel_diag(kern, idx),
                                  jnp.diagonal(gram))


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 30), st.integers(1, 8), st.integers(0, 2 ** 16))
def test_gaussian_range_property(n, d, seed):
    """Gaussian kernel values always in (0, 1] and K(x,x) = 1."""
    x = jnp.asarray(np.random.default_rng(seed).normal(size=(n, d)) * 3,
                    jnp.float32)
    g = kernel_cross(Gaussian(kappa=jnp.float32(0.7)), x, x)
    assert float(jnp.min(g)) >= 0.0
    assert float(jnp.max(g)) <= 1.0 + 1e-5
    # the matmul-trick expansion loses ~|x|^2 * eps_f32 on the diagonal;
    # that is the expected f32 behaviour, not a bug (clamped at 0 pre-exp)
    np.testing.assert_allclose(jnp.diagonal(g), 1.0, atol=1e-4)


def test_median_heuristic_scale():
    x = jnp.asarray(np.random.default_rng(3).normal(size=(256, 4)), jnp.float32)
    m = float(median_sq_dist_heuristic(x))
    d2 = np.sum((np.asarray(x)[:, None] - np.asarray(x)[None]) ** 2, -1)
    med = np.median(d2[~np.eye(256, dtype=bool)])
    assert m == pytest.approx(med, rel=0.05)
