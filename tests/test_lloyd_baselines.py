"""Non-kernel baselines (paper §6 comparison set)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adjusted_rand_index
from repro.core.lloyd import kmeans_fit, minibatch_kmeans_fit
from repro.data import blobs


def test_lloyd_on_blobs():
    x, y = blobs(n=1500, d=8, k=5, seed=1)
    _, assign, hist = kmeans_fit(jnp.asarray(x), 5, jax.random.PRNGKey(0))
    assert adjusted_rand_index(y, np.asarray(assign)) > 0.7
    objs = [h["objective"] for h in hist]
    assert all(b <= a + 1e-6 for a, b in zip(objs, objs[1:]))


@pytest.mark.parametrize("rate", ["beta", "sklearn"])
def test_minibatch_kmeans_rates(rate):
    x, y = blobs(n=2000, d=8, k=5, seed=2)
    _, assign, hist = minibatch_kmeans_fit(
        jnp.asarray(x), 5, jax.random.PRNGKey(0), batch_size=256,
        rate=rate, max_iters=60)
    assert adjusted_rand_index(y, np.asarray(assign)) > 0.6


def test_minibatch_kmeans_early_stop():
    x, _ = blobs(n=2000, d=8, k=5, seed=2)
    _, _, hist = minibatch_kmeans_fit(
        jnp.asarray(x), 5, jax.random.PRNGKey(0), batch_size=512,
        rate="beta", max_iters=200, epsilon=1e-3, early_stop=True)
    assert len(hist) < 200
