"""Shared test fixtures.  NOTE: XLA_FLAGS device-count tricks are deliberately
NOT set here — smoke tests and benches must see the 1 real CPU device; only
launch/dryrun.py (its own process) forces 512 placeholder devices."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
