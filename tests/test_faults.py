"""Deterministic fault injection + the hardened recovery spine.

Three layers of claims:

* The harness itself (:mod:`repro.service.faults`): firings are pure in
  (plan seed, site, occurrence index) — same plan, same workload, same
  trace; ``faults=None`` leaves every instrumented path bit-identical.
* The hardening each fault exposes: CRC-checked snapshots that
  quarantine + fall back, a watchdog that catches HUNG (not just slow)
  steps, the non-finite-carry guard + dead-center reseed, swap-failure
  counting + backoff, request cancel/deadline skip.
* The headline guarantee: a learner tortured by injected crashes/hangs
  recovers to a carry BIT-IDENTICAL to the fault-free run.

Shares test_service.py's tiny shape family (capacity 128, d 8, k 4) so
the cross-estimator program cache compiles once for the module.
"""
import os
import time

import jax
import numpy as np
import pytest

from repro.api.estimator import SnapshotIntegrityError
from repro.core.loop import guard_carry
from repro.service import (
    FaultPlan, FaultRule, InjectedFault, telemetry)
from repro.service.demo import build_service

pytestmark = pytest.mark.chaos     # select with -m chaos; runs in the
                                   # default (not-slow) lane too

K, D, CAP = 4, 8, 128


def _svc(tmpdir, **kw):
    kw.setdefault("k", K)
    kw.setdefault("d", D)
    kw.setdefault("capacity", CAP)
    kw.setdefault("batch_size", 32)
    kw.setdefault("tau", 16)
    kw.setdefault("iters_per_round", 2)
    kw.setdefault("arrivals_per_step", 64)
    kw.setdefault("buckets", (64,))
    return build_service(str(tmpdir), **kw)


def _leaves(carry):
    return [np.asarray(x) for x in jax.tree.leaves(carry)]


def _assert_identical(a, b):
    la, lb = _leaves(a), _leaves(b)
    assert len(la) == len(lb)
    for xa, xb in zip(la, lb):
        np.testing.assert_array_equal(xa, xb)


# ------------------------------------------------------------ the harness
def test_plan_validates_sites_and_kinds():
    with pytest.raises(ValueError):
        FaultPlan([FaultRule("actor.dance", "crash")])
    with pytest.raises(ValueError):
        FaultPlan([FaultRule("actor.swap", "explode")])


def test_at_every_prob_triggers_and_trace():
    plan = FaultPlan([FaultRule("learner.step", "crash", at=(2,)),
                      FaultRule("actor.swap", "io", every=3,
                                max_fires=1)], seed=5)
    for i in range(5):
        if i == 2:
            with pytest.raises(InjectedFault):
                plan.fire("learner.step")
        else:
            plan.fire("learner.step")
    fired = 0
    for i in range(12):
        try:
            plan.fire("actor.swap")
        except OSError:
            fired += 1
    assert fired == 1                       # max_fires caps the every-rule
    assert plan.trace_list() == [("learner.step", "crash", 2),
                                 ("actor.swap", "io", 3)]


def test_prob_rule_is_pure_in_seed_and_occ():
    def run(seed):
        plan = FaultPlan([FaultRule("buffer.push", "nan", prob=0.3)],
                         seed=seed)
        out = []
        for i in range(40):
            out.append(plan.fire("buffer.push", index=i) is not None)
        return out, plan.trace_list()

    a, ta = run(11)
    b, tb = run(11)
    c, _ = run(12)
    assert a == b and ta == tb
    assert a != c                           # seed actually matters
    assert any(a)


def test_hang_aborts_and_raises():
    plan = FaultPlan([FaultRule("learner.step", "hang", at=(0,),
                                delay_s=30.0)])
    t0 = time.monotonic()
    import threading

    threading.Timer(0.05, plan.abort_hangs).start()
    with pytest.raises(InjectedFault, match="hang"):
        plan.fire("learner.step")
    assert time.monotonic() - t0 < 5.0      # aborted, not expired


def test_nan_and_corrupt_helpers_are_deterministic(tmp_path):
    plan = FaultPlan([FaultRule("buffer.push", "nan", at=(0,))], seed=3)
    ev = plan.fire("buffer.push", index=0)
    x = np.arange(80, dtype=np.float32).reshape(8, 10)
    a = plan.nan_rows(x, ev)
    b = plan.nan_rows(x, ev)
    np.testing.assert_array_equal(a, b)
    assert np.isnan(a).any() and not np.isnan(x).any()

    p = str(tmp_path / "f.bin")
    with open(p, "wb") as f:
        f.write(bytes(4096))
    plan2 = FaultPlan([FaultRule("snapshot.publish", "corrupt",
                                 at=(0,))], seed=3)
    ev2 = plan2.fire("snapshot.publish")
    plan2.corrupt_file(p, ev2)
    with open(p, "rb") as f:
        raw = f.read()
    assert raw != bytes(4096)
    assert raw[-128:] == bytes(128)         # EOCD region untouched


# ----------------------------------------------- faults=None bit-identity
def test_faults_none_is_bit_identical(tmp_path):
    """The whole instrumented spine with faults=None produces the same
    carry and the same buffer content as... itself; and the injection
    plumbing adds nothing observable (no counters, no trace)."""
    la, *_ = _svc(tmp_path / "a", publish_every=2)
    lb, *_ = _svc(tmp_path / "b", publish_every=2)
    _assert_identical(la.run(4), lb.run(4))
    assert la.guard_patched == 0 and la.guard_reseeded == 0
    assert la.stats()["watchdog_fires"] == 0


# ------------------------------------------------------------ carry guard
def test_guard_clean_carry_same_object(tmp_path):
    l, *_ = _svc(tmp_path)
    carry = l.run(2)
    guarded, rep = guard_carry(carry, seed=0)
    assert guarded is carry and rep.clean


def test_guard_repairs_poisoned_carry(tmp_path):
    l, *_ = _svc(tmp_path)
    carry = l.run(2)
    coef = np.array(carry.state.coef, copy=True)
    coef[0] = np.nan                        # kill center 0 entirely
    coef[1, 0] = np.inf                     # poison one entry of center 1
    bad = carry._replace(state=carry.state._replace(coef=coef))
    x = l.buffer.snapshot()
    kernel = l.est.plan_.executor.kernel
    guarded, rep = guard_carry(bad, x=x, kernel=kernel, seed=0)
    assert rep.patched > 0 and rep.reseeded == 1
    gcoef = np.asarray(guarded.state.coef)
    assert np.isfinite(gcoef).all()
    assert gcoef[0, 0] == 1.0               # reseeded as a single point
    assert np.isfinite(np.asarray(guarded.state.sqnorm)).all()
    # deterministic: same inputs, same repair
    guarded2, _ = guard_carry(bad, x=x, kernel=kernel, seed=0)
    _assert_identical(guarded, guarded2)


def test_nan_arrivals_survive_via_guard(tmp_path):
    """Degenerate (NaN-row) arrivals at the buffer: the fit still
    completes and every published carry is finite — the guard repaired
    whatever the poisoned batch broke."""
    plan = FaultPlan([FaultRule("buffer.push", "nan", at=(CAP,))],
                     seed=9)
    l, *_ = _svc(tmp_path, faults=plan)
    carry = l.run(3)
    for leaf in _leaves(carry):
        if np.issubdtype(leaf.dtype, np.floating):
            assert np.isfinite(leaf).all()
    assert plan.occurrences("buffer.push") >= CAP


# --------------------------------------------------------------- watchdog
def test_watchdog_catches_hung_step(tmp_path):
    """A step that HANGS (never returns) is detected at the deadline and
    recovery converges to the fault-free carry bit-identically."""
    l_clean, *_ = _svc(tmp_path / "clean", publish_every=2)
    want = l_clean.run(6)

    plan = FaultPlan([FaultRule("learner.step", "hang", at=(3,),
                                delay_s=120.0)])
    l, *_ = _svc(tmp_path / "chaos", publish_every=2, faults=plan,
                 step_timeout_s=2.0)
    got = l.run(6)
    assert l.stats()["watchdog_fires"] == 1 and l.restores == 1
    _assert_identical(want, got)


# --------------------------------------- snapshot integrity + fallback
def test_corrupt_snapshot_quarantined_and_load_falls_back(tmp_path):
    l, _, store, *_ = _svc(tmp_path, publish_every=1)
    l.run(3)                                # versions 1, 2, 3 on disk
    versions = store.versions()
    assert len(versions) == 3
    newest = versions[-1]
    with open(store.path_for(newest), "r+b") as f:
        f.seek(200)
        b = f.read(1)
        f.seek(200)
        f.write(bytes([b[0] ^ 0xFF]))
    v, est = store.load()
    assert v == versions[-2]                # fell back past the corrupt one
    assert store.quarantined == 1 and store.load_fallbacks == 1
    assert os.path.exists(store.path_for(newest) + ".corrupt")
    assert newest not in store.versions()
    assert store.latest_version() == versions[-2]   # pointer heals too
    assert est.predict(np.zeros((4, D), np.float32)) is not None


def test_explicit_version_corrupt_raises(tmp_path):
    l, _, store, *_ = _svc(tmp_path, publish_every=1)
    l.run(2)
    v = store.versions()[-1]
    with open(store.path_for(v), "r+b") as f:
        f.seek(100)
        f.write(b"\xff\xff\xff\xff")
    with pytest.raises(SnapshotIntegrityError):
        store.load(v)
    assert store.quarantined == 1


def test_learner_restores_past_corrupt_snapshot(tmp_path):
    """Crash + corrupt newest snapshot: run_resilient falls back to the
    older intact version and still converges bit-identically (the
    buffer replay covers the extra rewind)."""
    l_clean, *_ = _svc(tmp_path / "clean", publish_every=2)
    want = l_clean.run(6)

    plan = FaultPlan([FaultRule("learner.step", "crash", at=(5,))])
    l, _, store, *_ = _svc(tmp_path / "chaos", publish_every=2,
                           faults=plan)

    def corrupt_newest(rnd):
        if rnd == 4:        # after v4 published, before the crash at 5
            with open(store.path_for(4), "r+b") as f:
                f.seek(300)
                b = f.read(1)
                f.seek(300)
                f.write(bytes([b[0] ^ 0xFF]))

    l.on_round = corrupt_newest
    got = l.run(6)
    assert l.restores == 1
    assert l.stats()["restore_fallbacks"] >= 1
    assert store.quarantined >= 1
    _assert_identical(want, got)


# ------------------------------------------------------- actor satellites
def test_swap_failures_counted_and_surfaced(tmp_path):
    l, actor, store, *_ = _svc(tmp_path, publish_every=1)
    l.run(2)
    plan = FaultPlan([FaultRule("actor.swap", "io", at=(0,))])
    actor.faults = plan
    store.faults = None
    with pytest.raises(OSError):
        actor.try_swap(force=True)
    # the loop counts what try_swap raises
    actor._stop.set()
    assert actor._swap_backoff_s(0) == actor.poll_every_s
    assert actor._swap_backoff_s(2) > actor.poll_every_s
    actor.swap_failures += 1                # what _swap_loop would do
    t = telemetry.poll(actor=actor)
    assert t["snapshot"]["swap_failures"] == 1
    assert "quarantined" in t["snapshot"]


def test_corrupt_publish_never_swapped_in(tmp_path):
    """An actor polling a store whose newest publish was corrupted swaps
    in the newest INTACT version instead — corrupt bytes never serve."""
    plan = FaultPlan([FaultRule("snapshot.publish", "corrupt",
                                at=(2,))], seed=4)
    l, actor, store, *_ = _svc(tmp_path, publish_every=1, faults=plan)
    l.run(3)                                # publish #2 (v3) corrupted
    assert actor.try_swap(force=True)
    assert actor.version == 2               # newest intact
    assert store.quarantined == 1
    assert actor.snapshot_stats()["quarantined"] == 1


def test_mismatched_kind_held_not_requeued(tmp_path):
    l, actor, *_ = _svc(tmp_path, publish_every=1)
    l.run(1)
    actor.try_swap(force=True)
    a = actor.submit(np.zeros((4, D), np.float32), "predict")
    b = actor.submit(np.zeros((4, D), np.float32), "transform")
    batch = actor._gather()
    assert batch == [a] and actor._held is b
    batch2 = actor._gather()                # held becomes the next head
    assert batch2[0] is b and actor._held is None
    actor._serve(batch)
    actor._serve(batch2)
    assert a.wait(5.0).shape == (4,)
    assert b.wait(5.0).shape == (4, K)


def test_cancelled_request_skipped(tmp_path):
    l, actor, *_ = _svc(tmp_path, publish_every=1)
    l.run(1)
    actor.try_swap(force=True)
    a = actor.submit(np.zeros((4, D), np.float32))
    b = actor.submit(np.ones((4, D), np.float32))
    a.cancel()
    actor._serve([a, b])
    assert actor.cancel_skipped == 1
    with pytest.raises(TimeoutError):
        a.wait(0.1)
    assert b.wait(5.0).shape == (4,)
    # deadline path: an expired deadline is equivalent to cancel
    c = actor.submit(np.zeros((4, D), np.float32), deadline_s=0.0)
    time.sleep(0.01)
    actor._serve([c])
    assert actor.cancel_skipped == 2


def test_serve_retries_transient_fault(tmp_path):
    plan = FaultPlan([FaultRule("actor.serve", "io", at=(0,))])
    l, actor, *_ = _svc(tmp_path, publish_every=1)
    l.run(1)
    actor.try_swap(force=True)
    actor.faults = plan
    r = actor.submit(np.zeros((4, D), np.float32))
    actor._serve([r])
    assert r.wait(5.0).shape == (4,)        # retried past the IOError
    assert actor.serve_retried == 1


# ------------------------------------------------------- trace replays
def test_same_plan_same_workload_same_trace(tmp_path):
    def run(sub):
        plan = FaultPlan([FaultRule("learner.step", "crash", at=(2,)),
                          FaultRule("buffer.push", "nan", prob=0.02)],
                         seed=21)
        l, *_ = _svc(tmp_path / sub, publish_every=2, faults=plan)
        carry = l.run(5)
        return plan.trace_list(), carry

    ta, ca = run("a")
    tb, cb = run("b")
    assert ta == tb and len(ta) > 0
    _assert_identical(ca, cb)
