"""Benchmark harness — one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]

Prints ``name,us_per_call,derived`` CSV rows (plus commentary lines starting
with '#').  Mapping to the paper:

  speedup        Fig. 1 (runtime bars): full-batch vs Algorithm 1 vs
                 Algorithm 2 per-iteration wall time; speedup ratios.
  n_independence Thm 1(1): Algorithm 2 iteration time is independent of n
                 (the full-batch baseline scales ~n^2).
  quality        Figs. 2-13: ARI/NMI of all algorithms on matched datasets.
  tau_sweep      Appendix C: quality vs tau in {50,100,200,300}.
  rates          §6 claim 2: beta learning rate vs sklearn rate.
  gamma_table    Table 1: gamma per (dataset x kernel).
  termination    Thm 1(2): iterations-to-stop vs 1/epsilon.
  service        serving gates (docs/serving.md): microbatch p99 vs bare
                 predict, zero recompiles after warmup, snapshot-swap
                 pause — writes BENCH_service.json.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Gaussian, MBConfig, adjusted_rand_index, fit, gamma_of,
    normalized_mutual_info, predict,
)
from repro.core import fullbatch, lloyd, untruncated
from repro.core.minibatch import make_step, sample_batch
from repro.core.state import init_state, window_size
from repro.data import blobs, circles, moons
from repro.data.graph_kernels import heat_kernel, knn_kernel

GAUSS = Gaussian(kappa=jnp.float32(1.0))


def bench_env(seed=0) -> dict:
    """Shared provenance block embedded in every BENCH_*.json ``env`` key:
    enough to tell two result files apart (code version, jax version,
    backend/device, seed) without re-running anything."""
    import os
    import subprocess

    here = os.path.dirname(os.path.abspath(__file__))
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"], cwd=here,
                             capture_output=True, text=True,
                             timeout=10).stdout.strip() or None
    except Exception:                       # noqa: BLE001 — no git, no sha
        sha = None
    dev = jax.devices()[0]
    return dict(git_sha=sha, jax_version=jax.__version__,
                backend=jax.default_backend(),
                device_kind=getattr(dev, "device_kind", str(dev)),
                device_count=jax.device_count(), seed=int(seed))


def _time_step(fn, iters=10, warmup=2):
    for _ in range(warmup):
        out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6  # us


# ----------------------------------------------------------------- speedup
def bench_speedup(fast: bool):
    ns = [2048, 8192] if fast else [2048, 8192, 16384]
    k, b, tau, d = 10, 512, 200, 32
    for n in ns:
        x, _ = blobs(n=n, d=d, k=k, seed=0)
        x = jnp.asarray(x)
        cfg = MBConfig(k=k, batch_size=b, tau=tau, max_iters=5,
                       epsilon=-1.0)
        init_idx = jnp.arange(k, dtype=jnp.int32)

        # full batch (the O(n^2) baseline)
        fb_step = jax.jit(fullbatch.make_fullbatch_step(GAUSS, k))
        assign0 = jnp.zeros((n,), jnp.int32)
        t_fb = _time_step(lambda: fb_step(assign0, x)[0], iters=3)

        # Algorithm 1 (DP, O(n(b+k)))
        dp_step = jax.jit(untruncated.make_dp_step(GAUSS, cfg))
        dps = untruncated.init_dp_state(x, init_idx, GAUSS)
        bidx = sample_batch(jax.random.PRNGKey(0), n, b)
        t_dp = _time_step(lambda: dp_step(dps, x, bidx)[0].sqnorm)

        # Algorithm 2 (truncated, O(k(tau+b)^2), n-independent)
        st = init_state(x, init_idx, GAUSS, window_size(b, tau))
        mb_step = jax.jit(make_step(GAUSS, cfg))
        t_mb = _time_step(lambda: mb_step(st, x, bidx)[0].sqnorm)

        print(f"speedup_fullbatch_n{n},{t_fb:.0f},1.0x")
        print(f"speedup_alg1_n{n},{t_dp:.0f},{t_fb / t_dp:.1f}x")
        print(f"speedup_alg2_n{n},{t_mb:.0f},{t_fb / t_mb:.1f}x")


def bench_n_independence(fast: bool):
    k, b, tau, d = 10, 256, 100, 16
    times = []
    ns = [4096, 16384] if fast else [4096, 16384, 65536]
    for n in ns:
        x, _ = blobs(n=n, d=d, k=k, seed=0)
        x = jnp.asarray(x)
        cfg = MBConfig(k=k, batch_size=b, tau=tau, max_iters=5,
                       epsilon=-1.0)
        st = init_state(x, jnp.arange(k, dtype=jnp.int32), GAUSS,
                        window_size(b, tau))
        step = jax.jit(make_step(GAUSS, cfg))
        bidx = sample_batch(jax.random.PRNGKey(0), n, b)
        t = _time_step(lambda: step(st, x, bidx)[0].sqnorm)
        times.append(t)
        print(f"n_independence_n{n},{t:.0f},iter_time_us")
    ratio = times[-1] / times[0]
    print(f"n_independence_ratio,{ratio:.2f},"
          f"~1.0 expected across {ns[-1] // ns[0]}x n growth")


# ----------------------------------------------------------------- quality
def _mb_fit_ari(xj, kern, k, b, tau, rate, y, seed, iters=80):
    from repro.api import KernelKMeans, SolverConfig

    cfg = SolverConfig(k=k, batch_size=b, tau=tau, rate=rate,
                       max_iters=iters, epsilon=-1.0, kernel=kern,
                       cache="none", distribution="single", jit=False)
    est = KernelKMeans(cfg).fit(xj, key=jax.random.PRNGKey(seed))
    pred = np.asarray(est.predict(xj))
    return (adjusted_rand_index(y, pred), normalized_mutual_info(y, pred))


def bench_quality(fast: bool):
    reps = 2 if fast else 3
    datasets = {
        "blobs": (lambda s: blobs(n=2000, d=16, k=8, seed=s), 8, "gaussian"),
        "circles": (lambda s: circles(n=1500, seed=s), 2, "heat"),
        "moons": (lambda s: moons(n=1500, seed=s), 2, "heat"),
    }
    for dname, (gen, k, kname) in datasets.items():
        rows = {m: [] for m in ["full", "mb_beta", "mb_sklearn",
                                "trunc_beta", "nonkernel_mb"]}
        for s in range(reps):
            x, y = gen(s)
            if kname == "gaussian":
                kern, xj = GAUSS, jnp.asarray(x)
            else:
                kern, xi = heat_kernel(x, k=10, t=2000.0)
                kern = jax.tree.map(jnp.asarray, kern)
                xj = jnp.asarray(xi)
            t0 = time.perf_counter()
            a_fb, _ = fullbatch.fit(xj, kern, k, jax.random.PRNGKey(s),
                                    max_iters=30)
            t_fb = time.perf_counter() - t0
            rows["full"].append(
                (adjusted_rand_index(y, np.asarray(a_fb)), t_fb))
            for rate, row, keep_t in (("beta", "mb_beta", True),
                                      ("sklearn", "mb_sklearn", False)):
                # untruncated mini-batch == Algorithm 1 (DP) — NOT Alg2
                # with a giant window (whose O(k W^2) Gram would explode)
                cfg_u = MBConfig(k=k, batch_size=256, tau=0, rate=rate,
                                 max_iters=80, epsilon=-1.0)
                t0 = time.perf_counter()
                st_u, _ = untruncated.fit(xj, kern, cfg_u,
                                          jax.random.PRNGKey(s),
                                          early_stop=False)
                pred = np.asarray(untruncated.assignments(st_u, xj, kern))
                rows[row].append((adjusted_rand_index(y, pred),
                                  time.perf_counter() - t0 if keep_t
                                  else 0))
            t0 = time.perf_counter()
            ari, _ = _mb_fit_ari(xj, kern, k, 256, 200, "beta", y, s)
            rows["trunc_beta"].append((ari, time.perf_counter() - t0))
            _, assign, _ = lloyd.minibatch_kmeans_fit(
                jnp.asarray(x), k, jax.random.PRNGKey(s), batch_size=256,
                rate="beta", max_iters=80)
            rows["nonkernel_mb"].append(
                (adjusted_rand_index(y, np.asarray(assign)), 0))
        for m, vals in rows.items():
            aris = [v[0] for v in vals]
            ts = [v[1] for v in vals if v[1]]
            tstr = f"{np.mean(ts) * 1e6:.0f}" if ts else ""
            print(f"quality_{dname}_{m},{tstr},"
                  f"ARI={np.mean(aris):.3f}+-{np.std(aris):.3f}")


def bench_tau_sweep(fast: bool):
    x, y = circles(n=1500, seed=0)
    kern, xi = heat_kernel(x, k=10, t=2000.0)
    kern = jax.tree.map(jnp.asarray, kern)
    xj = jnp.asarray(xi)
    for tau in [50, 100, 200, 300]:
        t0 = time.perf_counter()
        ari, nmi = _mb_fit_ari(xj, kern, 2, 256, tau, "beta", y, 0)
        dt = (time.perf_counter() - t0) * 1e6
        print(f"tau_sweep_{tau},{dt:.0f},ARI={ari:.3f}")


def bench_rates(fast: bool):
    """beta vs sklearn, kernel AND non-kernel (fills Schwartzman'23 gap)."""
    x, y = blobs(n=2000, d=16, k=8, seed=1)
    xj = jnp.asarray(x)
    for rate in ["beta", "sklearn"]:
        ari, _ = _mb_fit_ari(xj, GAUSS, 8, 256, 200, rate, y, 0)
        print(f"rates_kernel_{rate},,ARI={ari:.3f}")
        objs = []
        for s in range(2):
            c, a, h = lloyd.minibatch_kmeans_fit(
                xj, 8, jax.random.PRNGKey(s), batch_size=256, rate=rate,
                max_iters=60)
            objs.append(adjusted_rand_index(y, np.asarray(a)))
        print(f"rates_nonkernel_{rate},,ARI={np.mean(objs):.3f}")


def bench_gamma_table(fast: bool):
    """Table 1 reproduction: gamma per dataset x kernel."""
    sets = {"circles": circles(n=1000, seed=0),
            "moons": moons(n=1000, seed=0),
            "blobs": blobs(n=1000, d=16, k=8, seed=0)}
    for dname, (x, _) in sets.items():
        print(f"gamma_{dname}_gaussian,,"
              f"{float(gamma_of(GAUSS, jnp.asarray(x))):.4f}")
        kk, xi = knn_kernel(x, k=10)
        g1 = float(gamma_of(jax.tree.map(jnp.asarray, kk), jnp.asarray(xi)))
        print(f"gamma_{dname}_knn,,{g1:.4f}")
        kh, xih = heat_kernel(x, k=10, t=2000.0)
        g2 = float(gamma_of(jax.tree.map(jnp.asarray, kh),
                            jnp.asarray(xih)))
        print(f"gamma_{dname}_heat,,{g2:.4f}")


def bench_termination(fast: bool):
    """Thm 1(2): iterations to early-stop scale ~ 1/epsilon (gamma = 1)."""
    from repro.api import KernelKMeans, SolverConfig

    x, _ = blobs(n=4000, d=16, k=8, seed=0)
    xj = jnp.asarray(x)
    for eps in [0.04, 0.02, 0.01, 0.005]:
        iters = []
        for s in range(2 if fast else 3):
            cfg = SolverConfig(k=8, batch_size=512, tau=200, epsilon=eps,
                               max_iters=400, kernel=GAUSS, cache="none",
                               distribution="single", jit=False)
            est = KernelKMeans(cfg).fit(xj, key=jax.random.PRNGKey(s))
            iters.append(len(est.history_))
        print(f"termination_eps{eps},,iters={np.mean(iters):.1f}")


# ------------------------------------------------------------ multi-restart
_MULTI_RESTART_SCRIPT = """
import time
import jax, jax.numpy as jnp, numpy as np
from repro.core import MBConfig, Gaussian, fit_jit
from repro.core.engine import MultiRestartEngine
from repro.data import blobs
from repro.launch.mesh import make_restart_mesh

R, REPS = {restarts}, {reps}
assert len(jax.devices()) == 8, jax.devices()
x, _ = blobs(n=4096, d=16, k=8, seed=0)
x = jnp.asarray(x)
kern = Gaussian(kappa=jnp.float32(1.0))
cfg = MBConfig(k=8, batch_size=128, tau=64, max_iters=25, epsilon=-1.0)
init_idx = jnp.arange(8, dtype=jnp.int32) * 100

# single restart via the repo's single-restart entry point (per-call cost,
# including the trace it pays on every invocation)
t0 = time.perf_counter()
_, it = fit_jit(x, kern, cfg, jax.random.PRNGKey(0), init_idx)
jax.block_until_ready(it)
t_single = time.perf_counter() - t0

mesh = make_restart_mesh(R)
eng = MultiRestartEngine(kern, cfg, restarts=R, mesh=mesh, init="random")
r = eng.fit(x, jax.random.PRNGKey(0))
jax.block_until_ready(r.objectives)          # one-time compile
t0 = time.perf_counter()
for _ in range(REPS):
    r = eng.fit(x, jax.random.PRNGKey(0))
    jax.block_until_ready(r.objectives)
t_multi = (time.perf_counter() - t0) / REPS

e1 = MultiRestartEngine(kern, cfg, restarts=1, init="random")
r1 = e1.fit(x, jax.random.PRNGKey(0))
jax.block_until_ready(r1.objectives)
t0 = time.perf_counter()
for _ in range(REPS):
    r1 = e1.fit(x, jax.random.PRNGKey(0))
    jax.block_until_ready(r1.objectives)
t_one = (time.perf_counter() - t0) / REPS

print(f"multi_restart_single_call,{{t_single * 1e6:.0f}},"
      f"one fit_jit restart per-call")
print(f"multi_restart_engine_R{{R}},{{t_multi * 1e6:.0f}},"
      f"{{t_multi / t_single:.2f}}x_vs_single_call "
      f"({{mesh.devices.size}}dev best-of-{{R}})")
print(f"multi_restart_amortized_R{{R}}_vs_R1,{{t_multi * 1e6:.0f}},"
      f"{{t_multi / t_one:.2f}}x_vs_compiled_R1")
"""


def bench_multi_restart(fast: bool):
    """Engine claim: best-of-R fit in ONE compiled program is cheaper than
    2x a single restart as invoked today (fit_jit re-traces per call; the
    engine compiles once and vmaps the R fits).  Runs in a subprocess on 8
    virtual CPU devices so the restart axis really shards."""
    import os
    import subprocess
    import sys

    script = _MULTI_RESTART_SCRIPT.format(restarts=4, reps=2 if fast else 4)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env, cwd=root,
                       capture_output=True, text=True, timeout=600)
    if r.returncode != 0:
        print(f"# multi_restart FAILED: {r.stderr[-500:]}")
        return
    print(r.stdout, end="")


# ------------------------------------------------------------ fused restarts
_FUSED_RESTARTS_SCRIPT = """
import json, os, time
import jax, jax.numpy as jnp
from repro.api import KernelKMeans, SolverConfig
from repro.api import keys as api_keys
from repro.core import Gaussian
from repro.core.engine import make_init_run
from repro.data import blobs
from repro.launch.mesh import make_fused_mesh

R, REPS, ITERS = {restarts}, {reps}, {iters}
assert len(jax.devices()) == 8, jax.devices()
x, _ = blobs(n=4096, d=16, k=8, seed=0)
x = jnp.asarray(x)
kern = Gaussian(kappa=jnp.float32(1.0))
base = dict(k=8, batch_size=128, tau=64, max_iters=ITERS, epsilon=-1.0,
            kernel=kern, distribution="sharded", cache="none", jit=True)
key = jax.random.PRNGKey(0)

# both arms get the SAME precomputed (R, k) init indices, so the timed
# comparison is R fits (+ the fused plan's on-device winner selection,
# which is part of its deliverable) — not init-draw asymmetry
k_init, k_fit, k_eval = api_keys.restart_keys(key)
fit_keys = api_keys.per_restart(k_fit, R)
mb = SolverConfig(**base).mb_config()
init_idx = make_init_run(kern, mb, "kmeans++")(
    api_keys.per_restart(k_init, R), x)
jax.block_until_ready(init_idx)

# fused: R restarts x data x model in ONE compiled program
mesh = make_fused_mesh(R)
fused = KernelKMeans(SolverConfig(restarts=R, **base), mesh=mesh)
fused.fit(x, key, init_idx=init_idx)                 # compile
jax.block_until_ready(fused.result_.objectives)
assert fused.plan_.name == "fused_restart_sharded"

def best_of(fn, reps):
    # min over reps: robust to scheduler jitter on oversubscribed CI
    # hosts (8 virtual devices on ~2 cores), unlike a 2-rep mean
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)

def run_fused():
    fused.fit(x, key, init_idx=init_idx)
    jax.block_until_ready(fused.result_.objectives)

t_fused = best_of(run_fused, REPS)

# sequential baseline: the SAME R per-restart fits, one compiled sharded
# program per restart invoked back to back on all 8 devices (compiled
# program cached across calls — the fairest non-fused configuration)
mesh2 = jax.make_mesh((4, 2), ("data", "model"))
seq = KernelKMeans(SolverConfig(**base), mesh=mesh2)
ex = seq.plan_for(x.shape[0]).executor

def run_seq():
    for r in range(R):
        out = ex.fit(x, fit_keys[r], center_pts=x[init_idx[r]],
                     always_split=False)
        jax.block_until_ready(out.state.sqnorm)

run_seq()                                            # compile
t_seq = best_of(run_seq, REPS)

speedup = t_seq / t_fused
root = {root!r}
import sys
sys.path.insert(0, root)
from benchmarks.run import bench_env
out = dict(
    env=bench_env(seed=0),
    workload=dict(n=4096, d=16, k=8, batch_size=128, tau=64, iters=ITERS,
                  restarts=R, devices=8,
                  fused_mesh=list(mesh.devices.shape),
                  sequential_mesh=list(mesh2.devices.shape)),
    fused_ms=t_fused * 1e3, sequential_ms=t_seq * 1e3,
    speedup_x=speedup, plan="fused_restart_sharded",
    fused_faster=bool(t_fused < t_seq))
with open(os.path.join(root, "BENCH_fused_restarts.json"), "w") as f:
    json.dump(out, f, indent=2)
print(f"fused_restarts_sequential_R{{R}},{{t_seq * 1e6:.0f}},"
      f"R_sharded_fits_back_to_back")
print(f"fused_restarts_fused_R{{R}},{{t_fused * 1e6:.0f}},"
      f"{{speedup:.2f}}x_vs_sequential ({{mesh.devices.shape}} mesh)")
assert t_fused < t_seq, (
    f"fused {{t_fused * 1e3:.1f}}ms not faster than sequential "
    f"{{t_seq * 1e3:.1f}}ms")
"""


def bench_fused_restarts(fast: bool):
    """Tentpole claim: R restarts of the SHARDED step fused into one
    compiled program on a ("restart", "data", "model") mesh beat R
    back-to-back sharded fits (same per-restart keys, compiled programs
    cached in both arms).  Writes BENCH_fused_restarts.json; runs on 8
    virtual CPU devices in a subprocess so the restart axis really
    shards."""
    import os
    import subprocess
    import sys

    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    script = _FUSED_RESTARTS_SCRIPT.format(
        restarts=4, reps=2 if fast else 4, iters=15 if fast else 25,
        root=root)
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(root, "src")
    r = subprocess.run([sys.executable, "-c", script], env=env, cwd=root,
                       capture_output=True, text=True, timeout=600)
    if r.returncode != 0:
        print(f"# fused_restarts FAILED: {r.stderr[-500:]}")
        raise SystemExit(1)
    print(r.stdout, end="")


# ------------------------------------------------------------ kernel cache
def bench_kernel_cache(fast: bool):
    """Gram tile cache (repro.cache): cached vs uncached fit + predict on a
    repeated-row workload.  Kernel-evaluation counts are MEASURED for the
    cached path (every miss = tile x n evals, from the cache counters) and
    analytic for the uncached path (per Algorithm-2 step: b*kW assignment +
    k*W^2 sqnorm recompute + b*kW direct eval; per predict query: kW).
    Writes machine-readable BENCH_kernel_cache.json at the repo root."""
    import json
    import os

    from repro.cache import predict_cached, stats
    from repro.core import fit, predict
    from repro.core.minibatch import fit_cached
    from repro.core.state import window_size as _wsz

    n = 2048 if fast else 4096
    d, k, b, tau = 16, 8, 256, 64
    iters = 10 if fast else 25
    tile = n // 16
    capacity = 16            # covers every row block: steady state = 0 miss
    reps = 4                 # 4x repeated-row query stream
    x, _ = blobs(n=n, d=d, k=k, seed=0)
    x = jnp.asarray(x)
    cfg = MBConfig(k=k, batch_size=b, tau=tau, max_iters=iters, epsilon=-1.0)
    init_idx = (jnp.arange(k, dtype=jnp.int32) * (n // k))
    kw = k * _wsz(b, tau)
    key = jax.random.PRNGKey(0)

    # --- uncached fit + predict --------------------------------------------
    t0 = time.perf_counter()
    st_u, hist_u = fit(x, GAUSS, cfg, key, init_idx=init_idx,
                       early_stop=False)
    jax.block_until_ready(st_u.sqnorm)
    t_fit_u = time.perf_counter() - t0
    evals_fit_u = len(hist_u) * (2 * b * kw + k * _wsz(b, tau) ** 2)

    qidx = jnp.tile(jnp.arange(n, dtype=jnp.int32), reps)
    xq = x[qidx]
    predict(st_u, x, xq, GAUSS).block_until_ready()   # warm compile
    t0 = time.perf_counter()
    pred_u = predict(st_u, x, xq, GAUSS)
    pred_u.block_until_ready()
    t_pred_u = time.perf_counter() - t0
    evals_pred_u = int(qidx.shape[0]) * kw

    # --- cached fit + predict (nested sampler raises the hit rate) ---------
    t0 = time.perf_counter()
    st_c, hist_c, ck = fit_cached(x, GAUSS, cfg, key, tile=tile,
                                  capacity=capacity, init_idx=init_idx,
                                  sampler="nested", early_stop=False)
    jax.block_until_ready(st_c.sqnorm)
    t_fit_c = time.perf_counter() - t0
    s_fit = stats(ck.cache)

    # warm compile WITHOUT threading the returned state, so the final
    # counters reflect the fit plus exactly ONE predict pass
    predict_cached(ck, st_c, qidx)[0].block_until_ready()
    t0 = time.perf_counter()
    pred_c, ck = predict_cached(ck, st_c, qidx)
    pred_c.block_until_ready()
    t_pred_c = time.perf_counter() - t0
    s_all = stats(ck.cache)

    evals_u = evals_fit_u + evals_pred_u
    evals_c = max(s_all["evals"], 1)
    reduction = evals_u / evals_c
    # The counters only see stateful (warm/insert) lookups; read-through
    # hits/misses inside the step are uncounted.  With capacity covering
    # every row block AND zero evictions, a block warmed once stays
    # resident forever, so every read-through access after its warm is a
    # hit — i.e. the measured miss count is the COMPLETE kernel-eval count.
    counters_complete = (s_all["evictions"] == 0
                         and capacity >= n // tile)
    assert counters_complete, (
        "eval accounting incomplete (evictions occurred); resize capacity")
    # numerical-equivalence check: same (cached-fit) state served through
    # the cache vs direct kernel evaluation — must agree exactly.  (pred_u
    # is a DIFFERENT fit — the uncached baseline uses the uniform sampler —
    # so it is only the timing/eval-count reference.)
    pred_ref = predict(st_c, x, xq, GAUSS)
    agree = float(jnp.mean((pred_ref == pred_c).astype(jnp.float32)))
    out = {
        "env": bench_env(seed=0),
        "workload": dict(n=n, d=d, k=k, batch_size=b, tau=tau, iters=iters,
                         tile=tile, capacity=capacity,
                         queries=int(qidx.shape[0]), sampler="nested",
                         fast=fast),
        "fit": dict(time_ms_uncached=t_fit_u * 1e3,
                    time_ms_cached=t_fit_c * 1e3,
                    evals_uncached=evals_fit_u, evals_cached=s_fit["evals"],
                    hits=s_fit["hits"], misses=s_fit["misses"],
                    evictions=s_fit["evictions"],
                    hit_rate=s_fit["hit_rate"]),
        "predict": dict(time_ms_uncached=t_pred_u * 1e3,
                        time_ms_cached=t_pred_c * 1e3,
                        evals_uncached=evals_pred_u,
                        label_agreement_same_state=agree),
        "totals": dict(evals_uncached=evals_u, evals_cached=evals_c,
                       eval_reduction_x=reduction,
                       hit_rate=s_all["hit_rate"],
                       counters_complete=counters_complete),
    }
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_kernel_cache.json"), "w") as f:
        json.dump(out, f, indent=2)
    print(f"kernel_cache_fit_uncached,{t_fit_u * 1e6:.0f},"
          f"{evals_fit_u}_evals")
    print(f"kernel_cache_fit_cached,{t_fit_c * 1e6:.0f},"
          f"{s_fit['evals']}_evals_hit_rate={s_fit['hit_rate']:.2f}")
    print(f"kernel_cache_predict_uncached,{t_pred_u * 1e6:.0f},"
          f"{evals_pred_u}_evals")
    print(f"kernel_cache_predict_cached,{t_pred_c * 1e6:.0f},"
          f"agreement={agree:.4f}")
    print(f"kernel_cache_reduction,,{reduction:.1f}x_fewer_kernel_evals")


# --------------------------------------------------------------- step fuse
def bench_step_fuse(fast: bool):
    """PR-5 tentpole gate: the streaming fused step (`step="fused"` —
    online-argmin assignment, slab-chunked sqnorm recompute, no
    materialized (b, k*W) strip) must beat the composed op chain on BOTH
    wall-clock and peak per-step temp memory (XLA compiled memory
    analysis), while staying bit-identical at f32.  Writes
    BENCH_step_fuse.json; asserted, so CI gates on it.

    The shape is assignment-dominated (k large, tau small relative to b):
    that is the regime the paper's O(k b (tau+b)) term governs and where
    the strip the fused step never materializes is the dominant
    intermediate."""
    import json
    import os

    from repro.core.minibatch import make_step
    from repro.core.state import init_state, window_size

    if fast:
        n, d, k, b, tau, reps = 4096, 32, 32, 512, 64, 3
    else:
        n, d, k, b, tau, reps = 8192, 64, 64, 1024, 64, 5
    x, _ = blobs(n=n, d=d, k=min(k, 16), seed=0)
    x = jnp.asarray(x)
    init_idx = (jnp.arange(k, dtype=jnp.int32) * 17) % n
    bidx = sample_batch(jax.random.PRNGKey(0), n, b)

    results = {}
    outs = {}
    for impl in ("composed", "fused"):
        cfg = MBConfig(k=k, batch_size=b, tau=tau, max_iters=5,
                       epsilon=-1.0, step=impl)
        st0 = init_state(x, init_idx, GAUSS, window_size(b, tau))
        step = jax.jit(make_step(GAUSS, cfg))
        temp_bytes = step.lower(st0, x, bidx).compile() \
            .memory_analysis().temp_size_in_bytes
        out = step(st0, x, bidx)
        jax.block_until_ready(out[0].sqnorm)        # compile + warm
        times = []
        for _ in range(reps):
            t0 = time.perf_counter()
            out = step(st0, x, bidx)
            jax.block_until_ready(out[0].sqnorm)
            times.append(time.perf_counter() - t0)
        results[impl] = (min(times), temp_bytes)
        outs[impl] = out
        print(f"step_fuse_{impl},{min(times) * 1e6:.0f},"
              f"temp_{temp_bytes / 1e6:.0f}MB")

    bit_identical = bool(
        np.array_equal(np.asarray(outs["composed"][0].sqnorm),
                       np.asarray(outs["fused"][0].sqnorm))
        and np.array_equal(np.asarray(outs["composed"][0].idx),
                           np.asarray(outs["fused"][0].idx))
        and np.array_equal(np.asarray(outs["composed"][1].improvement),
                           np.asarray(outs["fused"][1].improvement)))
    t_c, m_c = results["composed"]
    t_f, m_f = results["fused"]
    out = dict(
        env=bench_env(seed=0),
        workload=dict(n=n, d=d, k=k, batch_size=b, tau=tau,
                      window=tau + b, reps=reps, fast=fast,
                      backend=jax.default_backend()),
        composed=dict(step_ms=t_c * 1e3, temp_bytes=m_c),
        fused=dict(step_ms=t_f * 1e3, temp_bytes=m_f),
        speedup_x=t_c / t_f, temp_reduction_x=m_c / max(m_f, 1),
        bit_identical=bit_identical,
        fused_faster=bool(t_f < t_c),
        fused_smaller=bool(m_f < m_c))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_step_fuse.json"), "w") as f:
        json.dump(out, f, indent=2)
    print(f"step_fuse_speedup,,{t_c / t_f:.2f}x_wall_clock")
    print(f"step_fuse_temp_reduction,,{m_c / max(m_f, 1):.2f}x_peak_temp")
    assert bit_identical, "fused step diverged from composed at f32"
    assert t_f < t_c, (f"fused {t_f * 1e3:.0f}ms not faster than "
                       f"composed {t_c * 1e3:.0f}ms")
    assert m_f < m_c, (f"fused temp {m_f} not below composed {m_c}")


# ------------------------------------------------------------- api overhead
def bench_api_overhead(fast: bool):
    """Estimator-vs-direct parity: KernelKMeans dispatch must resolve at
    trace time, so a repeat `fit` through the estimator (compiled program
    cached on the executor) costs the same as invoking a hand-built jitted
    while_loop — zero per-step Python overhead.  Also reports the legacy
    fit_jit per-call cost (which re-traces every invocation) for contrast.
    """
    import warnings

    from repro.api import KernelKMeans, SolverConfig
    from repro.core.minibatch import (
        make_step, run_early_stopped, sampled_step_with_key)
    from repro.core.state import init_state, window_size

    n = 2048 if fast else 4096
    k, b, tau, d = 8, 128, 64, 16
    iters, reps = 25, 3 if fast else 6
    x, _ = blobs(n=n, d=d, k=k, seed=0)
    x = jnp.asarray(x)
    mb = MBConfig(k=k, batch_size=b, tau=tau, max_iters=iters, epsilon=-1.0)
    init_idx = jnp.arange(k, dtype=jnp.int32) * (n // k)
    key = jax.random.PRNGKey(0)

    # direct baseline: hand-built compiled loop, traced once
    w = window_size(b, tau)
    step = make_step(GAUSS, mb)

    @jax.jit
    def direct(x, init_idx, key):
        state0 = init_state(x, init_idx, GAUSS, w)
        return run_early_stopped(mb, sampled_step_with_key(step, x, mb),
                                 state0, key)

    jax.block_until_ready(direct(x, init_idx, key)[0].sqnorm)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(direct(x, init_idx, key)[0].sqnorm)
    t_direct = (time.perf_counter() - t0) / reps

    # estimator: same plan point, compiled program cached on the executor
    from repro.api.executors import program_builds

    est = KernelKMeans(SolverConfig(
        k=k, batch_size=b, tau=tau, max_iters=iters, epsilon=-1.0,
        kernel=GAUSS, cache="none", distribution="single", jit=True))
    est.fit(x, key, init_idx=init_idx)                        # compile
    jax.block_until_ready(est.state_.sqnorm)
    builds_before = program_builds()
    t0 = time.perf_counter()
    for _ in range(reps):
        est.fit(x, key, init_idx=init_idx)
        jax.block_until_ready(est.state_.sqnorm)
    t_est = (time.perf_counter() - t0) / reps
    rebuilds = program_builds() - builds_before

    # legacy fit_jit: pays a re-trace on every call (the cost the
    # estimator's cached executor removes)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        from repro.core import fit_jit
        jax.block_until_ready(
            fit_jit(x, GAUSS, mb, key, init_idx)[0].sqnorm)
        t0 = time.perf_counter()
        jax.block_until_ready(
            fit_jit(x, GAUSS, mb, key, init_idx)[0].sqnorm)
        t_legacy = time.perf_counter() - t0

    ratio = t_est / t_direct
    print(f"api_overhead_direct,{t_direct * 1e6:.0f},compiled_loop")
    print(f"api_overhead_estimator,{t_est * 1e6:.0f},"
          f"{ratio:.2f}x_vs_direct")
    print(f"api_overhead_repeat_builds,{rebuilds},programs_rebuilt")
    print(f"api_overhead_legacy_fit_jit,{t_legacy * 1e6:.0f},"
          f"{t_legacy / t_direct:.2f}x_vs_direct (per-call retrace)")
    assert ratio < 1.5, (
        f"estimator dispatch overhead {ratio:.2f}x vs direct compiled "
        "call — plan dispatch must resolve at trace time")
    assert rebuilds == 0, (
        f"{rebuilds} compiled programs rebuilt across {reps} repeat fits "
        "— the loop-core program cache must hold them flat (the PR-5 "
        "contract, re-pinned after the PR-9 loop-core refactor)")


# ----------------------------------------------------------------- service
def bench_service(fast: bool):
    """PR-7 serving gate (docs/serving.md): the learner/actor split must
    serve microbatched ``predict`` at p99 <= 2x a bare ``predict`` call at
    the same bucket shape, with ZERO recompiles after warmup (both the
    cross-executor ``program_builds()`` counter and the actor's own
    ``serve_compiles``), and keep serving across atomic snapshot swaps
    with the load+warm pause bounded and reported.  Writes
    BENCH_service.json; asserted, so CI gates on it.

    Three phases: (1) learner rounds — the resume program must compile
    once and stay flat; (2) steady-state closed-loop serving — latency vs
    the bare baseline; (3) snapshot churn — a publisher thread pushes new
    versions while the closed loop keeps serving, exercising the
    off-serving-path swap."""
    import json
    import os
    import tempfile
    import threading

    from repro.api.executors import program_builds
    from repro.service.demo import build_service
    from repro.service.telemetry import LatencyWindow

    if fast:
        capacity, b, tau, k, d = 1024, 128, 64, 8, 16
        bucket, rounds, reps_bare, warm_reqs, measured = 256, 4, 40, 8, 80
        n_swaps = 2
    else:
        capacity, b, tau, k, d = 2048, 256, 128, 8, 16
        bucket, rounds, reps_bare, warm_reqs, measured = 512, 6, 60, 16, 250
        n_swaps = 3

    with tempfile.TemporaryDirectory(prefix="repro_bench_svc_") as snapdir:
        learner, actor, store, buf, _ = build_service(
            snapdir, k=k, d=d, capacity=capacity, batch_size=b, tau=tau,
            iters_per_round=2, publish_every=2, buckets=(bucket,),
            queue_depth=64, max_wait_ms=0.5)
        actor.poll_every_s = 0.05           # snappy swap pickup

        # phase 1: learner rounds; the partial_fit resume program must
        # compile on round 1 and never again (fixed buffer shape)
        builds_per_round = []
        learner.on_round = lambda r: builds_per_round.append(
            program_builds())
        learner.run(rounds)
        assert builds_per_round[-1] == builds_per_round[1], (
            f"resume program rebuilt across rounds: {builds_per_round}")
        print(f"service_fit_builds,,"
              f"{builds_per_round[-1]}_flat_after_round_1")

        # bare baseline: the same assignment at the same (bucket, d)
        # shape, no queue/pad/thread in the way
        _, est_bare = store.load()
        rng = np.random.default_rng(123)
        queries = [rng.normal(0, 1, (bucket, d)).astype(np.float32)
                   for _ in range(8)]
        np.asarray(est_bare.predict(queries[0]))          # compile + warm
        bare = []
        for i in range(reps_bare):
            t0 = time.perf_counter()
            np.asarray(est_bare.predict(queries[i % len(queries)]))
            bare.append((time.perf_counter() - t0) * 1e3)
        bare_p50, bare_p99 = (float(np.percentile(bare, q))
                              for q in (50, 99))

        # actor warmup, then freeze the compile counters
        actor.start()
        for i in range(warm_reqs):
            actor.predict(queries[i % len(queries)])
        builds_warm = program_builds()
        serve_warm = actor.serve_compiles

        # phase 2: steady-state closed loop — full-bucket requests, so no
        # coalesce wait and no padding; latency is queue + serve + scatter
        actor.latency = LatencyWindow()
        t0 = time.perf_counter()
        for i in range(measured):
            actor.predict(queries[i % len(queries)])
        wall = time.perf_counter() - t0
        micro = actor.latency.percentiles()
        qps_rows = measured * bucket / wall

        # phase 3: snapshot churn while serving — the swapper thread
        # loads + warms off the serving path; the closed loop must keep
        # completing requests throughout
        base_v = store.latest_version()

        def _publish():
            for j in range(n_swaps):
                time.sleep(0.25)
                store.publish(learner.est, base_v + j + 1)

        swaps_before = actor.swaps
        actor.latency = LatencyWindow()
        pub = threading.Thread(target=_publish, daemon=True)
        pub.start()
        served_churn = 0
        t0 = time.perf_counter()
        while (actor.swaps - swaps_before < n_swaps
               and time.perf_counter() - t0 < 30.0):
            actor.predict(queries[served_churn % len(queries)])
            served_churn += 1
        pub.join(10.0)
        churn = actor.latency.percentiles()
        swaps_during = actor.swaps - swaps_before
        pause_ms = actor.last_swap_pause_ms
        builds_end = program_builds()
        serve_end = actor.serve_compiles
        actor.stop()

    ratio = micro["p99"] / bare_p99
    print(f"service_bare_predict,{bare_p50 * 1e3:.0f},"
          f"p99={bare_p99:.2f}ms")
    print(f"service_microbatch,{micro['p50'] * 1e3:.0f},"
          f"p99={micro['p99']:.2f}ms {ratio:.2f}x_bare "
          f"{qps_rows:.0f}rows_per_s")
    print(f"service_swap,,{swaps_during}_swaps "
          f"pause={pause_ms:.0f}ms served_during={served_churn}")

    out = dict(
        env=bench_env(seed=0),
        workload=dict(k=k, d=d, capacity=capacity, batch_size=b, tau=tau,
                      bucket=bucket, rounds=rounds, fast=fast,
                      backend=jax.default_backend()),
        fit_builds_per_round=builds_per_round,
        bare_ms=dict(p50=bare_p50, p99=bare_p99, reps=reps_bare),
        micro_ms=dict(p50=micro["p50"], p99=micro["p99"],
                      count=micro["count"]),
        micro_over_bare_p99=ratio,
        qps_rows=qps_rows,
        qps_requests=measured / wall,
        swap=dict(swaps=swaps_during, last_pause_ms=pause_ms,
                  served_during_churn=served_churn,
                  p99_during_churn_ms=churn["p99"]),
        programs=dict(fit_builds=builds_end, serve_compiles=serve_end,
                      recompiles_after_warmup=(builds_end - builds_warm)
                      + (serve_end - serve_warm)))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_service.json"), "w") as f:
        json.dump(out, f, indent=2)

    assert ratio <= 2.0, (
        f"microbatched p99 {micro['p99']:.2f}ms is {ratio:.2f}x the bare "
        f"predict p99 {bare_p99:.2f}ms at the same ({bucket}, {d}) shape")
    assert builds_end == builds_warm and serve_end == serve_warm, (
        f"recompiles after warmup: fit {builds_warm}->{builds_end}, "
        f"serve {serve_warm}->{serve_end}")
    assert swaps_during >= 1, "no snapshot swap observed while serving"
    assert pause_ms is not None and pause_ms < 10_000, (
        f"snapshot swap load+warm took {pause_ms}ms")
    assert served_churn > 0, "serving stalled during snapshot churn"


# --------------------------------------------------------------- landmark
def bench_landmark(fast: bool):
    """Landmark-compression gate (docs/compression.md): on an unbounded
    stream (the ``grow_window`` no-eviction baseline, support never
    truncated) serving cost grows linearly with fit history, while
    round-cadence Nystrom compression pins it at O(k*m) — predict latency
    must stay flat (<= 1.1x round 1) as the uncompressed arm's grows, and
    the compressed objective on a held-out eval batch must stay within 5%
    of the uncompressed run's.  Writes BENCH_landmark.json; asserted, so
    CI gates on it.

    Both arms run the SAME batch schedule from the SAME init; the only
    difference is what happens between rounds: grow the window (baseline)
    vs project onto m landmarks (compressed)."""
    import json
    import os

    from repro.core.minibatch import assign_chunked, center_distances_chunked
    from repro.landmark import CompressSpec, compress_state, grow_window

    if fast:
        n, d, k, b, tau = 8192, 16, 8, 128, 64
        rounds, iters, m, grow, reps, nq = 10, 6, 32, 96, 8, 2048
    else:
        n, d, k, b, tau = 16384, 32, 16, 256, 128
        rounds, iters, m, grow, reps, nq = 12, 8, 64, 192, 10, 4096

    x, _ = blobs(n=n, d=d, k=k, seed=0)
    x = jnp.asarray(x)
    xe, _ = blobs(n=nq, d=d, k=k, seed=1)          # held-out eval batch
    xe = jnp.asarray(xe)
    w0 = window_size(b, tau)
    init_idx = (jnp.arange(k, dtype=jnp.int32) * 31) % n
    cfg = MBConfig(k=k, batch_size=b, tau=tau, max_iters=iters,
                   epsilon=-1.0)
    spec = CompressSpec(every=0, m=m)
    key = jax.random.PRNGKey(42)
    assign = jax.jit(assign_chunked, static_argnames=("chunk",))
    dists = jax.jit(center_distances_chunked, static_argnames=("chunk",))

    def run_round(st, rnd):
        # both arms share this schedule; the step program is rebuilt per
        # window width in the grown arm (learner-side cost, not timed)
        step = jax.jit(make_step(GAUSS, cfg))
        for i in range(iters):
            bidx = sample_batch(jax.random.fold_in(key, rnd * iters + i),
                                n, b)
            st, _ = step(st, x, bidx)
        return st

    def time_rounds(servings):
        """Per-round best-of-``reps`` predict latency (ms).  Reps are
        INTERLEAVED round-robin across rounds so slow machine periods hit
        every round equally — the per-round minima then reflect shape
        cost, not when in the run a round happened to be timed."""
        for coef, sqnorm, sup in servings:          # compile + warm all
            jax.block_until_ready(assign(GAUSS, coef, sqnorm, sup, xe,
                                         4096))
        times = [[] for _ in servings]
        for _ in range(reps):
            for i, (coef, sqnorm, sup) in enumerate(servings):
                t0 = time.perf_counter()
                jax.block_until_ready(assign(GAUSS, coef, sqnorm, sup,
                                             xe, 4096))
                times[i].append(time.perf_counter() - t0)
        return [min(t) * 1e3 for t in times]

    def objective(coef, sqnorm, sup):
        dd = dists(GAUSS, coef, sqnorm, sup, xe, 4096)
        return float(jnp.mean(jnp.min(dd, axis=1)))

    # ---- uncompressed arm: fit, then widen the window every round
    st_u = init_state(x, init_idx, GAUSS, w0)
    servings_u, rows_u = [], []
    for rnd in range(rounds):
        st_u = run_round(st_u, rnd)
        sup = x[st_u.idx.reshape(-1)]
        servings_u.append((st_u.coef, st_u.sqnorm, sup))
        rows_u.append(int(sup.shape[0]))
        if rnd < rounds - 1:
            st_u = grow_window(st_u, grow)
    obj_u = objective(*servings_u[-1])

    # ---- compressed arm: same schedule at fixed W, project onto m
    # landmarks every round and serve the O(k*m) representation
    st_c = init_state(x, init_idx, GAUSS, w0)
    servings_c, drifts = [], []
    for rnd in range(rounds):
        st_c = run_round(st_c, rnd)
        st_c, info = compress_state(GAUSS, st_c, spec, x=x)
        jax.block_until_ready(st_c.coef)
        drifts.append(float(info.drift_bound))
        # after compression only the first m slots are live — that slice
        # IS the CompressedKernelCenters serving tuple
        servings_c.append((st_c.coef[:, :m], st_c.sqnorm,
                           x[st_c.idx[:, :m].reshape(-1)]))
    obj_c = objective(*servings_c[-1])

    lat_u = time_rounds(servings_u)
    lat_c = time_rounds(servings_c)

    growth_u = lat_u[-1] / lat_u[0]
    growth_c = lat_c[-1] / lat_c[0]
    obj_gap = abs(obj_c - obj_u) / max(abs(obj_u), 1e-12)
    print(f"landmark_uncompressed,{lat_u[-1] * 1e3:.0f},"
          f"{growth_u:.2f}x_round1 rows={rows_u[0]}->{rows_u[-1]}")
    print(f"landmark_compressed,{lat_c[-1] * 1e3:.0f},"
          f"{growth_c:.2f}x_round1 rows={k * m} m={m}")
    print(f"landmark_objective,,gap={obj_gap:.4f} "
          f"drift_bound={max(drifts):.3f}")

    out = dict(
        env=bench_env(seed=42),
        workload=dict(n=n, d=d, k=k, batch_size=b, tau=tau, window=w0,
                      rounds=rounds, iters_per_round=iters, m=m,
                      grow_per_round=grow, eval_rows=nq, reps=reps,
                      fast=fast),
        uncompressed=dict(predict_ms=lat_u, support_rows=rows_u,
                          latency_growth_x=growth_u, objective=obj_u),
        compressed=dict(predict_ms=lat_c, support_rows=k * m,
                        latency_growth_x=growth_c, objective=obj_c,
                        drift_bounds=drifts),
        objective_gap=obj_gap,
        compression_ratio=m / (w0 + (rounds - 1) * grow))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_landmark.json"), "w") as f:
        json.dump(out, f, indent=2)

    assert growth_c <= 1.1, (
        f"compressed predict latency grew {growth_c:.2f}x over "
        f"{rounds} rounds (must stay flat <= 1.1x round 1)")
    assert growth_u > 1.1, (
        f"uncompressed baseline only grew {growth_u:.2f}x — the no-"
        f"eviction arm is not exercising unbounded support growth")
    assert obj_gap <= 0.05, (
        f"compressed objective {obj_c:.4f} deviates {obj_gap:.1%} from "
        f"uncompressed {obj_u:.4f} on the held-out batch (> 5%)")


# ------------------------------------------------------------------ chaos
def bench_chaos(fast: bool):
    """PR-10 robustness gate (docs/robustness.md): the service under a
    deterministic injected fault schedule must (1) recover a learner
    carry BIT-IDENTICAL to the fault-free run — crashes, hung steps and
    a corrupt checkpoint included, (2) reproduce the exact same fault
    trace when the same plan seed is run twice, (3) lose ZERO admitted
    requests and never swap a corrupt snapshot in during an actor soak
    with corrupt publishes + transient swap/serve IOErrors, with p99
    bounded throughout.  Writes BENCH_chaos.json; asserted, so CI gates
    on it."""
    import json
    import os
    import tempfile

    from repro.service import FaultPlan, FaultRule
    from repro.service.demo import build_service

    if fast:
        k, d, capacity, b, tau = 4, 8, 128, 32, 16
        rounds, soak_reqs, bucket = 8, 40, 64
    else:
        k, d, capacity, b, tau = 4, 8, 256, 32, 16
        rounds, soak_reqs, bucket = 10, 80, 64

    svc_kw = dict(k=k, d=d, capacity=capacity, batch_size=b, tau=tau,
                  iters_per_round=2, arrivals_per_step=64,
                  buckets=(bucket,), publish_every=2)

    def leaves(carry):
        return [np.asarray(x) for x in jax.tree.leaves(carry)]

    # ---- phase 1: fault-free reference carry
    with tempfile.TemporaryDirectory(prefix="repro_chaos_ref_") as sd:
        l_ref, *_ = build_service(sd, **svc_kw)
        carry_ref = l_ref.run(rounds)

    # ---- phase 2: crash + hung step + corrupt checkpoint, twice.
    # The schedule: the 2nd publish is byte-corrupted on disk, a crash
    # hits step 5 (so the restore must FALL BACK past the corrupt v4 to
    # v2), and a 120s hang hits a later step (so only the WATCHDOG can
    # save the run).  Recovery must converge bit-identically, and the
    # same seed must fire the same trace both times.
    def chaos_run(sd):
        plan = FaultPlan([
            FaultRule("snapshot.publish", "corrupt", at=(1,)),
            FaultRule("learner.step", "crash", at=(5,)),
            FaultRule("learner.step", "hang", at=(9,), delay_s=120.0),
        ], seed=42)
        l, _, store, *_ = build_service(sd, faults=plan,
                                        step_timeout_s=10.0, **svc_kw)
        carry = l.run(rounds, max_restarts=5)
        return carry, plan.trace_list(), l.stats(), store

    with tempfile.TemporaryDirectory(prefix="repro_chaos_a_") as sd:
        carry_a, trace_a, stats_a, store_a = chaos_run(sd)
        quarantined_a = store_a.quarantined
    with tempfile.TemporaryDirectory(prefix="repro_chaos_b_") as sd:
        carry_b, trace_b, _, _ = chaos_run(sd)

    bit_identical = all(
        np.array_equal(x, y) for x, y in zip(leaves(carry_ref),
                                             leaves(carry_a)))
    replayed = all(
        np.array_equal(x, y) for x, y in zip(leaves(carry_a),
                                             leaves(carry_b)))
    print(f"chaos_recovery,,bit_identical={bit_identical} "
          f"watchdog={stats_a['watchdog_fires']} "
          f"fallbacks={stats_a['restore_fallbacks']} "
          f"restores={stats_a['restores']}")
    print(f"chaos_replay,,trace_len={len(trace_a)} "
          f"identical={trace_a == trace_b}")

    # ---- phase 3: actor soak under corrupt publishes + transient
    # swap/serve IOErrors.  `at`-indexed transients guarantee the retry
    # (occurrence+1) succeeds, so every admitted request must complete.
    soak_plan = FaultPlan([
        FaultRule("snapshot.publish", "corrupt", every=3, max_fires=2),
        FaultRule("actor.swap", "io", at=(1,)),
        FaultRule("actor.serve", "io", at=(2, 7, 13)),
    ], seed=7)
    lost = served = 0
    with tempfile.TemporaryDirectory(prefix="repro_chaos_soak_") as sd:
        soak_kw = dict(svc_kw, publish_every=1)
        l, actor, store, buf, _ = build_service(sd, faults=soak_plan,
                                                **soak_kw)
        actor.poll_every_s = 0.05
        actor.serve_retries = 2
        l.run(2)                        # first snapshots exist
        l.start(rounds)                 # keep publishing (some corrupt)
        actor.start()
        rng = np.random.default_rng(123)
        queries = [rng.normal(0, 1, (bucket, d)).astype(np.float32)
                   for _ in range(8)]
        pending = []
        for i in range(soak_reqs):
            pending.append(actor.submit(queries[i % len(queries)]))
            if len(pending) >= 8:
                for req in pending:
                    try:
                        req.wait(60.0)
                        served += 1
                    except Exception:   # noqa: BLE001 — counted as lost
                        lost += 1
                pending.clear()
        for req in pending:
            try:
                req.wait(60.0)
                served += 1
            except Exception:           # noqa: BLE001
                lost += 1
        l.join(120.0)
        actor.stop()
        l.stop()
        # the injected corrupt publishes may have been SKIPPED rather
        # than quarantined (a newer intact version can land before the
        # actor polls — also correct).  Force the deterministic case:
        # corrupt the newest snapshot on disk, then swap — the actor
        # must quarantine it and acquire the newest INTACT version.
        newest = store.latest_version()
        with open(store.path_for(newest), "r+b") as f:
            f.seek(64)
            byte = f.read(1)
            f.seek(64)
            f.write(bytes([byte[0] ^ 0xFF]))
        actor.try_swap(force=True)
        final_version = actor.version
        intact = store.versions()
        lat = actor.latency.percentiles()
        q_stats = actor.queue_stats()
        snap_stats = actor.snapshot_stats()
        quarantined_soak = store.quarantined

    print(f"chaos_soak,,served={served}/{soak_reqs} lost={lost} "
          f"quarantined={quarantined_soak} "
          f"swap_failures={snap_stats['swap_failures']} "
          f"p99={lat['p99']:.1f}ms")

    out = dict(
        env=bench_env(seed=0),
        workload=dict(k=k, d=d, capacity=capacity, batch_size=b, tau=tau,
                      rounds=rounds, soak_reqs=soak_reqs, fast=fast,
                      backend=jax.default_backend()),
        recovery=dict(bit_identical_to_fault_free=bit_identical,
                      watchdog_fires=stats_a["watchdog_fires"],
                      restore_fallbacks=stats_a["restore_fallbacks"],
                      restores=stats_a["restores"],
                      quarantined=quarantined_a),
        replay=dict(trace=trace_a, identical=trace_a == trace_b),
        soak=dict(admitted=soak_reqs, served=served, lost=lost,
                  quarantined=quarantined_soak,
                  swap_failures=snap_stats["swap_failures"],
                  serve_retried=q_stats["serve_retried"],
                  final_version=final_version,
                  intact_versions=intact,
                  p50_ms=lat["p50"], p99_ms=lat["p99"]))
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    with open(os.path.join(root, "BENCH_chaos.json"), "w") as f:
        json.dump(out, f, indent=2)

    assert bit_identical, (
        "recovered carry differs from the fault-free run under the "
        "injected schedule")
    assert replayed and trace_a == trace_b, (
        f"same seed did not reproduce the same run: trace_a={trace_a} "
        f"trace_b={trace_b}")
    assert stats_a["watchdog_fires"] >= 1, "hung step never detected"
    assert stats_a["restore_fallbacks"] >= 1, (
        "corrupt checkpoint never forced a restore fallback")
    assert lost == 0, f"{lost} admitted requests lost during the soak"
    assert quarantined_soak >= 1, "no corrupt publish was quarantined"
    assert final_version in intact, (
        f"served version {final_version} is not an intact snapshot")
    assert lat["p99"] is not None and lat["p99"] < 5_000.0, (
        f"p99 {lat['p99']:.0f}ms unbounded during recovery")


BENCHES = {
    "speedup": bench_speedup,
    "multi_restart": bench_multi_restart,
    "fused_restarts": bench_fused_restarts,
    "kernel_cache": bench_kernel_cache,
    "step_fuse": bench_step_fuse,
    "api_overhead": bench_api_overhead,
    "service": bench_service,
    "chaos": bench_chaos,
    "landmark": bench_landmark,
    "n_independence": bench_n_independence,
    "quality": bench_quality,
    "tau_sweep": bench_tau_sweep,
    "rates": bench_rates,
    "gamma_table": bench_gamma_table,
    "termination": bench_termination,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true")
    args, _ = ap.parse_known_args()
    print("name,us_per_call,derived")
    for name, fn in BENCHES.items():
        if args.only and name != args.only:
            continue
        print(f"# --- {name} ---")
        t0 = time.time()
        fn(args.fast)
        print(f"# {name} done in {time.time() - t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
