"""Aggregate experiments/dryrun/*.json into the §Roofline markdown table.

    PYTHONPATH=src python -m benchmarks.roofline [--mesh 16x16] [--md]

Per (arch x shape x mesh): the three roofline terms (seconds), the dominant
bottleneck, bytes/device, MODEL_FLOPS / HLO_FLOPS utilization ratio, and a
one-line "what would move the dominant term down" note.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

NOTES = {
    ("collective", "moe"): "explicit shard_map all-to-all EP dispatch in "
    "place of GSPMD's gather/scatter resharding of the (E,C,D) buckets",
    ("collective", "dense"): "2D-shard FFN activations / reduce-scatter "
    "grads instead of all-reduce; overlap psum with matmuls",
    ("collective", "ssm"): "keep time-scan state device-local; remove "
    "resharding at scan boundaries",
    ("memory", "any"): "larger microbatch or less aggressive remat; fuse "
    "elementwise chains; bf16 activations",
    ("compute", "any"): "already compute-bound — approach peak via MXU-"
    "aligned tiles",
}


def note_for(dominant: str, arch: str) -> str:
    kind = "moe" if ("arctic" in arch or "deepseek" in arch) else \
        ("ssm" if ("rwkv" in arch or "zamba" in arch) else "dense")
    for key in ((dominant, kind), (dominant, "any")):
        if key in NOTES:
            return NOTES[key]
    return ""


def load_cells(out_dir="experiments/dryrun"):
    cells = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_bytes(b):
    if b is None:
        return "?"
    return f"{b / 2**30:.2f}G"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = load_cells(args.out)
    if args.mesh:
        cells = [c for c in cells if c["mesh"] == args.mesh]

    def roof_of(c):
        """Prefer scan-trip-count-corrected terms (see dryrun.py)."""
        if "corrected" in c:
            return c["corrected"]["roofline"], c["corrected"].get(
                "useful_flops_ratio"), "*"
        return c["roofline"], c.get("useful_flops_ratio"), ""

    hdr = ("| arch | shape | mesh | opts | compute_s | memory_s | "
           "collective_s | dominant | peak_B/dev | useful_flops | "
           "bound-note |")
    print(hdr)
    print("|" + "---|" * 11)
    for c in cells:
        r, ratio, star = roof_of(c)
        dom = r["dominant"].replace("_s", "")
        ratio_s = f"{ratio:.2f}{star}" if ratio else "-"
        opts = ",".join(c.get("opts", [])) or "base"
        print(f"| {c['arch']} | {c['shape']} | {c['mesh']} | {opts} "
              f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
              f"| {r['collective_s']:.2e} | **{dom}** "
              f"| {fmt_bytes(c['memory']['peak_bytes'])} "
              f"| {ratio_s} | {note_for(dom, c['arch'])[:60]} |")

    # summary: worst roofline fraction (useful/total on dominant axis)
    print()

    def ratio_of(c):
        return roof_of(c)[1]

    worst = sorted((c for c in cells if ratio_of(c)),
                   key=ratio_of)[:5]
    print("# worst useful-flops ratios (hillclimb candidates):")
    for c in worst:
        print(f"#   {c['arch']} x {c['shape']} x {c['mesh']}: "
              f"{ratio_of(c):.3f}")
    most_coll = sorted(
        cells, key=lambda c: -(roof_of(c)[0]["collective_s"]
                               / max(sum([roof_of(c)[0]['compute_s'],
                                          roof_of(c)[0]['memory_s'],
                                          roof_of(c)[0]['collective_s']]),
                                     1e-30)))[:5]
    print("# most collective-bound:")
    for c in most_coll:
        r = roof_of(c)[0]
        tot = r["compute_s"] + r["memory_s"] + r["collective_s"]
        print(f"#   {c['arch']} x {c['shape']} x {c['mesh']}: "
              f"{r['collective_s'] / tot:.1%} of step")


if __name__ == "__main__":
    main()
