"""train_step: loss + grad + AdamW, with microbatch accumulation, remat'd
models, and an int8 error-feedback gradient-compression hook.

The step is pure and pjit-friendly: distribution comes entirely from the
shardings of TrainState/batch (launch/sharding.py), so the same function
serves the 1-device smoke tests and the 512-chip dry-run.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import forward_train
from repro.models.config import ModelConfig
from repro.train.optimizer import (
    AdamWConfig, AdamWState, adamw_init, adamw_update,
)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array
    ef_error: Any = None     # error-feedback buffer (grad compression)


def make_train_state(params, compress: bool = False) -> TrainState:
    ef = (jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
          if compress else None)
    return TrainState(params=params, opt=adamw_init(params),
                      step=jnp.zeros((), jnp.int32), ef_error=ef)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token CE.  logits (B,S,V) [any float dtype], labels (B,S)."""
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ------------------------------------------------- gradient compression
def quantize_int8(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads_ef(grads, ef_error):
    """int8 quantization with error feedback: the quantization residual is
    carried into the next step, so the *accumulated* update is unbiased
    (arXiv:1901.09847-style).  On real multi-pod hardware the int8 tensors
    are what crosses the 'pod' ICI links; here the quantize->dequantize
    round-trip exercises identical numerics."""

    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, scale = quantize_int8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq, g32 - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), \
        tdef.unflatten([o[1] for o in out])


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    microbatch: Optional[int] = None,
                    compress: bool = False):
    """Returns step(state, batch) -> (state, metrics).
    batch: {'tokens': (B,S), 'labels': (B,S)} (or 'embeds' for stub
    frontends).  `microbatch`: split B into that many accumulation chunks.
    """

    def loss_fn(params, batch):
        logits = forward_train(params, cfg, batch)
        return cross_entropy(logits[:, :-1], batch["labels"][:, :-1])

    def grads_of(params, batch):
        if microbatch is None or microbatch <= 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        b = batch["labels"].shape[0]
        mb = b // microbatch
        split = jax.tree.map(
            lambda x: x.reshape(microbatch, mb, *x.shape[1:]), batch)

        def acc(carry, micro):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, micro)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, g_acc, g)), None

        zero = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32),
                            params)
        (loss_sum, g_sum), _ = jax.lax.scan(acc, (jnp.zeros(()), zero),
                                            split)
        inv = 1.0 / microbatch
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def step(state: TrainState, batch):
        loss, grads = grads_of(state.params, batch)
        ef = state.ef_error
        if compress:
            grads, ef = compress_grads_ef(grads, state.ef_error)
        params, opt, om = adamw_update(grads, state.opt, state.params,
                                       opt_cfg)
        new_state = TrainState(params=params, opt=opt,
                               step=state.step + 1, ef_error=ef)
        return new_state, {"loss": loss, **om}

    return step
