"""AdamW with f32 master weights (params may live in bf16).

State layout (per parameter leaf): master (f32), mu (f32), nu (f32) — all
sharded like the parameter with the ZeRO upgrade applied by
launch/sharding.py (first replicated dim additionally sharded over 'data').
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWConfig(NamedTuple):
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup: int = 100


class AdamWState(NamedTuple):
    master: Any   # f32 pytree
    mu: Any
    nu: Any
    count: jax.Array


def adamw_init(params) -> AdamWState:
    # copy=True: with f32 params, astype would alias the parameter buffer
    # and break donation (same buffer donated twice via params AND master)
    f32 = lambda t: jax.tree.map(  # noqa: E731
        lambda x: jnp.array(x, dtype=jnp.float32, copy=True), t)
    zeros = lambda t: jax.tree.map(  # noqa: E731
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return AdamWState(master=f32(params), mu=zeros(params),
                      nu=zeros(params), count=jnp.zeros((), jnp.int32))


def _schedule(cfg: AdamWConfig, count):
    warm = jnp.minimum(count.astype(jnp.float32) / max(cfg.warmup, 1), 1.0)
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state: AdamWState, params, cfg: AdamWConfig):
    """Returns (new_params [model dtype], new_state, metrics)."""
    count = state.count + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = _schedule(cfg, count)
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        step = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        m = m - lr * (step + cfg.weight_decay * m)
        return m, mu, nu

    flat_g, tdef = jax.tree.flatten(grads)
    flat_m = jax.tree.leaves(state.master)
    flat_mu = jax.tree.leaves(state.mu)
    flat_nu = jax.tree.leaves(state.nu)
    out = [upd(g, m, mu, nu)
           for g, m, mu, nu in zip(flat_g, flat_m, flat_mu, flat_nu)]
    new_master = tdef.unflatten([o[0] for o in out])
    new_mu = tdef.unflatten([o[1] for o in out])
    new_nu = tdef.unflatten([o[2] for o in out])
    new_params = jax.tree.map(
        lambda m, p: m.astype(p.dtype), new_master, params)
    new_state = AdamWState(new_master, new_mu, new_nu, count)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
