"""Fault tolerance: watchdog-driven train loop with checkpoint/restart and
(simulated) straggler / failure handling.

On a real cluster the failure signal is a missing heartbeat or a collective
timeout; here `run_resilient` accepts any step callable that may raise, and
the recovery path — restore last checkpoint, (optionally) shrink the mesh,
replay the deterministic data stream — is identical to production.  Because
every batch is a pure function of (seed, step) (data/pipeline.py) and the
optimizer is deterministic, a crash-recovery run converges to EXACTLY the
same state as an uninterrupted run (asserted in tests).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Optional

from repro.train.checkpoint import Checkpointer


class StepTimeout(RuntimeError):
    """Raised by the watchdog when a step exceeds the straggler budget."""


def run_resilient(step_fn: Callable[[Any, Any], tuple],
                  pipeline: Callable[[int], Any],
                  state: Any,
                  n_steps: int,
                  ckpt: Checkpointer,
                  ckpt_every: int = 10,
                  max_restarts: int = 3,
                  step_timeout_s: Optional[float] = None,
                  make_state_like: Optional[Callable[[], Any]] = None,
                  shardings: Any = None,
                  on_restore: Optional[Callable[[int], None]] = None):
    """Drive `state = step_fn(state, batch)` for n_steps with recovery.

    Straggler mitigation: if `step_timeout_s` is set, a step whose host
    wall-time exceeds it raises StepTimeout and takes the same
    restore-and-retry path as a crash (on real pods: exclude the slow host
    and restore onto the shrunk mesh via `shardings`).
    """
    initial_state = state    # recovery target when no checkpoint exists yet
    start = 0
    restarts = 0
    history = []
    while start < n_steps:
        try:
            for step in range(start, n_steps):
                t0 = time.monotonic()
                batch = pipeline(step)
                state, metrics = step_fn(state, batch)
                dt = time.monotonic() - t0
                if step_timeout_s is not None and dt > step_timeout_s:
                    raise StepTimeout(f"step {step} took {dt:.3f}s")
                history.append({"step": step, **{
                    k: float(v) for k, v in metrics.items()}})
                if (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, state)
            start = n_steps
        except Exception:  # noqa: BLE001 — any failure triggers recovery
            restarts += 1
            if restarts > max_restarts:
                raise
            ckpt.wait()
            last = ckpt.latest_step() or 0
            if last > 0:
                like = (make_state_like() if make_state_like is not None
                        else state)
                state = ckpt.restore(last, like, shardings)
            else:
                state = initial_state
            if on_restore is not None:
                on_restore(last)
            history = [h for h in history if h["step"] < last]
            start = last
    ckpt.wait()
    return state, history
