"""Fault tolerance: watchdog-driven train loop with checkpoint/restart,
restore fallback through older checkpoints, and deterministic restart
backoff.

On a real cluster the failure signal is a missing heartbeat or a collective
timeout; here `run_resilient` accepts any step callable that may raise, and
the recovery path — restore last checkpoint, (optionally) shrink the mesh,
replay the deterministic data stream — is identical to production.  Because
every batch is a pure function of (seed, step) (data/pipeline.py) and the
optimizer is deterministic, a crash-recovery run converges to EXACTLY the
same state as an uninterrupted run (asserted in tests), even when the
restore had to fall back past a corrupt checkpoint to an older one.

The watchdog is REAL: with ``step_timeout_s`` set, each step runs on a
dedicated worker thread and the driver waits on its completion with a
deadline — a step that HANGS (never returns) raises :class:`StepTimeout`
at the deadline and takes the restore path, instead of only being noticed
after it eventually completes.  The hung worker is abandoned (its late
result, success or exception, is discarded by generation tag); callers
injecting hangs should abort them via ``on_watchdog`` (the chaos
harness's :meth:`FaultPlan.abort_hangs`) so abandoned threads die rather
than linger — on real pods this is where the slow host gets excluded and
the mesh shrinks.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Callable, Optional

import numpy as np

from repro.train.checkpoint import Checkpointer

# every counter run_resilient maintains in its ``events`` dict
EVENT_KEYS = ("restarts", "watchdog_fires", "restore_fallbacks",
              "backoff_s")


class StepTimeout(RuntimeError):
    """Raised by the watchdog when a step exceeds the straggler budget."""


class _StepWorker:
    """One persistent worker thread executing steps on behalf of the
    watchdog.  Results carry a generation tag; when the driver times out
    and abandons a step, the worker's eventual (late) result is discarded
    by tag mismatch and a fresh thread takes over — the abandoned thread
    finishes (or dies on an aborted injected hang) in the background."""

    def __init__(self):
        self._req: "queue.Queue" = queue.Queue()
        self._res: "queue.Queue" = queue.Queue()
        self._gen = 0
        self._thread: Optional[threading.Thread] = None

    def _ensure(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            self._thread = threading.Thread(
                target=self._loop, daemon=True,
                name="resilience-step-worker")
            self._thread.start()

    def _loop(self) -> None:
        while True:
            gen, fn, args = self._req.get()
            try:
                out = (gen, True, fn(*args))
            except BaseException as e:     # noqa: BLE001 — relayed below
                out = (gen, False, e)
            self._res.put(out)

    def call(self, fn: Callable, args: tuple, timeout_s: float):
        """Run ``fn(*args)`` with a hard deadline; re-raises the step's
        own exception (including BaseException-derived cooperative-stop
        signals) on the calling thread, or :class:`StepTimeout` when the
        deadline passes first."""
        self._ensure()
        self._gen += 1
        gen = self._gen
        self._req.put((gen, fn, args))
        deadline = time.monotonic() + timeout_s
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                # abandon: a hung thread can't be killed, but its late
                # result is discarded and a fresh worker takes over
                self._thread = None
                raise StepTimeout(
                    f"step exceeded the {timeout_s:.3f}s watchdog budget")
            try:
                g, ok, val = self._res.get(timeout=remaining)
            except queue.Empty:
                continue
            if g != gen:                   # stale result of an abandoned step
                continue
            if ok:
                return val
            raise val


def _backoff_s(restarts: int, base_s: float, cap_s: float,
               seed: int) -> float:
    """Exponential backoff with DETERMINISTIC jitter: a pure function of
    (seed, restart count), so chaos runs replay the same waits."""
    if base_s <= 0.0:
        return 0.0
    jitter = float(np.random.default_rng((int(seed), 0xB0FF,
                                          int(restarts))).random())
    return min(cap_s, base_s * (2.0 ** (restarts - 1))) * (1.0
                                                           + 0.25 * jitter)


def _restore_latest(ckpt, like, shardings, initial_state, events):
    """Restore the newest intact checkpoint, falling back through older
    ones when a restore raises (corrupt file, missing leaf) — each
    skipped checkpoint counts as a ``restore_fallback``.  Returns
    ``(state, step)``; ``(initial_state, 0)`` when nothing restores."""
    steps_fn = getattr(ckpt, "steps", None)
    if steps_fn is not None:
        avail = sorted(int(s) for s in steps_fn())[::-1]
    else:
        last = ckpt.latest_step() or 0
        avail = [last] if last > 0 else []
    for s in avail:
        try:
            return ckpt.restore(s, like, shardings), s
        except Exception:  # noqa: BLE001 — fall back to the next older
            events["restore_fallbacks"] += 1
            continue
    return initial_state, 0


def run_resilient(step_fn: Callable[[Any, Any], tuple],
                  pipeline: Callable[[int], Any],
                  state: Any,
                  n_steps: int,
                  ckpt: Checkpointer,
                  ckpt_every: int = 10,
                  max_restarts: int = 3,
                  step_timeout_s: Optional[float] = None,
                  make_state_like: Optional[Callable[[], Any]] = None,
                  shardings: Any = None,
                  on_restore: Optional[Callable[[int], None]] = None,
                  backoff_base_s: float = 0.0,
                  backoff_cap_s: float = 5.0,
                  backoff_seed: int = 0,
                  on_watchdog: Optional[Callable[[], None]] = None,
                  events: Optional[dict] = None):
    """Drive `state = step_fn(state, batch)` for n_steps with recovery.

    Straggler/hang mitigation: with `step_timeout_s` set, every step runs
    under the watchdog worker — a step that hangs raises StepTimeout AT
    the deadline (not after it returns) and takes the same
    restore-and-retry path as a crash (on real pods: exclude the slow
    host and restore onto the shrunk mesh via `shardings`).
    `on_watchdog` fires on each timeout, before the restore.

    Recovery hardening: restores FALL BACK through older checkpoints when
    the newest fails to restore (`ckpt.steps()` when available), restarts
    are spaced by exponential backoff with deterministic jitter
    (`backoff_base_s`; default 0 keeps tests instant), and `events` (a
    caller-owned dict) accumulates `restarts` / `watchdog_fires` /
    `restore_fallbacks` / `backoff_s` for degraded-mode telemetry.
    """
    if events is None:
        events = {}
    for k in EVENT_KEYS:
        events.setdefault(k, 0.0 if k == "backoff_s" else 0)
    worker = _StepWorker() if step_timeout_s is not None else None
    initial_state = state    # recovery target when no checkpoint exists yet
    start = 0
    restarts = 0
    history = []
    while start < n_steps:
        try:
            for step in range(start, n_steps):
                batch = pipeline(step)
                if worker is not None:
                    try:
                        state, metrics = worker.call(
                            step_fn, (state, batch), step_timeout_s)
                    except StepTimeout:
                        events["watchdog_fires"] += 1
                        if on_watchdog is not None:
                            on_watchdog()
                        raise
                else:
                    state, metrics = step_fn(state, batch)
                history.append({"step": step, **{
                    k: float(v) for k, v in metrics.items()}})
                if (step + 1) % ckpt_every == 0:
                    ckpt.save(step + 1, state)
            start = n_steps
        except Exception:  # noqa: BLE001 — any failure triggers recovery
            restarts += 1
            events["restarts"] += 1
            if restarts > max_restarts:
                raise
            ckpt.wait()
            wait_s = _backoff_s(restarts, backoff_base_s, backoff_cap_s,
                                backoff_seed)
            if wait_s > 0.0:
                events["backoff_s"] += wait_s
                time.sleep(wait_s)
            like = (make_state_like() if make_state_like is not None
                    else state)
            state, last = _restore_latest(ckpt, like, shardings,
                                          initial_state, events)
            if on_restore is not None:
                on_restore(last)
            history = [h for h in history if h["step"] < last]
            start = last
    ckpt.wait()
    return state, history
