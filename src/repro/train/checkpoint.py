"""Sharded checkpointing with elastic restore.

Format: one .npz holding every leaf (path-flattened keys) + a JSON manifest
(step, mesh shape, framework version).  Leaves are gathered to host at save
and re-placed with the TARGET mesh's shardings at restore — so a checkpoint
written on a 2x16x16 mesh restores onto 16x16 (pod loss) or onto 8 devices
(CI), as long as divisibility holds: elastic scaling is a restore-time
re-shard, not a format concern.

Saves are asynchronous: `save()` snapshots to host (blocking only on device
transfer) and writes in a daemon thread; call `wait()` (or save again) to
join — keeps checkpoint I/O off the training critical path.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Any, Optional

import jax
import numpy as np

_SEP = "|"


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = _SEP.join(str(p) for p in path)
        out[key] = np.asarray(jax.device_get(leaf))
    return out, treedef


class Checkpointer:
    def __init__(self, directory: str):
        self.dir = directory
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, state: Any, meta: Optional[dict] = None,
             blocking: bool = False):
        self.wait()
        arrays, _ = _flatten(state)
        manifest = {"step": int(step), **(meta or {})}

        def _write():
            tmp = os.path.join(self.dir, f"ckpt_{step}.tmp.npz")
            dst = os.path.join(self.dir, f"ckpt_{step}.npz")
            np.savez(tmp, **arrays)
            os.replace(tmp, dst)
            with open(os.path.join(self.dir, f"ckpt_{step}.json"),
                      "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, "latest.tmp"),
                       os.path.join(self.dir, "latest"))

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if not os.path.exists(p):
            return None
        with open(p) as f:
            return int(f.read().strip())

    def steps(self) -> list:
        """Every step with a checkpoint file on disk, ascending — the
        restore-fallback chain for :mod:`repro.train.resilience` when the
        newest checkpoint turns out to be corrupt."""
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and name.endswith(".npz") \
                    and ".tmp." not in name:
                try:
                    out.append(int(name[len("ckpt_"):-len(".npz")]))
                except ValueError:
                    continue
        return sorted(out)

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """`like`: a pytree (arrays or ShapeDtypeStructs) defining the
        structure; `shardings`: optional matching tree of NamedShardings for
        the TARGET mesh (elastic re-shard happens here)."""
        data = np.load(os.path.join(self.dir, f"ckpt_{step}.npz"))
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        leaves = []
        shard_flat = (jax.tree.leaves(shardings)
                      if shardings is not None else [None] * len(flat))
        for (path, leaf), shard in zip(flat, shard_flat):
            key = _SEP.join(str(p) for p in path)
            arr = data[key]
            if arr.shape != tuple(leaf.shape):
                raise ValueError(
                    f"checkpoint leaf {key} shape {arr.shape} != "
                    f"expected {leaf.shape}")
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"ckpt_{step}.json")) as f:
            return json.load(f)
