"""repro.api — the single front door for mini-batch kernel k-means.

One estimator (:class:`KernelKMeans`, sklearn-style ``fit`` /
``partial_fit`` / ``predict`` / ``transform`` / ``score`` plus ``save`` /
``load``), configured by one :class:`SolverConfig` whose *orthogonal* axes
(``cache`` x ``distribution`` x ``restarts`` x ``sampler`` x ``jit``)
replace the eight legacy ``fit_*`` entry points.  A registry-driven
resolver (:func:`resolve_plan` / :func:`register_solver`) maps any config
point to a composed executor, so new execution strategies (e.g. the fused
restart x data x model program on the roadmap) register as one more plan
instead of a ninth ``fit_*``.

See ``docs/api.md`` for the config matrix and the legacy migration table.

This module is import-light and resolves its public names lazily (PEP 562)
so ``repro.core`` can depend on :mod:`repro.api.keys` without a cycle.
"""
from __future__ import annotations

__all__ = [
    "KernelKMeans",
    "SolverConfig",
    "FitOutcome",
    "Plan",
    "SolverSpec",
    "register_solver",
    "unregister_solver",
    "list_solvers",
    "resolve_plan",
    "list_kernels",
    "make_kernel",
    "register_kernel_factory",
    "keys",
]

# name -> submodule providing it (resolved on first attribute access)
_EXPORTS = {
    "KernelKMeans": "repro.api.estimator",
    "SolverConfig": "repro.api.config",
    "FitOutcome": "repro.api.executors",
    "Plan": "repro.api.plan",
    "SolverSpec": "repro.api.plan",
    "register_solver": "repro.api.plan",
    "unregister_solver": "repro.api.plan",
    "list_solvers": "repro.api.plan",
    "resolve_plan": "repro.api.plan",
    "list_kernels": "repro.core.kernel_fns",
    "make_kernel": "repro.core.kernel_fns",
    "register_kernel_factory": "repro.core.kernel_fns",
    "keys": "repro.api.keys",
}


def __getattr__(name: str):
    try:
        modname = _EXPORTS[name]
    except KeyError:
        raise AttributeError(f"module 'repro.api' has no attribute "
                             f"{name!r}") from None
    import importlib

    if name == "keys":
        value = importlib.import_module("repro.api.keys")
    else:
        value = getattr(importlib.import_module(modname), name)
    globals()[name] = value      # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(__all__))
