"""Solver-plan registry: map a resolved :class:`SolverConfig` point to an
executor.

Every execution strategy registers a :class:`SolverSpec` — a predicate
over resolved configs plus an executor factory.  :func:`resolve_plan`
resolves the config's ``auto`` axes, then picks the matching spec of
highest ``(priority, registration order)``.  Config points nobody claims
raise ``NotImplementedError`` naming :func:`register_solver` — which is
exactly how the roadmap's fused restart x data x model program lands: as
one more registration, not a ninth ``fit_*``:

    register_solver(
        "fused_restart_sharded",
        matches=lambda c: c.restarts > 1 and c.distribution == "sharded",
        build=lambda cfg, mesh: FusedExecutor(cfg, mesh))
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple, Optional

from repro.api.config import SolverConfig
from repro.api import executors as _ex


@dataclass(frozen=True)
class SolverSpec:
    """A registered execution strategy."""

    name: str
    matches: Callable[[SolverConfig], bool]
    build: Callable[..., "_ex.Executor"]     # (config, mesh) -> executor
    priority: int = 0
    description: str = ""


class Plan(NamedTuple):
    """A resolved execution plan: the concrete config point (no ``auto``
    axes left) and the executor that runs it."""

    name: str
    config: SolverConfig
    executor: "_ex.Executor"


_REGISTRY: dict = {}       # name -> (SolverSpec, registration index)
_COUNTER = [0]


def register_solver(name: str, *, matches, build, priority: int = 0,
                    description: str = "", overwrite: bool = False) -> None:
    """Register an execution strategy.  Among matching specs the highest
    ``priority`` wins (ties: most recently registered), so downstream
    packages can claim config subspaces without touching this module."""
    if name in _REGISTRY and not overwrite:
        raise ValueError(f"solver {name!r} is already registered "
                         f"(registered: {list_solvers()}); pass "
                         "overwrite=True to replace it")
    _COUNTER[0] += 1
    _REGISTRY[name] = (SolverSpec(name=name, matches=matches, build=build,
                                  priority=priority,
                                  description=description), _COUNTER[0])


def unregister_solver(name: str) -> None:
    if name not in _REGISTRY:
        raise ValueError(f"solver {name!r} is not registered "
                         f"(registered: {list_solvers()})")
    del _REGISTRY[name]


def list_solvers() -> list:
    """Registered solver names, in registration order."""
    return [n for n, (_, i) in sorted(_REGISTRY.items(),
                                      key=lambda kv: kv[1][1])]


def resolve_plan(config: SolverConfig, *, n: Optional[int] = None,
                 mesh=None, solver: Optional[str] = None) -> Plan:
    """Resolve ``config``'s ``auto`` axes for (n, mesh) and build the
    executor of the best-matching registered solver.  ``solver`` forces a
    specific registration by name (the legacy shims use it so e.g.
    ``fit_restarts(restarts=1)`` still lands on the engine)."""
    resolved = config.resolve(n=n, mesh=mesh)
    if solver is not None:
        try:
            spec, _ = _REGISTRY[solver]
        except KeyError:
            raise ValueError(f"unknown solver {solver!r} "
                             f"(registered: {list_solvers()})") from None
    else:
        matching = [(s.priority, order, s)
                    for s, order in _REGISTRY.values()
                    if s.matches(resolved)]
        if not matching:
            raise NotImplementedError(
                f"no solver plan matches {resolved.axes_repr()}; this "
                "combination has no registered executor — register one "
                "with repro.api.register_solver(name, matches=..., "
                f"build=...).  Registered solvers: {list_solvers()}")
        _, _, spec = max(matching, key=lambda t: (t[0], t[1]))
    return Plan(name=spec.name, config=resolved,
                executor=spec.build(resolved, mesh))


# ---------------------------------------------------------------------------
# Built-in solvers: one registration per legacy fit_* entry point family.

register_solver(
    "single",
    matches=lambda c: (c.distribution == "single" and c.cache == "none"
                       and c.restarts == 1),
    build=_ex.SingleExecutor,
    description="plain Algorithm-2 fit (host loop or one compiled "
                "while_loop); legacy fit / fit_jit")

register_solver(
    "single_precomputed",
    matches=lambda c: (c.distribution == "single"
                       and c.cache == "precomputed" and c.restarts == 1),
    build=_ex.PrecomputedExecutor,
    description="full-Gram precompute then gather-only iterations; legacy "
                "serve --cache-mode precomputed path")

register_solver(
    "single_lru",
    matches=lambda c: (c.distribution == "single" and c.cache == "lru"
                       and c.restarts == 1),
    build=_ex.CachedExecutor,
    description="Gram tile cache fit; legacy fit_cached")

register_solver(
    "sharded",
    matches=lambda c: (c.distribution == "sharded" and c.cache == "none"
                       and c.restarts == 1),
    build=_ex.ShardedExecutor,
    description="shard_map data x model fit; legacy fit_distributed / "
                "fit_distributed_jit")

register_solver(
    "sharded_lru",
    matches=lambda c: (c.distribution == "sharded" and c.cache == "lru"
                       and c.restarts == 1 and c.jit),
    build=_ex.ShardedCachedExecutor,
    description="sharded fit with per-shard tile caches; legacy "
                "fit_distributed_cached_jit")

register_solver(
    "multi_restart",
    matches=lambda c: (c.restarts > 1 and c.distribution == "single"
                       and c.cache == "none"),
    build=_ex.RestartExecutor,
    description="best-of-R restarts in one compiled program; legacy "
                "fit_restarts / MultiRestartEngine")

register_solver(
    "fused_restart_sharded",
    matches=lambda c: (c.restarts > 1 and c.distribution == "sharded"
                       and c.jit and c.cache in ("none", "lru")),
    build=_ex.FusedRestartExecutor,
    description="R restarts of the SHARDED step as one compiled program "
                "on a restart x data x model mesh (launch.mesh."
                "make_fused_mesh); sharded shared-eval-batch winner "
                "selection; cache='lru' adds per-(restart, data-shard) "
                "Gram tile caches — the first registry-only solver (no "
                "legacy fit_* twin)")
