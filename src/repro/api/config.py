"""``SolverConfig`` — one dataclass, orthogonal execution axes.

The legacy surface hard-coded one point of the cache x sharding x restarts
x jit space into each function NAME (``fit``, ``fit_cached``,
``fit_distributed_cached_jit``, ...).  Here the same space is spanned by
independent config axes:

    cache         'none' | 'lru' | 'precomputed' | 'auto'
    distribution  'single' | 'sharded' | 'auto'
    restarts      R >= 1
    sampler       'iid' | 'nested'
    jit           host-driven loop (False) vs one compiled while_loop (True)
    step          'composed' | 'fused' | 'auto'  — inner-step implementation
                  ('fused': streaming one-pass Pallas step, docs/perf.md)
    precision     'f32' | 'bf16' — kernel-eval coordinate precision
                  (accumulation always stays f32)
    prefetch      one-deep batch pipeline on the host-driven plans

plus the Algorithm-2 statics that previously lived in
:class:`repro.core.minibatch.MBConfig` (``k``, ``batch_size``, ``tau``,
``rate``, ...), and the kernel — either a built kernel pytree or a
registry name (``kernel="rbf"`` + ``kernel_params``; see
``repro.core.kernel_fns.list_kernels``).

``resolve`` pins the ``auto`` axes for a concrete dataset/mesh;
``repro.api.plan.resolve_plan`` then maps the resolved point to an
executor through the solver registry.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional, Tuple

from repro.core.kernel_fns import KernelFn, Precomputed, make_kernel
from repro.core.minibatch import MBConfig

_CACHE_VALUES = ("none", "lru", "precomputed", "auto")
_DISTRIBUTION_VALUES = ("single", "sharded", "auto")
_SAMPLER_VALUES = ("iid", "nested")
_STEP_VALUES = ("composed", "fused", "auto")
_PRECISION_VALUES = ("f32", "bf16")

# cache='auto' precomputes the full Gram while n^2 stays under this many
# elements (f32: 64 MB) — beyond that it falls back to the LRU tile cache
# for nested sampling, or no cache at all.
PRECOMPUTED_AUTO_MAX_ELEMS = 16 * 2 ** 20


@dataclass(frozen=True)
class SolverConfig:
    """Everything a :class:`repro.api.KernelKMeans` fit needs, in one
    frozen dataclass.  All axes are orthogonal; unsupported combinations
    are rejected by the plan resolver (with a pointer to
    ``register_solver``), not by this class."""

    # ---- Algorithm 2 statics (mirrors core.minibatch.MBConfig) ----------
    k: int = 8
    batch_size: int = 256
    tau: int = 128
    rate: str = "beta"
    sqnorm_mode: str = "recompute"
    eval_mode: str = "direct"
    epsilon: float = 1e-4
    max_iters: int = 200
    use_pallas: bool = False
    compute_dtype: str = "float32"

    # ---- kernel ---------------------------------------------------------
    kernel: Any = "rbf"                  # registry name or KernelFn pytree
    kernel_params: Any = ()              # mapping / item-tuple for names

    # ---- fit behaviour --------------------------------------------------
    init: str = "kmeans++"               # 'kmeans++' | 'random'
    early_stop: bool = True

    # ---- execution axes -------------------------------------------------
    cache: str = "auto"
    distribution: str = "auto"
    restarts: int = 1
    sampler: str = "iid"
    jit: bool = True
    step: str = "auto"
    precision: str = "f32"
    prefetch: bool = True

    # ---- cache knobs ----------------------------------------------------
    cache_tile: int = 256
    cache_capacity: int = 16
    cache_dtype: str = "float32"

    # ---- nested-sampler knobs -------------------------------------------
    reuse: float = 0.5
    refresh: int = 8

    # ---- distribution knobs ---------------------------------------------
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    # ---- multi-restart knobs --------------------------------------------
    restart_axis: Optional[str] = None
    eval_batch_size: Optional[int] = None
    share_eval_gram: Optional[bool] = None

    # ---- landmark compression axis (docs/compression.md) ----------------
    # "off" (serving identical to historical), or a mapping like
    # {"every": T, "m": m, "selector": "uniform"|"leverage",
    #  "jitter": 1e-6}: every T-th fit iteration projects each center's
    # support window onto m landmark rows in place (every=0: no in-loop
    # hook — round-cadence / explicit ``KernelKMeans.compress`` only).
    # Orthogonal to every other axis — resolved into MBConfig, so all
    # executors honor it through the same step factories.
    compress: Any = "off"

    def __post_init__(self):
        if self.cache not in _CACHE_VALUES:
            raise ValueError(f"cache={self.cache!r} not in {_CACHE_VALUES}")
        if self.distribution not in _DISTRIBUTION_VALUES:
            raise ValueError(f"distribution={self.distribution!r} not in "
                             f"{_DISTRIBUTION_VALUES}")
        if self.sampler not in _SAMPLER_VALUES:
            raise ValueError(f"sampler={self.sampler!r} not in "
                             f"{_SAMPLER_VALUES}")
        if self.step not in _STEP_VALUES:
            raise ValueError(f"step={self.step!r} not in {_STEP_VALUES}")
        if self.precision not in _PRECISION_VALUES:
            raise ValueError(f"precision={self.precision!r} not in "
                             f"{_PRECISION_VALUES}")
        if self.restarts < 1:
            raise ValueError("restarts must be >= 1")
        if self.init not in ("kmeans++", "random"):
            raise ValueError(f"init={self.init!r} (expected 'kmeans++' or "
                             "'random')")
        # normalize param containers to hashing-friendly tuples
        kp = self.kernel_params
        if not isinstance(kp, tuple):
            kp = tuple(sorted(dict(kp).items()))
        object.__setattr__(self, "kernel_params", kp)
        object.__setattr__(self, "data_axes", tuple(self.data_axes))
        # normalize + validate the compress axis (mappings and the
        # list-of-pairs shape JSON round-trips produce both normalize to a
        # hashable sorted item-tuple; spec_of re-validates values)
        from repro.landmark.compress import spec_of
        spec = spec_of(self.compress)   # raises on malformed values
        if spec is None:
            object.__setattr__(self, "compress", "off")
        else:
            from repro.core.state import window_size
            w = window_size(self.batch_size, self.tau)
            if spec.m > w:
                raise ValueError(
                    f"compress m={spec.m} exceeds the support window "
                    f"W=tau+batch_size={w}")
            object.__setattr__(self, "compress",
                               tuple(sorted(spec._asdict().items())))

    # ------------------------------------------------------------------ --
    def replace(self, **changes) -> "SolverConfig":
        return dataclasses.replace(self, **changes)

    def resolved_step(self) -> str:
        """The concrete step implementation this config runs with.
        ``step='auto'`` picks the streaming fused step where its Pallas
        kernels compile natively (TPU) and the paper-faithful
        recompute/direct modes are in effect; everywhere else the
        composed chain (non-TPU backends run the fused step only on
        request — its structural XLA fallback is bit-identical but the
        composed chain is the long-validated default)."""
        if self.step != "auto":
            return self.step
        if self.sqnorm_mode != "recompute" or self.eval_mode != "direct":
            return "composed"
        import jax
        return "fused" if jax.default_backend() == "tpu" else "composed"

    def mb_config(self) -> MBConfig:
        """The Algorithm-2 static config this point runs with.  The
        ``precision`` axis lowers to the kernel-eval compute dtype
        (``bf16`` -> bfloat16 coordinates, f32 accumulation); ``step``
        resolves through :meth:`resolved_step`."""
        cdt = "bfloat16" if self.precision == "bf16" else self.compute_dtype
        spec = self.compress_spec()
        if spec is not None and spec.every <= 0:
            spec = None   # round-cadence-only mode: no in-loop hook
        return MBConfig(k=self.k, batch_size=self.batch_size, tau=self.tau,
                        rate=self.rate, sqnorm_mode=self.sqnorm_mode,
                        eval_mode=self.eval_mode, epsilon=self.epsilon,
                        max_iters=self.max_iters,
                        use_pallas=self.use_pallas,
                        compute_dtype=cdt,
                        step=self.resolved_step(),
                        compress=spec)

    def compress_spec(self):
        """The compress axis as a :class:`repro.landmark.compress
        .CompressSpec`, or None for ``"off"``."""
        from repro.landmark.compress import spec_of
        return spec_of(self.compress)

    def make_kernel_fn(self) -> KernelFn:
        """Resolve the kernel axis to an actual kernel pytree (registry
        names go through ``repro.core.kernel_fns.make_kernel``)."""
        return make_kernel(self.kernel, **dict(self.kernel_params))

    def resolve(self, n: Optional[int] = None,
                mesh=None) -> "SolverConfig":
        """Pin the ``auto`` axes for a concrete dataset size / mesh.
        Idempotent on already-resolved configs."""
        changes = {}
        if self.distribution == "auto":
            sharded = (mesh is not None
                       and self.model_axis in getattr(mesh, "axis_names", ()))
            changes["distribution"] = "sharded" if sharded else "single"
        if self.cache == "auto":
            dist = changes.get("distribution", self.distribution)
            kern = self.kernel
            index_data = (not isinstance(kern, str)
                          and (isinstance(kern, Precomputed)
                               or hasattr(kern, "cache")))
            if index_data:
                # already an explicit-Gram / cached kernel: adding another
                # cache layer on top would gain nothing
                changes["cache"] = "none"
            elif (dist == "single" and self.restarts == 1 and n is not None
                    and n * n <= PRECOMPUTED_AUTO_MAX_ELEMS):
                changes["cache"] = "precomputed"
            elif dist == "single" and self.restarts == 1 \
                    and self.sampler == "nested":
                changes["cache"] = "lru"
            else:
                changes["cache"] = "none"
        if self.restart_axis is None and self.restarts > 1 and \
                changes.get("distribution", self.distribution) == "sharded":
            # the fused restart x data x model plan needs a named restart
            # mesh axis; pin the canonical name (make_fused_mesh's default)
            changes["restart_axis"] = "restart"
        if self.step == "auto":
            changes["step"] = self.resolved_step()
        return self.replace(**changes) if changes else self

    def axes_repr(self) -> str:
        """Compact human string of the execution point (error messages,
        plan descriptions)."""
        return (f"cache={self.cache!r} distribution={self.distribution!r} "
                f"restarts={self.restarts} sampler={self.sampler!r} "
                f"jit={self.jit} step={self.step!r} "
                f"precision={self.precision!r}")


def field_names() -> Tuple[str, ...]:
    """Ordered SolverConfig field names — snapshotted by the public-API
    lock test (adding/removing/reordering fields is an API change)."""
    return tuple(f.name for f in dataclasses.fields(SolverConfig))
