"""Adapters that run the deprecated ``fit_*`` surfaces through the solver
plans.

Each legacy entry point maps to exactly one :class:`SolverConfig` point
(the migration table in ``docs/api.md``); the adapters here keep the
historical signatures, return shapes and PRNG semantics — in particular
the legacy behaviour of NOT consuming an init key split when ``init_idx``
/ ``center_pts`` is passed explicitly (``always_split=False``), so
pre-existing trajectories are bit-identical through the shims.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.api.config import SolverConfig
from repro.api.deprecation import warn_legacy  # noqa: F401  (shim import)
from repro.api.plan import resolve_plan


def _solver_config(cfg, kernel, **axes) -> SolverConfig:
    """Lift an MBConfig + kernel + execution axes into a SolverConfig."""
    return SolverConfig(
        k=cfg.k, batch_size=cfg.batch_size, tau=cfg.tau, rate=cfg.rate,
        sqnorm_mode=cfg.sqnorm_mode, eval_mode=cfg.eval_mode,
        epsilon=cfg.epsilon, max_iters=cfg.max_iters,
        use_pallas=cfg.use_pallas, compute_dtype=cfg.compute_dtype,
        step=cfg.step, kernel=kernel, **axes)


def fit(x, kernel, cfg, key, init="kmeans++", early_stop=True,
        init_idx=None, weights=None):
    scfg = _solver_config(cfg, kernel, cache="none", distribution="single",
                          jit=False, sampler="iid", init=init,
                          early_stop=early_stop)
    ex = resolve_plan(scfg, n=x.shape[0], solver="single").executor
    out = ex.fit(x, key, init_idx=init_idx, sample_weight=weights,
                 always_split=False)
    return out.state, out.history


def fit_jit(x, kernel, cfg, key, init_idx):
    scfg = _solver_config(cfg, kernel, cache="none", distribution="single",
                          jit=True, sampler="iid")
    ex = resolve_plan(scfg, n=x.shape[0], solver="single").executor
    out = ex.fit(x, key, init_idx=init_idx, always_split=False)
    return out.state, out.iters


def fit_cached(x, kernel, cfg, key, tile=256, capacity=16,
               init="kmeans++", early_stop=True, init_idx=None,
               sampler="uniform", reuse=0.5, refresh=8,
               store_dtype=jnp.float32):
    if sampler not in ("uniform", "nested"):
        raise ValueError(sampler)
    scfg = _solver_config(
        cfg, kernel, cache="lru", distribution="single", jit=False,
        sampler="iid" if sampler == "uniform" else "nested",
        init=init, early_stop=early_stop, cache_tile=tile,
        cache_capacity=capacity, cache_dtype=jnp.dtype(store_dtype).name,
        reuse=reuse, refresh=refresh)
    ex = resolve_plan(scfg, n=x.shape[0], solver="single_lru").executor
    out = ex.fit(x, key, init_idx=init_idx, always_split=False)
    return out.state, out.history, out.cache


def fit_distributed(xb_stream, center_pts, kernel, cfg, mesh,
                    data_axes=("data",), model_axis="model",
                    early_stop=True):
    # prefetch=False: the shim's contract is behavior-preserving, and the
    # one-deep pipeline observably advances a CALLER-owned iterator one
    # extra item on early stop (results are bit-identical either way) —
    # the estimator surface keeps the pipelined default
    scfg = _solver_config(cfg, kernel, cache="none",
                          distribution="sharded", jit=False,
                          early_stop=early_stop,
                          data_axes=tuple(data_axes),
                          model_axis=model_axis, prefetch=False)
    ex = resolve_plan(scfg, mesh=mesh, solver="sharded").executor
    return ex.fit_stream(xb_stream, center_pts, mb=cfg)


def fit_distributed_jit(x, center_pts, kernel, cfg, mesh, key,
                        data_axes=("data",), model_axis="model"):
    scfg = _solver_config(cfg, kernel, cache="none",
                          distribution="sharded", jit=True,
                          data_axes=tuple(data_axes),
                          model_axis=model_axis)
    ex = resolve_plan(scfg, n=x.shape[0], mesh=mesh,
                      solver="sharded").executor
    out = ex.fit(x, key, center_pts=center_pts, always_split=False,
                 strict=True)
    return out.state, out.iters


def fit_distributed_cached_jit(x, init_idx, base_kernel, cfg, mesh, key,
                               tile=256, capacity=16, data_axes=("data",),
                               model_axis="model", cache_dtype=jnp.float32):
    scfg = _solver_config(
        cfg, base_kernel, cache="lru", distribution="sharded", jit=True,
        data_axes=tuple(data_axes), model_axis=model_axis, cache_tile=tile,
        cache_capacity=capacity, cache_dtype=jnp.dtype(cache_dtype).name)
    ex = resolve_plan(scfg, n=x.shape[0], mesh=mesh,
                      solver="sharded_lru").executor
    out = ex.fit(x, key, init_idx=init_idx, always_split=False,
                 strict=True)
    return out.state, out.caches, out.iters


def fit_restarts(x, kernel, cfg, key, restarts, init="kmeans++",
                 init_idx=None, mesh=None, restart_axis=None,
                 eval_batch_size=None, share_eval_gram=None, _run=None,
                 _init_run=None):
    scfg = _solver_config(
        cfg, kernel, cache="none", distribution="single", jit=True,
        restarts=restarts, init=init, restart_axis=restart_axis,
        eval_batch_size=eval_batch_size, share_eval_gram=share_eval_gram)
    ex = resolve_plan(scfg, n=x.shape[0], mesh=mesh,
                      solver="multi_restart").executor
    out = ex.fit(x, key, init_idx=init_idx, _run=_run, _init_run=_init_run)
    return out.engine
