"""Warn-once plumbing for the legacy ``fit_*`` shims.

Every deprecated entry point warns EXACTLY ONCE per process (per entry
point), with a message that names the :class:`repro.api.SolverConfig`
point replacing it.  ``stacklevel`` is chosen so the warning is attributed
to the *user's* call site, not to the shim — which also keeps the repo's
"warnings from repro are errors" pytest filter from firing on the shims
themselves.
"""
from __future__ import annotations

import warnings

_WARNED: set = set()


def warn_legacy(name: str, replacement: str, *, stacklevel: int = 3) -> None:
    """Emit the one-time DeprecationWarning for legacy entry point ``name``.

    ``stacklevel=3`` attributes the warning to the caller of the shim that
    invoked us (user code -> shim -> warn_legacy -> warnings.warn)."""
    if name in _WARNED:
        return
    _WARNED.add(name)
    warnings.warn(
        f"{name} is deprecated; use repro.api.{replacement} — see "
        "docs/api.md for the migration table. (The shim delegates to the "
        "equivalent solver plan; trajectories are unchanged.)",
        DeprecationWarning, stacklevel=stacklevel)


def reset_warnings() -> None:
    """Forget which entry points have warned (test hook)."""
    _WARNED.clear()
