"""Single source of truth for PRNG key derivation across every fit variant.

Before this module existed, ``fit``, ``fit_cached`` and
``fit_distributed_jit`` each split keys in a slightly different order, so
the same seed produced *different* batch sequences depending on which entry
point you called.  Every solver plan now derives its keys through the
helpers below, which pin down ONE documented derivation:

    root key  (``as_key(seed_or_key)``)
      |
      ├─ single-restart plans (cache x jit x sampler):
      |     (init_key, fit_key) = split(root)          -- split_init
      |     step t:  (fit_key, kb_t) = split(fit_key)  -- next_batch_key
      |     nested sampler: batch t is a pure function of (fit_key, t)
      |     (``sample_batch_nested``; fit_key itself never advances)
      |
      ├─ sharded plans: same (init_key, fit_key) and kb_t stream; each data
      |     shard then draws its slice from fold_in(kb_t, replica_index)
      |     -- shard_key.  (The fold is applied even on a 1-shard mesh, so
      |     sharded trajectories are reproducible across mesh shapes but
      |     intentionally NOT identical to the single-device stream.)
      |
      └─ multi-restart plans:
            (init_key, fit_key, eval_key) = split(root, 3) -- restart_keys
            restart r inits from split(init_key, R)[r] and fits from
            split(fit_key, R)[r]; eval_key draws the shared eval batch.

Consequence: with ``init_idx`` unspecified, the single-device family
(plain / cached / precomputed / jit, iid sampler) draws *identical* batch
sequences from the same seed — the Gram-tile-cache equivalence tests rely
on it being bit-exact.

Legacy note: the deprecated ``fit_*`` shims preserve their historical
behaviour of NOT consuming an init split when ``init_idx`` is passed
explicitly (``KernelKMeans`` always splits, so its stream does not depend
on who drew the init).
"""
from __future__ import annotations

from typing import Tuple, Union

import jax
import jax.numpy as jnp

KeyOrSeed = Union[int, jax.Array]


def as_key(seed_or_key: KeyOrSeed) -> jax.Array:
    """Coerce an int seed (or pass through an existing PRNG key)."""
    if isinstance(seed_or_key, int):
        return jax.random.PRNGKey(seed_or_key)
    return seed_or_key


def split_init(key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """``(init_key, fit_key)`` — the one split every single-restart plan
    performs before touching data.  ``init_key`` seeds the k-means++ /
    random init draw; ``fit_key`` seeds the batch stream."""
    init_key, fit_key = jax.random.split(key)
    return init_key, fit_key


def next_batch_key(key: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Advance the fit stream one step: ``(fit_key', kb)``.

    ``kb`` draws iteration t's batch; ``fit_key'`` carries to t+1.  This is
    the body of every early-stopped loop (host or ``lax.while_loop``)."""
    key, kb = jax.random.split(key)
    return key, kb


def shard_key(kb: jax.Array, replica_index: jax.Array) -> jax.Array:
    """Per-data-shard batch key: fold the step's batch key with the shard's
    flat replica index (``distributed._replica_index``)."""
    return jax.random.fold_in(kb, replica_index)


def restart_keys(key: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """``(init_key, fit_key, eval_key)`` for the multi-restart engine."""
    k_init, k_fit, k_eval = jax.random.split(key, 3)
    return k_init, k_fit, k_eval


def per_restart(key: jax.Array, restarts: int) -> jax.Array:
    """(R, 2) independent per-restart keys from an init/fit key."""
    return jax.random.split(key, restarts)


def batch_key_at(key: jax.Array, step: int) -> jax.Array:
    """The batch key of iteration ``step`` as a pure function of the fit
    key — O(step) splits, for resumable host pipelines
    (``repro.data.pipeline.ClusterBatchPipeline(mode='keyed')``)."""
    kb = key
    for _ in range(step + 1):
        key, kb = next_batch_key(key)
    return kb


def derive_fit_keys(key: jax.Array, init_given: bool,
                    always_split: bool = True):
    """``(init_key, fit_key)`` at fit entry — THE audited root derivation
    every executor family performs (formerly ``executors._derive_keys``,
    duplicated per entry point before PR 3).

    * no explicit init:       ``split_init`` — init draw consumes the first
      split, the fit stream starts from the second.
    * init given, estimator:  ``always_split=True`` still burns the init
      split so the batch stream does not depend on who drew the init.
    * init given, legacy:     ``always_split=False`` reproduces the
      historical shims bit-exactly — the root key IS the fit key.
    """
    if not init_given:
        return split_init(key)
    if always_split:
        return None, split_init(key)[1]
    return None, key


__all__ = [
    "as_key", "split_init", "next_batch_key", "shard_key", "restart_keys",
    "per_restart", "batch_key_at", "derive_fit_keys",
]
