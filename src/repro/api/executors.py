"""Solver executors — declarative plan LOWERINGS onto the fit-loop core.

The loop skeleton itself — stage sequence, early stop, prefetch, the
precision/compress hooks, compiled-program caching, the resumable carry —
lives ONCE in :mod:`repro.core.loop`.  Each executor here supplies only
what genuinely differs between plan families, its :class:`LoopSpec`:

* the **sampler** (iid / weighted / nested / shard-local on-device),
* the **step body** (composed/fused ``make_step``, the cached warm+step
  pair, the shard_map ``_make_sampling_body``),
* the **mesh placement** (single device, data x model, restart x data x
  model) and
* the **donation signature** of its main fit program.

plus the orchestration that used to be copy-pasted across the ``fit_*``
family: PRNG key derivation (:func:`repro.api.keys.derive_fit_keys`),
init drawing (:func:`repro.core.init.draw_init`), divisibility
pad-and-mask (:func:`repro.core.distributed.pad_for_mesh`), and cache
lifecycle (build/warm/thread of the Gram tile cache).  A plan-vs-legacy
trajectory is therefore the *same* compiled computation, and new
cross-cutting axes register against the loop core once instead of once
per family (the PR-5/PR-7 lesson).

Executors are stateful on purpose: they cache the compiled programs
(jitted step / while_loop run) across ``fit`` calls — instance-local plus
the cross-executor registry in the loop core (``lookup_program``), which
is what makes ``KernelKMeans`` dispatch resolve at trace time with zero
per-step Python overhead (see ``benchmarks/run.py api_overhead``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.api import keys as api_keys
from repro.api.config import SolverConfig
from repro.core import init as init_lib
from repro.core.loop import (  # noqa: F401  (canonical home: the loop core;
    # re-exported here for the historical import surface — estimator,
    # service snapshot/telemetry, benchmarks and tests all import these
    # names from repro.api.executors)
    FitCarry, FitOutcome, LoopSpec, _kernel_sig, _x_keyed_run, carry_of,
    clear_program_cache, lookup_program, outcome_from_carry, program_builds,
)
from repro.core.loop import loop_config as _loop_mb
from repro.core.loop import precision_plan
from repro.core.minibatch import (
    assign_chunked, center_distances_chunked, host_fit_loop, make_step,
    run_early_stopped, run_early_stopped_keyed, sampled_step_with_key,
)
from repro.core.state import init_state, window_size

_assign = jax.jit(assign_chunked, static_argnames=("chunk",))
_distances = jax.jit(center_distances_chunked, static_argnames=("chunk",))

# the unified root derivation (see repro.api.keys docstring); kept under
# the historical private name for in-repo callers
_derive_keys = api_keys.derive_fit_keys


class Executor:
    """Base class: holds (config, mesh), resolves the kernel once, and
    provides the serving-side defaults (predict / distances from the
    support-point view of the fitted state)."""

    name = "?"
    supports_partial_fit = False

    def __init__(self, config: SolverConfig, mesh=None):
        self.config = config
        self.mesh = mesh
        self.kernel = config.make_kernel_fn()
        self.mb = config.mb_config()
        self._programs = {}      # instance-local compiled-program cache

    def _program(self, key, build, kernel_free: bool = False):
        """Compiled-program lookup through the loop core's cross-executor
        registry (:func:`repro.core.loop.lookup_program`): instance cache
        first, then the global registry keyed on the executor family +
        ``key`` + the kernel value signature.  ``key`` must capture the
        FULL closure signature minus the kernel — loop statics, mesh/axes,
        and the donated-argnum signature; ``kernel_free`` marks programs
        that take the kernel as a traced ARGUMENT (nothing kernel-shaped
        in the closure), which share unconditionally."""
        return lookup_program(self._programs, type(self).__name__, key,
                              build, kernel=self.kernel,
                              kernel_free=kernel_free)

    def _hooks(self, prefetch_ok: bool = True) -> tuple:
        """Which cross-cutting loop-core axes are ACTIVE under this plan —
        each axis has exactly one registration site in the loop core
        (prefetch: ``drive_fit_loop``; precision: ``precision_plan``;
        compress: ``compress_hook``)."""
        hooks = []
        if prefetch_ok and self.config.prefetch:
            hooks.append("prefetch")
        if precision_plan(self.kernel, self.mb).cdt is not None:
            hooks.append("precision:bf16")
        if self.mb.compress is not None and self.mb.compress.every > 0:
            hooks.append("compress")
        return tuple(hooks)

    def loop_spec(self) -> LoopSpec:
        """How this plan lowers onto the fit-loop core (the explain()
        surface).  Families override to describe their genuine deltas."""
        raise NotImplementedError

    # -- fitting ----------------------------------------------------------
    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            **kw) -> FitOutcome:
        raise NotImplementedError

    def resume(self, x, outcome: FitOutcome, iters: int) -> FitOutcome:
        raise NotImplementedError(
            f"plan {self.name!r} does not support partial_fit resumption")

    # -- serving ----------------------------------------------------------
    def serving_tuple(self, outcome: FitOutcome, x):
        """``(kernel, sup, coef, sqnorm)`` with ``sup`` the (k*W, d)
        support COORDINATES and ``kernel`` directly evaluable on
        coordinates — the uniform serving view every plan lowers to
        (index-data plans resolve their row ids here)."""
        state = outcome.state
        sup = x[state.idx.reshape(-1)]
        return self.kernel, sup, state.coef, state.sqnorm

    def predict(self, outcome: FitOutcome, x, xq, chunk: int = 4096):
        kern, sup, coef, sqnorm = self.serving_tuple(outcome, x)
        return _assign(kern, coef, sqnorm, sup, xq, chunk)

    def distances(self, outcome: FitOutcome, x, xq, chunk: int = 4096):
        kern, sup, coef, sqnorm = self.serving_tuple(outcome, x)
        return _distances(kern, coef, sqnorm, sup, xq, chunk)


def _sharded_batch_setup(executor: "Executor"):
    """Shared data-shard setup for every sharded-family executor: count
    the data shards and round the batch size UP to the next multiple
    (non-divisible batch sizes were a hard error on the legacy surface).
    Sets ``_shards``, ``effective_batch_size`` and ``_mb_eff``."""
    from repro.core.distributed import _data_shard_count

    executor._shards = _data_shard_count(executor.mesh,
                                         executor.config.data_axes)
    b = executor.mb.batch_size
    executor.effective_batch_size = -(-b // executor._shards) * \
        executor._shards
    executor._mb_eff = executor.mb._replace(
        batch_size=executor.effective_batch_size)


# ---------------------------------------------------------------- single
class SingleExecutor(Executor):
    """cache='none', distribution='single', restarts=1 — the paper's plain
    Algorithm-2 fit.  ``jit=True`` runs the whole early-stopped loop as one
    compiled ``lax.while_loop`` (legacy ``fit_jit``); ``jit=False`` (or a
    nested sampler / sample weights) drives it from the host (legacy
    ``fit``)."""

    name = "single"
    supports_partial_fit = True

    def _ensure_host_step(self):
        # donate the carried CenterState — the host loop threads it
        return self._program(
            ("host_step", self.mb, ("donate", 0)),
            lambda: jax.jit(make_step(self.kernel, self.mb),
                            donate_argnums=(0,)))

    def _jit_run(self, kind: str, max_iters: int):
        kernel = self.kernel
        mb = _loop_mb(self.mb, self.config.early_stop, max_iters=max_iters)
        w = window_size(mb.batch_size, mb.tau)
        # donation: the resume program consumes the carried CenterState
        # and fit key (the FitCarry buffers) — steady-state partial_fit
        # chains allocate nothing new per call.  The init program donates
        # NOTHING: its key/init_idx can be caller-owned buffers (the
        # legacy shims pass the user's raw key), which callers may reuse.
        donate = () if kind == "init" else (1, 2)

        def build():
            step = make_step(kernel, mb)

            if kind == "init":
                def run(x, init_idx, key):
                    state0 = init_state(x, init_idx, kernel, w)
                    return run_early_stopped_keyed(
                        mb, sampled_step_with_key(step, x, mb), state0,
                        key)
            else:
                def run(x, state, key):
                    return run_early_stopped_keyed(
                        mb, sampled_step_with_key(step, x, mb), state, key)

            return jax.jit(run, donate_argnums=donate)

        return self._program((kind, mb, ("donate",) + donate), build)

    def _use_jit(self, sample_weight):
        return (self.config.jit and sample_weight is None
                and self.config.sampler == "iid")

    def loop_spec(self) -> LoopSpec:
        jit = self._use_jit(None)
        return LoopSpec(
            lowering=self.name,
            driver="device" if jit else "host",
            sampler=self.config.sampler,
            step=f"make_step[{self.mb.step}]",
            placement="single device",
            donation=("state", "key") if jit else ("state",),
            hooks=self._hooks(prefetch_ok=not jit))

    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            max_iters: Optional[int] = None, **kw) -> FitOutcome:
        cfg = self.config
        mb = self.mb if max_iters is None \
            else self.mb._replace(max_iters=max_iters)
        init_key, fit_key = _derive_keys(key, init_idx is not None,
                                         always_split)
        if init_idx is None:
            init_idx = init_lib.draw_init(init_key, x, mb.k, self.kernel,
                                          cfg.init)

        if self._use_jit(sample_weight):
            run = self._jit_run("init", mb.max_iters)
            state, iters, out_key = run(x, init_idx, fit_key)
            return FitOutcome(state=state, iters=iters, key=out_key,
                              steps=None)

        probs = None
        if sample_weight is not None:
            probs = jnp.asarray(sample_weight, jnp.float32)
            probs = probs / jnp.sum(probs)
        step = self._ensure_host_step()
        w = window_size(mb.batch_size, mb.tau)
        state0 = init_state(x, init_idx, self.kernel, w)
        state, history, out_key = host_fit_loop(
            lambda st, bidx: step(st, x, bidx), x.shape[0], mb, state0,
            fit_key, probs=probs, early_stop=cfg.early_stop,
            sampler=cfg.sampler, reuse=cfg.reuse, refresh=cfg.refresh,
            prefetch=cfg.prefetch)
        return FitOutcome(state=state, iters=len(history), history=history,
                          key=out_key, steps=len(history))

    def resume(self, x, outcome: FitOutcome, iters: int) -> FitOutcome:
        cfg = self.config
        if outcome.key is None:
            raise ValueError("outcome carries no fit key; cannot resume")
        prev = outcome.steps
        if prev is None:
            prev = int(outcome.iters)
        if self._use_jit(None):
            run = self._jit_run("resume", iters)
            state, it2, out_key = run(x, outcome.state, outcome.key)
            return FitOutcome(state=state, iters=it2, key=out_key,
                              steps=prev + int(it2))
        step = self._ensure_host_step()
        mb = self.mb._replace(max_iters=iters)
        state, history, out_key = host_fit_loop(
            lambda st, bidx: step(st, x, bidx), x.shape[0], mb,
            outcome.state, outcome.key, early_stop=cfg.early_stop,
            sampler=cfg.sampler, reuse=cfg.reuse, refresh=cfg.refresh,
            step0=prev, prefetch=cfg.prefetch)
        return FitOutcome(state=state, iters=len(history), history=history,
                          key=out_key, steps=prev + len(history))


# ---------------------------------------------------------- precomputed
class PrecomputedExecutor(Executor):
    """cache='precomputed', distribution='single', restarts=1 — pay the
    n^2 Gram ONCE (``repro.cache.PrecomputedGram``), then every iteration
    is pure gathers.  The right plan when n^2 fits on device (cache='auto'
    picks it below ``config.PRECOMPUTED_AUTO_MAX_ELEMS``).

    The compiled programs take the Gram kernel as a traced ARGUMENT (pk is
    a pytree), so refitting on new data of the same shape reuses the
    compiled loop instead of re-tracing — and can never bake stale Gram
    values in as constants."""

    name = "single_precomputed"

    def loop_spec(self) -> LoopSpec:
        jit = self.config.jit and self.config.sampler == "iid"
        return LoopSpec(
            lowering=self.name,
            driver="device" if jit else "host",
            sampler=self.config.sampler,
            step=f"make_step[{self.mb.step}] over a precomputed Gram "
                 "(traced argument; iterations are pure gathers)",
            placement="single device",
            donation=() if jit else ("state",),
            hooks=self._hooks(prefetch_ok=not jit))

    def _jit_run(self):
        mb = _loop_mb(self.mb, self.config.early_stop)
        w = window_size(mb.batch_size, mb.tau)

        def build():
            def run(pk, xi, init_idx, key):
                step = make_step(pk, mb)
                state0 = init_state(xi, init_idx, pk, w)
                return run_early_stopped_keyed(
                    mb, sampled_step_with_key(step, xi, mb), state0, key)

            return jax.jit(run)

        # the Gram kernel is a traced ARGUMENT, so the program's closure
        # is the loop config alone — shareable regardless of kernel size
        return self._program(("jit_run", mb), build, kernel_free=True)

    def _ensure_host_step(self):
        mb = self.mb

        def build():
            def hstep(pk, state, xi, bidx):
                return make_step(pk, mb)(state, xi, bidx)

            return jax.jit(hstep, donate_argnums=(1,))

        return self._program(("host_step", mb, ("donate", 1)), build,
                             kernel_free=True)

    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            **kw) -> FitOutcome:
        from repro import cache as cache_lib

        cfg, mb = self.config, self.mb
        if sample_weight is not None:
            raise NotImplementedError("precomputed plan does not take "
                                      "sample weights (use cache='none')")
        pk, xi = cache_lib.as_kernel(cache_lib.precompute_gram(self.kernel,
                                                               x))
        init_key, fit_key = _derive_keys(key, init_idx is not None,
                                         always_split)
        if init_idx is None:
            init_idx = init_lib.draw_init(init_key, xi, mb.k, pk, cfg.init)
        if cfg.jit and cfg.sampler == "iid":
            state, iters, out_key = self._jit_run()(pk, xi, init_idx,
                                                    fit_key)
            return FitOutcome(state=state, iters=iters, key=out_key,
                              steps=None, x_view=xi)
        w = window_size(mb.batch_size, mb.tau)
        state0 = init_state(xi, init_idx, pk, w)
        step = self._ensure_host_step()
        state, history, out_key = host_fit_loop(
            lambda st, bidx: step(pk, st, xi, bidx), x.shape[0], mb,
            state0, fit_key, early_stop=cfg.early_stop,
            sampler=cfg.sampler, reuse=cfg.reuse, refresh=cfg.refresh,
            prefetch=cfg.prefetch)
        return FitOutcome(state=state, iters=len(history), history=history,
                          key=out_key, steps=len(history), x_view=xi)


# ------------------------------------------------------------------ lru
class CachedExecutor(Executor):
    """cache='lru', distribution='single', restarts=1 — the Gram tile
    cache fit (legacy ``fit_cached``): warm the batch+window row blocks,
    then the unchanged Algorithm-2 step serves every cross-kernel block
    from resident tiles.  Host-driven (the warm/step pair is one jitted
    program per iteration); the nested sampler keeps the working set
    resident."""

    name = "single_lru"

    def __init__(self, config, mesh=None):
        super().__init__(config, mesh)
        if self.mb.sqnorm_mode != "recompute" or self.mb.eval_mode != \
                "direct":
            # the incremental/delta variants evaluate cross-kernels inside
            # per-center vmaps, where cached lookups degrade to select
            # (both branches run) — correct but strictly slower
            raise ValueError("fit_cached supports the paper-faithful "
                             "sqnorm_mode='recompute' / eval_mode='direct' "
                             "(per-center vmapped kernel evals defeat the "
                             "cache's cond-skip)")

    def loop_spec(self) -> LoopSpec:
        return LoopSpec(
            lowering=self.name,
            driver="host",
            sampler=self.config.sampler,
            step="warm Gram tile cache + make_step (one jitted program)",
            placement="single device",
            donation=("state", "tile cache"),
            hooks=self._hooks())

    def _ensure_step(self):
        from repro import cache as cache_lib
        from repro.cache.tile_cache import warm

        kernel, mb = self.kernel, self.mb

        def build():
            def _cached_step(state, cache, xr, xi, batch_idx):
                # only (state, cache) are donated — the dataset and base
                # kernel buffers stay owned by the caller
                need = jnp.concatenate([batch_idx.astype(jnp.int32),
                                        state.idx.reshape(-1)])
                cache = warm(cache, kernel, xr, need)
                ck_t = cache_lib.CachedKernel(base=kernel, x=xr,
                                              cache=cache)
                st, info = make_step(ck_t, mb)(state, xi, batch_idx)
                return st, cache, info

            return jax.jit(_cached_step, donate_argnums=(0, 1))

        return self._program(
            ("cached_step", mb, self.config.cache_tile,
             self.config.cache_capacity, self.config.cache_dtype,
             ("donate", 0, 1)), build)

    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            **kw) -> FitOutcome:
        from repro import cache as cache_lib

        cfg, mb = self.config, self.mb
        if sample_weight is not None:
            raise NotImplementedError("lru plan does not take sample "
                                      "weights (use cache='none')")
        init_key, fit_key = _derive_keys(key, init_idx is not None,
                                         always_split)
        if init_idx is None:
            init_idx = init_lib.draw_init(init_key, x, mb.k, self.kernel,
                                          cfg.init)
        # pad the CACHE's row space to a tile multiple (the tile store
        # wants tile | n); the sampler draws from the real n rows only, so
        # pad rows are never referenced — only their (wasted) tile slots
        # exist
        n = x.shape[0]
        pad = (-n) % cfg.cache_tile
        x_cache = x if pad == 0 else jnp.concatenate(
            [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])
        ck, xi_full = cache_lib.make_cached(
            self.kernel, x_cache, tile=cfg.cache_tile,
            capacity=cfg.cache_capacity,
            dtype=jnp.dtype(cfg.cache_dtype))
        xi = xi_full[:n]
        w = window_size(mb.batch_size, mb.tau)
        state = init_state(xi, init_idx, ck, w)
        step = self._ensure_step()

        cache = ck.cache

        def step2(st, bidx):
            nonlocal cache
            st, cache, info = step(st, cache, x_cache, xi, bidx)
            return st, info

        state, history, out_key = host_fit_loop(
            step2, n, mb, state, fit_key,
            early_stop=cfg.early_stop, sampler=cfg.sampler,
            reuse=cfg.reuse, refresh=cfg.refresh, prefetch=cfg.prefetch)
        return FitOutcome(state=state, iters=len(history), history=history,
                          key=out_key, steps=len(history),
                          cache=ck._replace(cache=cache), x_view=xi)


# -------------------------------------------------------------- sharded
class ShardedExecutor(Executor):
    """distribution='sharded', cache='none', restarts=1 — the shard_map
    data x model path.  ``jit=True`` is the zero-host-sync while_loop with
    shard-local sampling (legacy ``fit_distributed_jit``); ``jit=False``
    drives the sharded step from a host batch stream (legacy
    ``fit_distributed``, batches drawn through the unified key stream via
    ``ClusterBatchPipeline(mode='keyed')``).

    Divisibility: non-divisible datasets are padded and the shard-local
    samplers masked (``pad_for_mesh`` + ``n_valid``); a batch size that
    does not divide the data shards is rounded UP to the next multiple
    (``effective_batch_size``) — both were hard errors on the legacy
    surface (``strict=True`` restores them for the shims)."""

    name = "sharded"

    def __init__(self, config, mesh=None):
        if mesh is None:
            from repro.launch.mesh import make_cluster_mesh
            mesh = make_cluster_mesh()
        super().__init__(config, mesh)
        _sharded_batch_setup(self)
        self._runs = {}

    def _mb_for(self, strict: bool):
        return self.mb if strict else self._mb_eff

    def _placement(self) -> str:
        cfg = self.config
        return (f"mesh {dict(self.mesh.shape)}: centers over "
                f"{cfg.model_axis!r}, batch over {tuple(cfg.data_axes)!r}")

    def loop_spec(self) -> LoopSpec:
        if self.config.jit:
            return LoopSpec(
                lowering=self.name, driver="device",
                sampler="shard-local on-device (stratified-uniform over "
                        "the data shards)",
                step="_make_sampling_body (shard_map)",
                placement=self._placement(), donation=("DistState",),
                hooks=self._hooks(prefetch_ok=False))
        return LoopSpec(
            lowering=self.name, driver="stream",
            sampler="host batch stream (keyed ClusterBatchPipeline)",
            step="make_dist_step (shard_map)",
            placement=self._placement(), donation=("DistState",),
            hooks=self._hooks())

    def _get_run(self, n_valid, strict: bool):
        mb = self._mb_for(strict)
        loop_mb = _loop_mb(mb, self.config.early_stop)
        cfg = self.config

        def build():
            from repro.core.distributed import make_dist_sampling_step

            step = make_dist_sampling_step(
                self.kernel, mb, self.mesh, cfg.data_axes,
                cfg.model_axis, n_valid=n_valid)

            def run(state, x, key):
                def step_with_key(st, kb):
                    st, info = step(st, x, kb)
                    return st, info.improvement

                return run_early_stopped(loop_mb, step_with_key, state,
                                         key)

            # donate the incoming DistState — it is freshly built and
            # device_put by fit() on every call, never caller-owned
            return jax.jit(run, donate_argnums=(0,))

        return self._program(
            ("dist_run", loop_mb, n_valid, strict, self.mesh,
             cfg.data_axes, cfg.model_axis, ("donate", 0)), build)

    def _resolve_centers(self, x, key, init_idx, center_pts, always_split):
        if center_pts is not None:
            _, fit_key = _derive_keys(key, True, always_split)
            return center_pts, fit_key
        init_key, fit_key = _derive_keys(key, init_idx is not None,
                                         always_split)
        if init_idx is None:
            init_idx = init_lib.draw_init(init_key, x, self.mb.k,
                                          self.kernel, self.config.init)
        return x[init_idx], fit_key

    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            strict: bool = False, pad_fill: float = 0.0,
            **kw) -> FitOutcome:
        from repro.core.distributed import (
            init_dist_state, pad_for_mesh, shard_dataset, state_shardings)

        cfg = self.config
        mb = self._mb_for(strict)
        if sample_weight is not None:
            raise NotImplementedError("sharded plans do not take sample "
                                      "weights (use distribution='single')")
        center_pts, fit_key = self._resolve_centers(
            x, key, init_idx, center_pts, always_split)

        if not cfg.jit:
            return self._fit_host(x, center_pts, fit_key, mb)

        if strict:
            x_p, n_valid = x, None
        else:
            x_p, nv = pad_for_mesh(x, self.mesh, cfg.data_axes,
                                   fill=pad_fill)
            n_valid = None if x_p is x else nv
        w = window_size(mb.batch_size, mb.tau)
        state0 = jax.device_put(
            init_dist_state(center_pts, self.kernel, w),
            state_shardings(self.mesh, cfg.model_axis))
        xs = shard_dataset(x_p, self.mesh, cfg.data_axes)
        state, iters = self._get_run(n_valid, strict)(state0, xs, fit_key)
        return FitOutcome(state=state, iters=iters)

    def _fit_host(self, x, center_pts, fit_key, mb):
        import numpy as np

        from repro.data.pipeline import ClusterBatchPipeline

        pipe = ClusterBatchPipeline(np.asarray(x), batch=mb.batch_size,
                                    mode="keyed", key=fit_key)
        state, history = self.fit_stream(iter(pipe), center_pts, mb=mb)
        return FitOutcome(state=state, iters=len(history), history=history)

    def fit_stream(self, xb_stream, center_pts, mb=None):
        """Drive the sharded step from an arbitrary host iterator of
        (b, d) batches — the legacy ``fit_distributed`` surface (and
        ``cluster_hidden_states``).  With ``config.prefetch`` the next
        batch's host-to-device transfer overlaps the current sharded step
        (one-deep double buffering; bit-identical results)."""
        from repro.core.distributed import _fit_distributed_impl

        cfg = self.config
        return _fit_distributed_impl(
            xb_stream, center_pts, self.kernel, mb or self.mb, self.mesh,
            cfg.data_axes, cfg.model_axis, early_stop=cfg.early_stop,
            prefetch=cfg.prefetch)

    def serving_tuple(self, outcome: FitOutcome, x):
        state = outcome.state                     # DistState: coord windows
        k, w, d = state.pts.shape
        return (self.kernel, state.pts.reshape(k * w, d), state.coef,
                state.sqnorm)

    def predict(self, outcome: FitOutcome, x, xq, chunk: int = 4096):
        from repro.core.distributed import (
            dist_to_center_state, predict_distributed)

        kern, sup, coef, sqnorm = self.serving_tuple(outcome, x)
        return predict_distributed(dist_to_center_state(outcome.state),
                                   sup, xq, kern, self.mesh, chunk=chunk)


# ------------------------------------------------------ sharded + cache
class ShardedCachedExecutor(ShardedExecutor):
    """distribution='sharded', cache='lru', jit=True — per-data-shard Gram
    tile caches carried through the while_loop (legacy
    ``fit_distributed_cached_jit``)."""

    name = "sharded_lru"

    def loop_spec(self) -> LoopSpec:
        return super().loop_spec()._replace(
            step="cached _make_sampling_body (per-shard Gram tile caches "
                 "ride the while_loop carry)",
            donation=("DistState", "shard caches"))

    def _get_cached_run(self, x_real, n_valid, strict: bool):
        def build():
            from repro.core.distributed import (
                make_cached_dist_sampling_step)

            mb = self._mb_for(strict)
            loop_mb = _loop_mb(mb, self.config.early_stop)
            step = make_cached_dist_sampling_step(
                self.kernel, x_real, mb, self.mesh, self.config.data_axes,
                self.config.model_axis, n_valid=n_valid)

            def run(state, caches, x_idx, key):
                def step_with_key(carry, kb):
                    st, cc = carry
                    st, cc, info = step(st, cc, x_idx, kb)
                    return (st, cc), info.improvement

                (state, caches), iters = run_early_stopped(
                    loop_mb, step_with_key, (state, caches), key)
                return state, caches, iters

            # state + caches are the while_loop carry, freshly built per
            # fit — donate both so the loop reuses their buffers in place
            return jax.jit(run, donate_argnums=(0, 1))

        return _x_keyed_run(self._runs, ("cached", n_valid, strict),
                            x_real, build)

    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            strict: bool = False, pad_fill: float = 0.0,
            **kw) -> FitOutcome:
        from repro.cache.cached_kernel import make_cached
        from repro.core.distributed import (
            init_dist_state, init_shard_caches, shard_dataset,
            state_shardings)

        cfg = self.config
        mb = self._mb_for(strict)
        if not cfg.jit:
            raise NotImplementedError(
                "the sharded lru plan is jit-only (the tile caches ride "
                "the while_loop carry); set jit=True or cache='none'")
        if sample_weight is not None:
            raise NotImplementedError("sharded plans do not take sample "
                                      "weights")
        init_key, fit_key = _derive_keys(key, init_idx is not None,
                                         always_split)
        if init_idx is None:
            init_idx = init_lib.draw_init(init_key, x, mb.k, self.kernel,
                                          cfg.init)
        cache_dtype = jnp.dtype(cfg.cache_dtype)
        # one padded row space serves BOTH constraints: divisible over the
        # data shards AND by the cache tile (pad_for_mesh's `multiple`).
        # Pad rows are masked out of the shard-local samplers (n_valid),
        # so only their tile slots exist — their coordinates never reach a
        # batch or a window.
        from repro.core.distributed import pad_for_mesh

        n = x.shape[0]
        if strict:
            x_cache, n_valid = x, None
        else:
            x_cache, nv = pad_for_mesh(x, self.mesh, cfg.data_axes,
                                       fill=pad_fill,
                                       multiple=cfg.cache_tile)
            n_valid = None if x_cache is x else nv
        ck0, xi_full = make_cached(self.kernel, x_cache,
                                   tile=cfg.cache_tile,
                                   capacity=cfg.cache_capacity,
                                   dtype=cache_dtype)
        xi = xi_full[:n]
        w = window_size(mb.batch_size, mb.tau)
        center_data = xi[init_idx]                  # (k, 1) index-data
        state0 = jax.device_put(
            init_dist_state(center_data, ck0, w),
            state_shardings(self.mesh, cfg.model_axis))
        xs = shard_dataset(xi_full, self.mesh, cfg.data_axes)
        caches0 = init_shard_caches(self.mesh, x_cache.shape[0],
                                    cfg.cache_tile, cfg.cache_capacity,
                                    cfg.data_axes, cache_dtype)
        run = self._get_cached_run(x_cache, n_valid, strict)
        state, caches, iters = run(state0, caches0, xs, fit_key)
        return FitOutcome(state=state, iters=iters, caches=caches,
                          x_view=xi)

    def serving_tuple(self, outcome: FitOutcome, x):
        state = outcome.state                  # DistState: index windows
        k, w, _ = state.pts.shape
        ids = state.pts[..., 0].reshape(-1).astype(jnp.int32)
        return self.kernel, x[ids], state.coef, state.sqnorm


# -------------------------------------------------------- multi-restart
class RestartExecutor(Executor):
    """restarts=R>1 — the best-of-R engine as one compiled program
    (legacy ``fit_restarts`` / ``MultiRestartEngine``), restart axis
    optionally device-sharded via a restart mesh.  The compiled R-restart
    program and the vmapped init draw are cached across fits."""

    name = "multi_restart"

    def __init__(self, config, mesh=None):
        super().__init__(config, mesh)
        self._run = None
        self._init_run = None

    def loop_spec(self) -> LoopSpec:
        cfg = self.config
        placement = ("single device (vmapped restart axis)"
                     if self.mesh is None else
                     f"restart axis sharded over mesh {dict(self.mesh.shape)}")
        return LoopSpec(
            lowering=self.name, driver="device",
            sampler=f"iid, R={cfg.restarts} independent per-restart key "
                    "streams",
            step=f"vmap(make_step[{self.mb.step}]) + shared-eval-batch "
                 "winner selection",
            placement=placement, donation=(),
            hooks=self._hooks(prefetch_ok=False))

    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            _run=None, _init_run=None, **kw) -> FitOutcome:
        from repro.core.engine import (
            _fit_restarts, make_init_run, make_restart_run)

        cfg = self.config
        if sample_weight is not None:
            raise NotImplementedError("multi-restart plans do not take "
                                      "sample weights")
        if _run is None:
            if self._run is None:
                self._run = make_restart_run(self.kernel, self.mb,
                                             cfg.share_eval_gram)
                self._init_run = make_init_run(self.kernel, self.mb,
                                               cfg.init)
            _run, _init_run = self._run, self._init_run
        res = _fit_restarts(
            x, self.kernel, self.mb, key, cfg.restarts, init=cfg.init,
            init_idx=init_idx, mesh=self.mesh,
            restart_axis=cfg.restart_axis,
            eval_batch_size=cfg.eval_batch_size,
            share_eval_gram=cfg.share_eval_gram, _run=_run,
            _init_run=_init_run)
        return FitOutcome(state=res.state, iters=res.iters, engine=res)

    def predict(self, outcome: FitOutcome, x, xq, chunk: int = 4096):
        if self.mesh is None:
            return super().predict(outcome, x, xq, chunk=chunk)
        from repro.core.distributed import predict_distributed
        return predict_distributed(outcome.state, x, xq, self.kernel,
                                   self.mesh, chunk=chunk)


# ---------------------------------------------- fused restart x data x model
class FusedRestartExecutor(Executor):
    """restarts=R>1, distribution='sharded', jit — the ROADMAP's fused
    restart x data x model program, the first solver to land purely
    through the registry: R early-stopped SHARDED fits (each one the
    ``sharded`` plan's exact trajectory for its per-restart key) run as
    ONE compiled shard_map program on a ("restart", "data", "model") mesh
    (``launch.mesh.make_fused_mesh``), with shared-eval-batch winner
    selection running sharded and, for ``cache='lru'``, per-(restart,
    data-shard) Gram tile caches riding the while_loop carry
    (``init_shard_caches(..., restarts=R)``)."""

    name = "fused_restart_sharded"

    def __init__(self, config: SolverConfig, mesh=None):
        if mesh is None:
            from repro.launch.mesh import make_fused_mesh
            mesh = make_fused_mesh(config.restarts)
        super().__init__(config, mesh)
        self.restart_axis = config.restart_axis or "restart"
        if self.restart_axis not in mesh.axis_names:
            raise ValueError(
                f"mesh axes {mesh.axis_names} carry no "
                f"{self.restart_axis!r} axis; build a fused mesh with "
                "repro.launch.mesh.make_fused_mesh(restarts)")
        _sharded_batch_setup(self)
        self._runs = {}
        self._init_run = None

    def loop_spec(self) -> LoopSpec:
        cfg = self.config
        cached = cfg.cache == "lru"
        return LoopSpec(
            lowering=self.name, driver="device",
            sampler="shard-local on-device, per-restart key streams",
            step=("cached _make_sampling_body x restarts (per-(restart, "
                  "shard) tile caches ride the carry)" if cached else
                  "_make_sampling_body x restarts (one shard_map program)"),
            placement=(f"mesh {dict(self.mesh.shape)}: restarts over "
                       f"{self.restart_axis!r}, centers over "
                       f"{cfg.model_axis!r}, batch over "
                       f"{tuple(cfg.data_axes)!r}"),
            donation=("shard caches",) if cached else (),
            hooks=self._hooks(prefetch_ok=False))

    def _eval_size(self, n: int) -> int:
        eb = self.config.eval_batch_size \
            or min(4 * self._mb_eff.batch_size, n)
        return -(-eb // self._shards) * self._shards

    def _keys_and_init(self, x, key, init_idx):
        cfg, restarts = self.config, self.config.restarts
        k_init, k_fit, k_eval = api_keys.restart_keys(key)
        if init_idx is None:
            if self._init_run is None:
                from repro.core.engine import make_init_run
                self._init_run = make_init_run(self.kernel, self._mb_eff,
                                               cfg.init)
            init_idx = self._init_run(api_keys.per_restart(k_init, restarts),
                                      x)
        if init_idx.shape[0] != restarts:
            raise ValueError(f"init_idx has {init_idx.shape[0]} rows, "
                             f"expected {restarts}")
        return init_idx, api_keys.per_restart(k_fit, restarts), k_eval

    def _get_run(self, n_valid, eval_size, x_real=None):
        def build():
            from repro.core.engine import make_fused_restart_run

            cfg = self.config
            return make_fused_restart_run(
                self.kernel, _loop_mb(self._mb_eff, cfg.early_stop),
                self.mesh, cfg.restarts, data_axes=cfg.data_axes,
                model_axis=cfg.model_axis, restart_axis=self.restart_axis,
                n_valid=n_valid, eval_size=eval_size, x_real=x_real)

        return _x_keyed_run(self._runs,
                            (n_valid, eval_size, x_real is not None),
                            x_real, build)

    def fit(self, x, key, init_idx=None, center_pts=None,
            sample_weight=None, always_split: bool = True,
            pad_fill: float = 0.0, **kw) -> FitOutcome:
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.core.distributed import init_dist_state, pad_for_mesh
        from repro.core.minibatch import sample_batch
        from repro.launch.sharding import (
            fused_state_placements, restart_placements)

        cfg = self.config
        if not cfg.jit:
            raise NotImplementedError(
                "the fused restart plan is jit-only (R restarts x data x "
                "model in one compiled program); set jit=True, or "
                "distribution='single' for a host-driven restart loop")
        if sample_weight is not None:
            raise NotImplementedError("sharded plans do not take sample "
                                      "weights (use distribution='single')")
        if center_pts is not None:
            raise NotImplementedError("the fused restart plan draws R "
                                      "independent inits; pass init_idx "
                                      "of shape (R, k) instead of "
                                      "center_pts")
        init_idx, fit_keys, k_eval = self._keys_and_init(x, key, init_idx)
        n = x.shape[0]
        eval_size = self._eval_size(n)
        eval_idx = sample_batch(k_eval, n, eval_size)   # real rows only
        w = window_size(self._mb_eff.batch_size, self._mb_eff.tau)
        xspec = NamedSharding(self.mesh, P(tuple(cfg.data_axes), None))

        if cfg.cache == "lru":
            return self._fit_cached(x, init_idx, fit_keys, eval_idx,
                                    eval_size, w, xspec, pad_fill)

        x_p, nv = pad_for_mesh(x, self.mesh, cfg.data_axes, fill=pad_fill)
        n_valid = None if x_p is x else nv
        state0 = jax.device_put(
            jax.vmap(lambda cp: init_dist_state(cp, self.kernel, w))(
                x[init_idx]),
            fused_state_placements(self.mesh, self.restart_axis,
                                   cfg.model_axis))
        (fit_keys,), _ = restart_placements(self.mesh, self.restart_axis,
                                            (fit_keys,))
        run = self._get_run(n_valid, eval_size)
        res = run(state0, jax.device_put(x_p, xspec),
                  jax.device_put(x[eval_idx], xspec), fit_keys)
        return FitOutcome(state=res.state, iters=res.iters, engine=res)

    def _fit_cached(self, x, init_idx, fit_keys, eval_idx, eval_size, w,
                    xspec, pad_fill):
        from repro.cache.cached_kernel import make_cached
        from repro.core.distributed import (
            init_dist_state, init_shard_caches, pad_for_mesh)
        from repro.launch.sharding import (
            fused_state_placements, restart_placements)

        cfg = self.config
        cache_dtype = jnp.dtype(cfg.cache_dtype)
        n = x.shape[0]
        x_cache, nv = pad_for_mesh(x, self.mesh, cfg.data_axes,
                                   fill=pad_fill, multiple=cfg.cache_tile)
        n_valid = None if x_cache is x else nv
        ck0, xi_full = make_cached(self.kernel, x_cache,
                                   tile=cfg.cache_tile,
                                   capacity=cfg.cache_capacity,
                                   dtype=cache_dtype)
        xi = xi_full[:n]
        state0 = jax.device_put(
            jax.vmap(lambda cp: init_dist_state(cp, ck0, w))(xi[init_idx]),
            fused_state_placements(self.mesh, self.restart_axis,
                                   cfg.model_axis))
        (fit_keys,), _ = restart_placements(self.mesh, self.restart_axis,
                                            (fit_keys,))
        caches0 = init_shard_caches(
            self.mesh, x_cache.shape[0], cfg.cache_tile, cfg.cache_capacity,
            cfg.data_axes, cache_dtype, restarts=cfg.restarts,
            restart_axis=self.restart_axis)
        run = self._get_run(n_valid, eval_size, x_real=x_cache)
        res, caches = run(state0, caches0, jax.device_put(xi_full, xspec),
                          jax.device_put(x[eval_idx], xspec), fit_keys)
        return FitOutcome(state=res.state, iters=res.iters, engine=res,
                          caches=caches, x_view=xi)

    def serving_tuple(self, outcome: FitOutcome, x):
        state = outcome.state                 # DistState, model-sharded
        k, w, d = state.pts.shape
        if self.config.cache == "lru":        # index windows
            ids = state.pts[..., 0].reshape(-1).astype(jnp.int32)
            return self.kernel, x[ids], state.coef, state.sqnorm
        return (self.kernel, state.pts.reshape(k * w, d), state.coef,
                state.sqnorm)

    def predict(self, outcome: FitOutcome, x, xq, chunk: int = 4096):
        from repro.core.distributed import (
            dist_to_center_state, predict_distributed)

        kern, sup, coef, sqnorm = self.serving_tuple(outcome, x)
        return predict_distributed(dist_to_center_state(outcome.state),
                                   sup, xq, kern, self.mesh, chunk=chunk)
