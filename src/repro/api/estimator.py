"""``KernelKMeans`` — sklearn-style estimator over the solver-plan layer.

    from repro.api import KernelKMeans, SolverConfig

    est = KernelKMeans(SolverConfig(k=8, kernel="rbf",
                                    kernel_params={"kappa": 2.0},
                                    cache="auto", restarts=4))
    est.fit(x, key=0)
    labels = est.predict(xq)
    est.save("centers.npz"); served = KernelKMeans.load("centers.npz")

One ``fit`` for every execution point (cache x distribution x restarts x
sampler x jit); the estimator resolves the config to a plan
(:func:`repro.api.plan.resolve_plan`), caches the executor — and with it
the compiled programs — across fits, and owns the serving surface
(``predict`` / ``transform`` / ``score``) plus the ``save``/``load``
state round-trip for serving processes.
"""
from __future__ import annotations

import io
import json
import struct
import zipfile
import zlib
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import keys as api_keys
from repro.api.config import SolverConfig, field_names
from repro.api.executors import (
    _assign, _distances, carry_of, outcome_from_carry, FitCarry,
)
from repro.core.kernel_fns import kernel_spec, make_kernel
from repro.core.state import CenterState

# SolverConfig fields that are JSON-serializable as-is (everything except
# the kernel spec, which save() lowers to (name, params)).
_JSON_FIELDS = tuple(f for f in field_names()
                     if f not in ("kernel", "kernel_params"))

# format-3 integrity footer: the npz payload is followed by 8 bytes —
# a 4-byte magic + the CRC32 of the payload.  Disk corruption anywhere
# in the file (payload OR footer) fails verification; the zip container
# alone catches truncation but not in-place bit flips.
_CRC_MAGIC = b"KKC3"
_CRC_FOOTER = struct.Struct("<4sI")


class SnapshotIntegrityError(RuntimeError):
    """Snapshot file failed its integrity check (CRC mismatch, truncated
    or undecodable container) — the bytes on disk are not the bytes that
    were saved.  Callers must treat the file as garbage: quarantine and
    fall back, never serve from it."""


def _verified_payload(path: str) -> bytes:
    """The npz payload of ``path`` with its format-3 CRC footer verified
    and stripped.  Legacy files (format 1/2, no footer) pass through
    whole — their container parse is their only integrity check."""
    with open(path, "rb") as f:
        raw = f.read()
    if len(raw) >= _CRC_FOOTER.size:
        magic, crc = _CRC_FOOTER.unpack(raw[-_CRC_FOOTER.size:])
        if magic == _CRC_MAGIC:
            payload = raw[:-_CRC_FOOTER.size]
            if zlib.crc32(payload) != crc:
                raise SnapshotIntegrityError(
                    f"CRC mismatch in {path}: stored {crc:#010x}, "
                    f"computed {zlib.crc32(payload):#010x}")
            return payload
    return raw


class KernelKMeans:
    """Mini-batch kernel k-means estimator (the paper's Algorithm 2 under
    every execution strategy the repo implements).

    Parameters: a :class:`SolverConfig` (or field overrides as kwargs) and
    an optional ``mesh`` for the sharded / restart-sharded plans.

    Fitted attributes: ``state_`` (truncated-center state), ``history_``
    (host-driven plans), ``iters_``, ``cache_`` (tile cache(s), cached
    plans), ``result_`` (per-restart ``EngineResult``, multi-restart
    plans), ``plan_`` (the resolved :class:`repro.api.plan.Plan`).
    """

    def __init__(self, config: Optional[SolverConfig] = None, *,
                 mesh=None, **overrides):
        if config is None:
            config = SolverConfig(**overrides)
        elif overrides:
            config = config.replace(**overrides)
        self.config = config
        self.mesh = mesh
        self.plan_ = None
        self._plan_sig = None
        self._carry_solver = None  # plan name a load()ed carry came from
        self._outcome = None
        self._x = None
        self._serving = None      # (kernel, sup, coef, sqnorm) after load()
        self.state_ = None
        self.history_ = None
        self.iters_ = None
        self.cache_ = None
        self.result_ = None
        # landmark-compression counters (docs/compression.md): cumulative
        # across the estimator's life, survive save()/load()
        self._compress_stats = {"compressions": 0, "m": None,
                                "last_drift": None, "ratio": None}

    # ------------------------------------------------------------- plans
    def plan_for(self, n: int):
        """Resolve (and cache) the execution plan for an n-row dataset.
        The executor — and the compiled programs it holds — is reused
        across fits as long as the resolved execution point is stable."""
        from repro.api.plan import resolve_plan

        resolved = self.config.resolve(n=n, mesh=self.mesh)
        sig = (resolved.cache, resolved.distribution, resolved.restarts,
               resolved.sampler, resolved.jit)
        if self.plan_ is None or sig != self._plan_sig:
            self.plan_ = resolve_plan(self.config, n=n, mesh=self.mesh)
            self._plan_sig = sig
        return self.plan_

    # --------------------------------------------------------------- fit
    def fit(self, X, key: Any = 0, *, init_idx=None, sample_weight=None):
        """Fit on ``(n, d)`` data (or the ``(n, 1)`` index view of a
        precomputed kernel).  ``key``: int seed or JAX PRNG key — the
        estimator derives init/fit keys through :mod:`repro.api.keys`, so
        the same seed draws the same batch sequence on every
        single-restart plan."""
        X = jnp.asarray(X)
        key = api_keys.as_key(key)
        plan = self.plan_for(X.shape[0])
        out = plan.executor.fit(X, key, init_idx=init_idx,
                                sample_weight=sample_weight)
        self._set_fitted(X, out)
        return self

    def partial_fit(self, X, key: Any = 0, *, iters: Optional[int] = None):
        """Continue (or start) fitting for ``iters`` more iterations
        (default ``config.max_iters``), resuming the batch-key stream
        exactly where the previous call stopped — ``fit(max_iters=a+b)``
        and ``fit(max_iters=a); partial_fit(iters=b)`` draw identical
        batches.  Single-restart, single-device plans only.

        .. note:: on the compiled (``jit=True``) plan the resume program
           DONATES the previous fitted state's buffers (steady-state
           partial_fit chains allocate nothing per call) — a reference
           to the pre-call ``state_`` is dead afterwards; snapshot it
           with ``jax.device_get`` / ``np.asarray`` first if you need
           the before/after pair."""
        X = jnp.asarray(X)
        iters = iters if iters is not None else self.config.max_iters
        if self._outcome is None:
            plan = self.plan_for(X.shape[0])
            if not plan.executor.supports_partial_fit:
                raise NotImplementedError(
                    f"plan {plan.name!r} does not support partial_fit "
                    "(use restarts=1, distribution='single', "
                    "cache='none')")
            out = plan.executor.fit(X, api_keys.as_key(key),
                                    max_iters=iters)
            self._set_fitted(X, out)
            return self
        # A load()ed estimator carries a resumable outcome but no plan
        # yet.  Resume on the SAVED plan, not whatever ``auto`` axes would
        # resolve to for the resume dataset's size — otherwise e.g. a
        # cache='auto' fit on large data (plan 'single') resumed on small
        # data would re-resolve to 'single_precomputed' and refuse.
        if self.plan_ is None and self._carry_solver is not None:
            from repro.api.plan import resolve_plan

            self.plan_ = resolve_plan(self.config, n=X.shape[0],
                                      mesh=self.mesh,
                                      solver=self._carry_solver)
            # a sentinel signature no plan_for() resolution can equal: a
            # later full fit() must re-resolve through the registry
            # instead of inheriting the carry-forced executor
            self._plan_sig = ("carry", self._carry_solver)
        plan = self.plan_ if self.plan_ is not None \
            else self.plan_for(X.shape[0])
        if not plan.executor.supports_partial_fit:
            raise NotImplementedError(
                f"plan {plan.name!r} does not support partial_fit")
        out = plan.executor.resume(X, self._outcome, iters)
        if self.history_ is not None and out.history is not None:
            out.history = self.history_ + out.history
        self._set_fitted(X, out)
        return self

    def _set_fitted(self, X, out):
        self._x = X
        self._outcome = out
        self._serving = None
        self.state_ = out.state
        self.history_ = out.history
        self.iters_ = out.iters
        self.cache_ = out.cache if out.cache is not None else out.caches
        self.result_ = out.engine

    # ----------------------------------------------------------- serving
    def _serving_tuple(self):
        if self._serving is not None:
            return self._serving
        if self._outcome is None:
            raise RuntimeError("fit() (or load()) before serving")
        return self.plan_.executor.serving_tuple(self._outcome, self._x)

    def predict(self, X, chunk: int = 4096):
        """Nearest-center labels (nq,) for coordinate queries."""
        X = jnp.asarray(X)
        if self._serving is None and self._outcome is not None:
            return self.plan_.executor.predict(self._outcome, self._x, X,
                                               chunk=chunk)
        kern, sup, coef, sqnorm = self._serving_tuple()
        return _assign(kern, coef, sqnorm, sup, X, chunk)

    def transform(self, X, chunk: int = 4096):
        """Feature-space distances d(x, C_j), (nq, k) — the
        cluster-distance embedding."""
        kern, sup, coef, sqnorm = self._serving_tuple()
        return _distances(kern, coef, sqnorm, sup, jnp.asarray(X), chunk)

    def score(self, X) -> float:
        """Negative clustering objective (mean min squared feature-space
        distance) — higher is better, sklearn-style."""
        d = self.transform(X)
        return -float(jnp.mean(jnp.min(d, axis=1)))

    def fit_predict(self, X, key: Any = 0, **kw):
        return self.fit(X, key, **kw).predict(X)

    # ----------------------------------------------------------- explain
    def explain(self, n: Optional[int] = None, *, d: int = 16,
                deep: bool = False) -> dict:
        """The resolved execution plan WITHOUT fitting anything: the
        registered solver it lowers to, the resolved config axes, the
        plan's :class:`repro.core.loop.LoopSpec` (sampler / step body /
        placement / donation / active hooks) and the canonical fit-loop
        stage sequence.  ``serve --dry-run`` prints exactly this.

        ``n``: dataset rows to resolve the plan for (the ``auto`` axes are
        size-dependent); defaults to the fitted dataset's size, else 4096.
        ``deep=True`` additionally ``.lower().compile()``'s the plain
        single-device step on ``(n, d)`` ShapeDtypeStructs and attaches
        its HLO memory/cost/collective analysis
        (:func:`repro.launch.analysis.analyze_compiled`)."""
        from repro.core import loop as loop_lib

        if n is None:
            n = self._x.shape[0] if self._x is not None else 4096
        plan = self.plan_for(n)
        resolved = self.config.resolve(n=n, mesh=self.mesh)
        spec = plan.executor.loop_spec()
        out = {
            "plan": plan.name,
            "n": int(n),
            "config": {f: getattr(resolved, f) for f in
                       ("cache", "distribution", "restarts", "sampler",
                        "jit", "step", "precision", "prefetch",
                        "compute_dtype")},
            "lowering": dict(spec._asdict()),
            "stages": loop_lib.stages(spec),
        }
        if deep:
            out["compiled_step"] = self._explain_deep(plan, n, d)
        return out

    def _explain_deep(self, plan, n: int, d: int) -> dict:
        """HLO analysis of the representative step program.  Only the
        plain coordinate-kernel step is analyzable without a dataset in
        the closure (precomputed/cached/sharded programs are built inside
        ``fit`` around the actual Gram / tile caches / mesh placement)."""
        if plan.name != "single":
            return {"note": f"plan {plan.name!r} builds its step program "
                            "inside fit (dataset / tile-cache / mesh "
                            "closure); fit once and inspect "
                            "program_builds() or benchmarks/run.py "
                            "instead"}
        from repro.core.minibatch import make_step
        from repro.core.state import init_state, window_size
        from repro.launch.analysis import analyze_compiled

        ex = plan.executor
        mb = ex.mb
        w = window_size(mb.batch_size, mb.tau)
        x_s = jax.ShapeDtypeStruct((n, d), jnp.float32)
        idx_s = jax.ShapeDtypeStruct((mb.k,), jnp.int32)
        state_s = jax.eval_shape(
            lambda x, i: init_state(x, i, ex.kernel, w), x_s, idx_s)
        b_s = jax.ShapeDtypeStruct((mb.batch_size,), jnp.int32)
        compiled = jax.jit(make_step(ex.kernel, mb)).lower(
            state_s, x_s, b_s).compile()
        return analyze_compiled(compiled)

    # ----------------------------------------------- landmark compression
    def compress(self, m: Optional[int] = None,
                 selector: Optional[str] = None,
                 jitter: Optional[float] = None) -> "KernelKMeans":
        """Project the SERVING representation onto ``m`` landmark rows per
        center (:class:`repro.landmark.serving.CompressedKernelCenters`):
        predict/transform/score afterwards cost O(k*m) per query and never
        touch the original support window.  Defaults come from the
        ``compress`` config axis.  The resumable fit carry is untouched —
        ``partial_fit`` keeps full fidelity and re-derives fresh serving
        state (compress again after it for bounded serving; the service
        Learner does exactly that each round).  Landmark selection is
        keyed by the fit step counter, so a crash-recovered learner
        reproduces the same compressed model bit-for-bit."""
        from repro.landmark.compress import CompressSpec
        from repro.landmark.serving import CompressedKernelCenters

        spec = self.config.compress_spec()
        if spec is None:
            spec = CompressSpec()
        if m is not None:
            spec = spec._replace(m=int(m))
        if selector is not None:
            spec = spec._replace(selector=selector)
        if jitter is not None:
            spec = spec._replace(jitter=float(jitter))
        kern, sup, coef, sqnorm = self._serving_tuple()
        k, w = coef.shape
        if spec.m >= w:
            return self   # already at/below the target support size
        step = self.state_.step if self.state_ is not None else \
            self._compress_stats["compressions"]
        ckc, info = CompressedKernelCenters.from_serving(
            kern, sup, coef, sqnorm, spec=spec._replace(every=0), step=step)
        self._serving = ckc.serving_tuple()
        st = self._compress_stats
        st["compressions"] += 1
        st["m"] = spec.m
        st["last_drift"] = float(info.drift_bound)
        st["ratio"] = spec.m / w
        return self

    def support_stats(self) -> Optional[dict]:
        """Live serving-support telemetry (present even with
        ``compress="off"``): total support rows, active (coef != 0) rows,
        the per-center window W, and the compression counters.  ``None``
        before fit()/load()."""
        if self._serving is None and self._outcome is None:
            return None
        _, sup, coef, _ = self._serving_tuple()
        coef = np.asarray(coef)
        k, w = coef.shape
        return {"rows": int(sup.shape[0]), "active":
                int(np.count_nonzero(coef)), "window": int(w), "k": int(k),
                **self._compress_stats}

    # ---------------------------------------------------- snapshot hooks
    # The serving split (repro.service) drives a long-lived estimator from
    # learner threads: it needs the resumable carry as HOST arrays (the
    # compiled resume program donates the device buffers, so a device-side
    # reference dies on the next partial_fit) and an in-place restore that
    # keeps the resolved plan — these three hooks are that surface.

    def snapshot_carry(self):
        """The current :class:`FitCarry` with every array leaf
        materialized to host numpy — safe to hold across donating
        ``partial_fit`` calls, to checkpoint, or to hand to another
        thread.  ``None`` when the fitted plan is not resumable."""
        carry = carry_of(self._outcome)
        if carry is None:
            return None
        return FitCarry(
            state=jax.tree.map(lambda a: np.asarray(a), carry.state),
            key=np.asarray(carry.key), steps=carry.steps,
            iters=carry.iters)

    def restore_carry(self, carry: FitCarry) -> "KernelKMeans":
        """Adopt ``carry`` as the resume point for the next
        ``partial_fit`` (the inverse of :meth:`snapshot_carry`); the
        resolved plan and compiled programs are kept."""
        self._outcome = outcome_from_carry(
            FitCarry(state=jax.tree_util.tree_map(jnp.asarray, carry.state),
                     key=jnp.asarray(carry.key), steps=carry.steps,
                     iters=carry.iters))
        self._serving = None
        self.state_ = self._outcome.state
        self.iters_ = self._outcome.iters
        self.history_ = None
        return self

    def save_atomic(self, path: str) -> str:
        """:meth:`save` through a same-directory temp file +
        ``os.replace`` — a concurrent reader (a serving actor) sees either
        the complete old file or the complete new one, never a torn
        write."""
        import os

        tmp = f"{path}.tmp.{os.getpid()}"
        self.save(tmp)
        os.replace(tmp, path)
        return path

    # -------------------------------------------------------- save / load
    def save(self, path: str) -> str:
        """Serialize the serving state (support coordinates, coefficients,
        center norms) plus the config to an ``.npz``.  Works for every
        plan whose kernel has a registry spec (``kernel_spec``) — cached /
        precomputed / sharded states are lowered to base-kernel support
        coordinates first, so a served prediction needs no cache, Gram or
        mesh.

        Plans that support ``partial_fit`` additionally round-trip their
        full :class:`repro.api.executors.FitCarry` — the center state,
        the carried PRNG fit key and the step cursor — so
        ``fit(a); save; load; partial_fit(b)`` draws exactly the batches
        ``fit(a); partial_fit(b)`` would have drawn (bit-identical
        states)."""
        kern, sup, coef, sqnorm = self._serving_tuple()
        name, params = kernel_spec(kern)
        # format 2 (the compressed-representation bump): adds "format" and
        # "compress" meta keys; the serving arrays may be a landmark-
        # compressed (k*m)-row representation while the carry arrays stay
        # the full resumable window.
        # format 3 (the integrity bump): the same npz payload followed by
        # an 8-byte CRC32 footer so disk corruption is DETECTED at load
        # time (SnapshotIntegrityError) instead of silently decoding to
        # garbage centers.  load() still accepts format-1 files (no
        # "format" key) and footer-less format-2 files unchanged — see
        # tests/test_save_load_skew.py.
        meta = {"format": 3, "kernel": name, "kernel_params": params,
                "config": {f: getattr(self.config, f)
                           for f in _JSON_FIELDS},
                "compress": self._compress_stats}
        arrays = dict(sup=np.asarray(sup), coef=np.asarray(coef),
                      sqnorm=np.asarray(sqnorm))
        # resumable iff the plan supports partial_fit; an estimator that
        # was itself load()ed (no plan yet) only holds an outcome when its
        # saved carry was resumable, so it keeps round-tripping
        resumable = (self.plan_.executor.supports_partial_fit
                     if self.plan_ is not None else self._x is None)
        carry = carry_of(self._outcome) if resumable else None
        if carry is not None and isinstance(carry.state, CenterState):
            for f, v in zip(carry.state._fields, carry.state):
                arrays[f"carry_{f}"] = np.asarray(v)
            arrays["carry_key"] = np.asarray(carry.key)
            meta["carry"] = {"steps": carry.steps, "iters": carry.iters,
                             "solver": (self.plan_.name
                                        if self.plan_ is not None
                                        else self._carry_solver)}
        buf = io.BytesIO()
        np.savez(buf, meta=np.frombuffer(
            json.dumps(meta).encode(), dtype=np.uint8), **arrays)
        payload = buf.getvalue()
        with open(path, "wb") as f:
            f.write(payload)
            f.write(_CRC_FOOTER.pack(_CRC_MAGIC, zlib.crc32(payload)))
        return path

    @classmethod
    def load(cls, path: str) -> "KernelKMeans":
        """Rebuild a serving estimator (``predict`` / ``transform`` /
        ``score``).  When the file carries a :class:`FitCarry` (saved by a
        ``partial_fit``-capable plan), the estimator is also RESUMABLE:
        ``partial_fit(X)`` continues the batch-key stream exactly where
        the saved fit stopped."""
        payload = _verified_payload(path)
        try:
            with np.load(io.BytesIO(payload)) as data:
                meta = json.loads(bytes(data["meta"]).decode())
                sup = jnp.asarray(data["sup"])
                coef = jnp.asarray(data["coef"])
                sqnorm = jnp.asarray(data["sqnorm"])
                carry = None
                if "carry_key" in data:
                    state = CenterState(*(jnp.asarray(data[f"carry_{f}"])
                                          for f in CenterState._fields))
                    cmeta = meta["carry"]
                    carry = FitCarry(state=state,
                                     key=jnp.asarray(data["carry_key"]),
                                     steps=cmeta["steps"],
                                     iters=cmeta["iters"])
        except (zipfile.BadZipFile, KeyError, OSError,
                json.JSONDecodeError, EOFError, ValueError) as e:
            # legacy (footer-less) files have no CRC; any undecodable
            # container — truncated write, bit flip inside a zip member —
            # surfaces as ONE clean error class, never garbage centers
            raise SnapshotIntegrityError(
                f"undecodable snapshot {path}: {e}") from e
        fmt = meta.get("format", 1)   # pre-compression files carry no key
        if fmt > 3:
            raise ValueError(f"snapshot format {fmt} is newer than this "
                             "build understands (<= 3)")
        cfg_dict = dict(meta["config"])
        cfg_dict["kernel"] = meta["kernel"]
        cfg_dict["kernel_params"] = meta["kernel_params"]
        est = cls(SolverConfig(**cfg_dict))
        if fmt >= 2 and meta.get("compress"):
            est._compress_stats.update(meta["compress"])
        est._serving = (make_kernel(meta["kernel"],
                                    **meta["kernel_params"]),
                        sup, coef, sqnorm)
        if carry is not None:
            est._outcome = outcome_from_carry(carry)
            est._carry_solver = meta["carry"].get("solver")
            est.state_ = est._outcome.state
            est.iters_ = est._outcome.iters
        return est
