"""Sharding rules: parameter / optimizer / batch / cache PartitionSpecs.

Strategy (DESIGN.md §4): tensor parallelism over 'model', data parallelism
over ('pod','data'); MoE experts use 'model' as the expert-parallel axis;
optimizer state is ZeRO-upgraded over 'data'.  Every rule is a preference
list — the first axis whose size divides the dimension wins, otherwise the
dimension is replicated (e.g. kv_heads=8 on a 16-wide model axis).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axis_size(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        return int(np.prod([mesh.shape[n] for n in name]))
    return mesh.shape[name]


def _pick(mesh: Mesh, shape, prefs) -> P:
    """prefs: list of (dim, axis) tried in order; first divisible wins."""
    spec = [None] * len(shape)
    used = set()
    for dim, axis in prefs:
        if dim >= len(shape) or spec[dim] is not None:
            continue
        key = axis if isinstance(axis, tuple) else (axis,)
        if any(a in used for a in key):
            continue
        if shape[dim] % _axis_size(mesh, axis) == 0:
            spec[dim] = axis
            used.update(key)
    return P(*spec)


# ------------------------------------------------------------ parameters
def _param_rule(path: str, shape, mesh: Mesh, n_lead: int):
    """n_lead: stacked-layer leading axes (never sharded)."""
    m = "model"
    body = len(shape) - n_lead

    def pk(*prefs):
        return _pick(mesh, shape, [(d + n_lead, a) for d, a in prefs])

    name = path.split("|")[-1].strip("'[]")
    if body <= 1:
        return P()  # norms / scalar vectors: replicate
    if name == "embed":
        return pk((0, m))
    if name == "lm_head":
        return pk((1, m))
    if name == "frontend_w":
        return pk((1, m))
    if name in ("wq", "wk", "wv") and body == 3:   # GQA (D, H, hd)
        return pk((1, m), (2, m))
    if name in ("bq", "bk", "bv"):
        return pk((0, m), (1, m))
    if name == "wo":                         # (H, hd, D) / rwkv (D, D)
        return pk((0, m), (1, m))
    if name in ("wuq", "wuk", "wuv"):        # MLA (in, H, hd)
        return pk((1, m))
    if name in ("wdkv", "wkr", "wdq"):       # MLA down-projections: small
        return P(*([None] * len(shape)))
    if name == "router":
        return P(*([None] * len(shape)))
    if name in ("wg", "wu", "wd") and body == 3:   # MoE experts (E, *, *)
        return pk((0, m))
    if name in ("wg", "wu", "w1", "wk"):     # MLP in-projections (D, F)
        return pk((1, m))
    if name in ("wd", "w2", "wv") and body == 2:   # MLP out (F, D)
        return pk((0, m))
    if name == "w_in":                       # mamba (D, X)
        return pk((1, m))
    if name == "w_out":                      # mamba (d_inner, D)
        return pk((0, m))
    if name == "conv_w":                     # (K, C)
        return pk((1, m))
    if name in ("wr",):                      # rwkv receptance (D, D)
        return pk((1, m))
    if name in ("w1", "w2", "u", "mu"):      # rwkv loras: small
        return P(*([None] * len(shape)))
    return P(*([None] * len(shape)))


def _n_lead_of(path: str) -> int:
    # stacked layers: params under 'layers' have 1 leading axis (L,) —
    # hybrid archs have 2 (groups, attn_every)
    if "layers" not in path:
        return 0
    return path.count("layers_lead")  # patched below


def param_specs(params_shape: Any, mesh: Mesh, hybrid: bool = False,
                replicate_patterns: tuple = ()):
    """Tree of PartitionSpecs matching the params pytree (shapes or arrays).
    Leaves whose path contains any of `replicate_patterns` are replicated
    (e.g. ('tm',) switches rwkv time-mix to pure data parallelism)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(params_shape)
    specs = []
    for path, leaf in flat:
        pstr = "|".join(str(p) for p in path)
        if any(pat in pstr for pat in replicate_patterns):
            specs.append(P())
            continue
        n_lead = 0
        if "layers" in pstr:
            n_lead = 2 if hybrid else 1
        # _param_rule returns a full-rank spec (it offsets by n_lead itself)
        specs.append(_param_rule(pstr, leaf.shape, mesh, n_lead))
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero_upgrade(spec_tree: Any, shape_tree: Any, mesh: Mesh,
                 axis: str = "data"):
    """ZeRO-1: shard optimizer moments over 'data' on the first replicated,
    divisible dimension (on top of the parameter's TP sharding)."""

    def up(spec: P, leaf):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for d, cur in enumerate(parts):
            if cur is None and leaf.shape[d] % mesh.shape[axis] == 0 \
                    and leaf.shape[d] > 0:
                parts[d] = axis
                break
        return P(*parts)

    return jax.tree.map(up, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def named(tree_specs: Any, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))


# ------------------------------------------------------------------ batch
def batch_specs(batch_shape: Any, mesh: Mesh):
    dp = tuple(a for a in mesh.axis_names if a != "model")

    def rule(path, leaf):
        name = str(path[-1]).strip("'[]")
        shape = leaf.shape
        if name == "positions" and len(shape) == 3:   # (3, B, S) mrope
            return _pick(mesh, shape, [(1, dp)])
        return _pick(mesh, shape, [(0, dp)])

    flat, treedef = jax.tree_util.tree_flatten_with_path(batch_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in flat])


# ------------------------------------------------------------------ cache
def cache_specs(cache_shape: Any, mesh: Mesh, hybrid: bool = False):
    """Decode caches: batch over data axes when divisible, else the
    sequence/capacity axis over 'data' (long-context SP), heads over
    'model' when divisible."""
    dp = tuple(a for a in mesh.axis_names if a != "model")
    m = "model"

    def rule(path, leaf):
        pstr = "|".join(str(p) for p in path)
        name = pstr.split("|")[-1].strip("'[]")
        n_lead = 2 if (hybrid and "blocks" in pstr) else 1
        shape = leaf.shape

        def pk(*prefs):
            return _pick(mesh, shape,
                         [(d + n_lead, a) for d, a in prefs])

        if name in ("k", "v"):        # (B, C, K, hd)
            return pk((0, dp), (2, m), (1, "data"), (3, m))
        if name == "kpos":            # (B, C)
            return pk((0, dp), (1, "data"))
        if name in ("ckv", "kr"):     # (B, C, l)
            return pk((0, dp), (1, "data"), (2, m))
        if name == "conv":            # (B, K-1, C)
            return pk((0, dp), (2, m))
        if name == "ssm":             # (B, H, P, N)
            return pk((0, dp), (1, m))
        if name == "state":           # rwkv (B, nh, hd, hd)
            return pk((0, dp), (1, m))
        if name in ("x_tm", "x_cm"):  # (B, D)
            return pk((0, dp), (1, m))
        return P()

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_shape)
    return jax.tree_util.tree_unflatten(
        treedef, [rule(p, l) for p, l in flat])


# ------------------------------------------------------- clustering engine
def restart_placements(mesh: Mesh, restart_axis: str, sharded: Any,
                       replicated: Any = None):
    """Placements for the multi-restart clustering engine: every leaf of
    ``sharded`` has its leading (restart) axis split over ``restart_axis``;
    every leaf of ``replicated`` is broadcast to all devices.  Returns the
    device_put trees (sharded_tree, replicated_tree)."""

    def shard_one(a):
        spec = P(restart_axis, *([None] * (a.ndim - 1)))
        return jax.device_put(a, NamedSharding(mesh, spec))

    sh = jax.tree.map(shard_one, sharded)
    rep = (jax.tree.map(
        lambda a: jax.device_put(a, NamedSharding(mesh, P())), replicated)
        if replicated is not None else None)
    return sh, rep


def fused_state_placements(mesh: Mesh, restart_axis: str = "restart",
                           model_axis: str = "model"):
    """NamedShardings for a restart-STACKED ``DistState`` (a leading (R,)
    axis on every leaf) on a restart x data x model mesh — the initial
    placement of the ``fused_restart_sharded`` plan: restarts split over
    ``restart_axis``, centers over ``model_axis``, everything replicated
    over the data axes (the dataset itself is placed separately,
    row-sharded over data)."""
    from repro.core.distributed import DistState

    r, m = restart_axis, model_axis
    spec = DistState(pts=P(r, m, None, None), coef=P(r, m, None),
                     head=P(r, m), sqnorm=P(r, m), counts=P(r, m),
                     step=P(r))
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec,
                        is_leaf=lambda s: isinstance(s, P))


# ------------------------------------------------------------- train state
def train_state_specs(state_shape: Any, mesh: Mesh, hybrid: bool = False,
                      replicate_patterns: tuple = ()):
    """TrainState(params, AdamWState(master, mu, nu, count), step, ef)."""
    from repro.train.train_step import TrainState
    from repro.train.optimizer import AdamWState

    ps = param_specs(state_shape.params, mesh, hybrid, replicate_patterns)
    zp = zero_upgrade(ps, state_shape.params, mesh)
    opt = AdamWState(master=zp, mu=zp, nu=zp, count=P())
    ef = (jax.tree.map(lambda _: P(), state_shape.ef_error)
          if state_shape.ef_error is not None else None)
    return TrainState(params=ps, opt=opt, step=P(), ef_error=ef)
