"""ShapeDtypeStruct stand-ins for every model input — weak-type-correct,
shardable, no device allocation (the dry-run lowers against these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, Shape
from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.train import make_train_state


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, shape: Shape):
    b, s = shape.global_batch, shape.seq_len
    batch = {"labels": _sds((b, s), jnp.int32)}
    if cfg.frontend == "stub":
        batch["embeds"] = _sds((b, s, cfg.frontend_dim), jnp.bfloat16)
    else:
        batch["tokens"] = _sds((b, s), jnp.int32)
    return batch


def prefill_batch_specs(cfg: ModelConfig, shape: Shape):
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "stub":
        return {"embeds": _sds((b, s, cfg.frontend_dim), jnp.bfloat16)}
    return {"tokens": _sds((b, s), jnp.int32)}


def decode_specs(cfg: ModelConfig, shape: Shape):
    """(cache, tokens, pos) — 'one new token with a KV cache of seq_len'."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
    return cache, _sds((b, 1), jnp.int32), _sds((b,), jnp.int32)


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def train_state_struct(cfg: ModelConfig):
    return jax.eval_shape(
        lambda k: make_train_state(init_params(cfg, k)),
        jax.random.PRNGKey(0))


def input_specs(cfg: ModelConfig, shape_name: str):
    """The full input pytree for the step lowered at this cell."""
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return {"state": train_state_struct(cfg),
                "batch": train_batch_specs(cfg, shape)}
    if shape.kind == "prefill":
        return {"params": params_specs(cfg),
                "batch": prefill_batch_specs(cfg, shape)}
    cache, tok, pos = decode_specs(cfg, shape)
    return {"params": params_specs(cfg), "cache": cache,
            "tokens": tok, "pos": pos}
