"""Serving launcher: batched prefill + decode loop.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving "
                         "(see DESIGN.md skip notes)")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    b, s = args.batch, args.prompt_len
    key = jax.random.PRNGKey(args.seed + 1)
    if cfg.frontend == "stub":
        batch = {"embeds": jax.random.normal(key, (b, s, cfg.frontend_dim))}
    else:
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}

    cache_len = s + args.gen + 8
    t0 = time.time()
    logits, cache = prefill(params, cfg, batch, cache_len=cache_len)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    dstep = jax.jit(lambda p, c, t, q: decode_step(p, cfg, c, t, q),
                    donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = dstep(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={s} generated={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({b * s / t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.1f} ms "
          f"({b * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample token ids:", gen[0, :10].tolist())


if __name__ == "__main__":
    main()
