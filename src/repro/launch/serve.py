"""Serving launcher: batched prefill + decode loop, plus the clustering
serving path (multi-restart fit -> sharded assignment of large query sets).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --batch 4 --prompt-len 32 --gen 16

    # clustering: fit best-of-R on-device, then serve sharded predictions
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --cluster --restarts 4 \
        --n 8192 --queries 65536 --k 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_lm(args):
    from repro.configs import get_config
    from repro.models import decode_step, init_params, prefill

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving "
                         "(see DESIGN.md skip notes)")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    b, s = args.batch, args.prompt_len
    key = jax.random.PRNGKey(args.seed + 1)
    if cfg.frontend == "stub":
        batch = {"embeds": jax.random.normal(key, (b, s, cfg.frontend_dim))}
    else:
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}

    cache_len = s + args.gen + 8
    t0 = time.time()
    logits, cache = prefill(params, cfg, batch, cache_len=cache_len)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    dstep = jax.jit(lambda p, c, t, q: decode_step(p, cfg, c, t, q),
                    donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = dstep(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={s} generated={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({b * s / t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.1f} ms "
          f"({b * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample token ids:", gen[0, :10].tolist())


def serve_cluster(args):
    """Fit the multi-restart engine, then serve sharded batch assignment —
    the clustering analogue of prefill+decode: one expensive fit, then
    high-throughput predict over query shards."""
    from repro.core import Gaussian, MBConfig, MultiRestartEngine
    from repro.core.distributed import predict_distributed
    from repro.data import blobs
    from repro.launch.mesh import make_restart_mesh

    x, _ = blobs(n=args.n, d=args.d, k=args.k, seed=args.seed)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(1.0))
    cfg = MBConfig(k=args.k, batch_size=args.batch_size, tau=args.tau,
                   max_iters=args.max_iters, epsilon=-1.0)
    mesh = make_restart_mesh(args.restarts)
    eng = MultiRestartEngine(kern, cfg, restarts=args.restarts, mesh=mesh)

    t0 = time.time()
    res = eng.fit(x, jax.random.PRNGKey(args.seed))
    jax.block_until_ready(res.objectives)
    t_fit = time.time() - t0
    print(f"cluster fit: R={args.restarts} on {mesh.devices.size} device(s) "
          f"in {t_fit * 1e3:.1f} ms; best objective "
          f"{float(res.objective):.4f} (restart {int(res.best)}, "
          f"per-restart {[round(float(o), 4) for o in res.objectives]})")

    xq = jnp.tile(x, (-(-args.queries // args.n), 1))[:args.queries]
    pred = predict_distributed(res.state, x, xq, kern, mesh)  # warm compile
    pred.block_until_ready()
    t0 = time.time()
    pred = predict_distributed(res.state, x, xq, kern, mesh)
    pred.block_until_ready()
    t_pred = time.time() - t0
    print(f"serve: {xq.shape[0]} queries in {t_pred * 1e3:.1f} ms "
          f"({xq.shape[0] / max(t_pred, 1e-9):.0f} assignments/s, "
          f"sharded over {mesh.devices.size} device(s))")
    print("cluster sizes:", jnp.bincount(pred, length=args.k).tolist())


def serve_cluster_cached(args):
    """Serving demo for the Gram tile cache subsystem (repro.cache):

    fit with the nested sampler warming a device-resident tile cache, then
    serve repeated-row query batches through ``predict_cached`` — the
    hit/miss/eviction counters are the measured kernel-evaluation telemetry
    (every miss = tile x n evaluations; hits are pure gathers).

    ``--cache-mode precomputed`` swaps the LRU for the full-Gram fast path
    (PrecomputedGram) — the right call when n^2 fits on device."""
    from repro.cache import as_kernel, precompute_gram, predict_cached, stats
    from repro.core import Gaussian, MBConfig, predict
    from repro.core.minibatch import fit_cached
    from repro.data import blobs

    x, _ = blobs(n=args.n, d=args.d, k=args.k, seed=args.seed)
    x = jnp.asarray(x)
    kern = Gaussian(kappa=jnp.float32(1.0))
    cfg = MBConfig(k=args.k, batch_size=args.batch_size, tau=args.tau,
                   max_iters=args.max_iters, epsilon=-1.0)

    if args.cache_mode == "precomputed":
        t0 = time.time()
        pk, xi = as_kernel(precompute_gram(kern, x))
        jax.block_until_ready(pk.gram)
        print(f"precomputed Gram: n={args.n} in "
              f"{(time.time() - t0) * 1e3:.1f} ms "
              f"({args.n * args.n} kernel evals, once)")
        from repro.core import fit
        t0 = time.time()
        state, hist = fit(xi, pk, cfg, jax.random.PRNGKey(args.seed),
                          early_stop=False)
        print(f"fullbatch-Gram fit: {len(hist)} iters in "
              f"{(time.time() - t0) * 1e3:.1f} ms (0 further kernel evals)")
        xq = jnp.tile(xi, (-(-args.queries // args.n), 1))[:args.queries]
        t0 = time.time()
        pred = predict(state, xi, xq, pk, chunk=4096)
        pred.block_until_ready()
        t_pred = time.time() - t0
        print(f"serve: {xq.shape[0]} queries in {t_pred * 1e3:.1f} ms "
              f"({xq.shape[0] / max(t_pred, 1e-9):.0f} assignments/s)")
        print("cluster sizes:", jnp.bincount(pred, length=args.k).tolist())
        return

    t0 = time.time()
    state, hist, ck = fit_cached(
        x, kern, cfg, jax.random.PRNGKey(args.seed),
        tile=args.cache_tile, capacity=args.cache_capacity,
        sampler="nested", early_stop=False)
    jax.block_until_ready(state.sqnorm)
    t_fit = time.time() - t0
    s = stats(ck.cache)
    print(f"cached fit: {len(hist)} iters in {t_fit * 1e3:.1f} ms — "
          f"hits {s['hits']} misses {s['misses']} "
          f"evictions {s['evictions']} "
          f"(hit rate {s['hit_rate']:.2%}, {s['evals']} kernel evals)")

    # repeated-row query stream: the serving regime the cache targets
    qidx = jnp.tile(jnp.arange(args.n, dtype=jnp.int32),
                    -(-args.queries // args.n))[:args.queries]
    pred, ck = predict_cached(ck, state, qidx, chunk=4096)  # warm compile
    pred.block_until_ready()
    before = stats(ck.cache)
    t0 = time.time()
    pred, ck = predict_cached(ck, state, qidx, chunk=4096)
    pred.block_until_ready()
    t_pred = time.time() - t0
    after = stats(ck.cache)
    print(f"serve: {qidx.shape[0]} queries in {t_pred * 1e3:.1f} ms "
          f"({qidx.shape[0] / max(t_pred, 1e-9):.0f} assignments/s) — "
          f"+{after['hits'] - before['hits']} hits "
          f"+{after['misses'] - before['misses']} misses "
          f"(lifetime hit rate {after['hit_rate']:.2%})")
    print("cluster sizes:", jnp.bincount(pred, length=args.k).tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # clustering serving path
    ap.add_argument("--cluster", action="store_true",
                    help="serve kernel k-means assignments instead of an LM")
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--queries", type=int, default=65536)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--tau", type=int, default=128)
    ap.add_argument("--max-iters", type=int, default=40)
    # Gram tile cache serving demo (repro.cache)
    ap.add_argument("--cache", action="store_true",
                    help="serve through the Gram tile cache with hit/miss/"
                         "eviction counters (implies --cluster)")
    ap.add_argument("--cache-mode", choices=["lru", "precomputed"],
                    default="lru")
    ap.add_argument("--cache-tile", type=int, default=512)
    ap.add_argument("--cache-capacity", type=int, default=16)
    args = ap.parse_args()

    if args.cache:
        serve_cluster_cached(args)
        return
    if args.cluster:
        serve_cluster(args)
        return
    if args.arch is None:
        raise SystemExit("--arch is required unless --cluster is given")
    serve_lm(args)


if __name__ == "__main__":
    main()
