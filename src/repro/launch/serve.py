"""Serving launcher: batched prefill + decode loop, plus the clustering
serving path (multi-restart fit -> sharded assignment of large query sets)
and the always-on service demo (repro.service learner/actor split).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --batch 4 --prompt-len 32 --gen 16

    # clustering: fit best-of-R on-device, then serve sharded predictions
    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    PYTHONPATH=src python -m repro.launch.serve --cluster --restarts 4 \
        --n 8192 --queries 65536 --k 8

    # serve from a published snapshot instead of refitting in-process
    PYTHONPATH=src python -m repro.launch.serve --cluster \
        --snapshot centers.npz --queries 65536

    # always-on service: learner thread publishing snapshots, actor
    # microbatching requests against the latest one
    PYTHONPATH=src python -m repro.launch.serve --service \
        --rounds 12 --requests 200
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def serve_lm(args):
    from repro.configs import get_config
    from repro.models import decode_step, init_params, prefill

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode serving "
                         "(see DESIGN.md skip notes)")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    b, s = args.batch, args.prompt_len
    key = jax.random.PRNGKey(args.seed + 1)
    if cfg.frontend == "stub":
        batch = {"embeds": jax.random.normal(key, (b, s, cfg.frontend_dim))}
    else:
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}

    cache_len = s + args.gen + 8
    t0 = time.time()
    logits, cache = prefill(params, cfg, batch, cache_len=cache_len)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    dstep = jax.jit(lambda p, c, t, q: decode_step(p, cfg, c, t, q),
                    donate_argnums=(1,))
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = jnp.full((b,), s + i, jnp.int32)
        logits, cache = dstep(params, cache, tok, pos)
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={b} prompt={s} generated={args.gen}")
    print(f"prefill: {t_prefill * 1e3:.1f} ms "
          f"({b * s / t_prefill:.0f} tok/s)")
    print(f"decode:  {t_decode * 1e3:.1f} ms "
          f"({b * (args.gen - 1) / max(t_decode, 1e-9):.0f} tok/s)")
    print("sample token ids:", gen[0, :10].tolist())


def serve_cluster(args):
    """Fit best-of-R through the KernelKMeans estimator (the restart axis
    device-sharded), then serve sharded batch assignment — the clustering
    analogue of prefill+decode: one expensive fit, then high-throughput
    predict over query shards.

    With ``--snapshot PATH`` the fit is skipped entirely: the estimator
    is rebuilt from a published snapshot (``KernelKMeans.load`` — the
    same file the service's learner publishes) and serves from it; the
    fitting and serving processes need share nothing but that file."""
    from repro.api import KernelKMeans, SolverConfig
    from repro.data import blobs
    from repro.launch.mesh import make_restart_mesh

    x, _ = blobs(n=args.n, d=args.d, k=args.k, seed=args.seed)
    x = jnp.asarray(x)

    if args.snapshot:
        t0 = time.time()
        est = KernelKMeans.load(args.snapshot)
        print(f"cluster serve: loaded snapshot {args.snapshot} "
              f"(k={est.config.k}, kernel={est.config.kernel!r}) "
              f"in {(time.time() - t0) * 1e3:.1f} ms — no in-process fit")
    else:
        cfg = SolverConfig(k=args.k, batch_size=args.batch_size,
                           tau=args.tau, max_iters=args.max_iters,
                           epsilon=-1.0, kernel="rbf",
                           kernel_params={"kappa": 1.0}, cache="none",
                           distribution="single", restarts=args.restarts)
        mesh = make_restart_mesh(args.restarts)
        est = KernelKMeans(cfg, mesh=mesh)

        t0 = time.time()
        res = est.fit(x, key=args.seed).result_
        jax.block_until_ready(res.objectives)
        t_fit = time.time() - t0
        print(f"cluster fit [{est.plan_.name}]: R={args.restarts} on "
              f"{mesh.devices.size} device(s) "
              f"in {t_fit * 1e3:.1f} ms; best objective "
              f"{float(res.objective):.4f} (restart {int(res.best)}, "
              f"per-restart {[round(float(o), 4) for o in res.objectives]})")
        if args.save_snapshot:
            est.save_atomic(args.save_snapshot)
            print(f"saved snapshot -> {args.save_snapshot}")

    xq = jnp.tile(x, (-(-args.queries // args.n), 1))[:args.queries]
    pred = est.predict(xq)                     # warm compile
    pred.block_until_ready()
    t0 = time.time()
    pred = est.predict(xq)
    pred.block_until_ready()
    t_pred = time.time() - t0
    where = ("from snapshot" if args.snapshot
             else f"sharded over {est.mesh.devices.size} device(s)")
    print(f"serve: {xq.shape[0]} queries in {t_pred * 1e3:.1f} ms "
          f"({xq.shape[0] / max(t_pred, 1e-9):.0f} assignments/s, "
          f"{where})")
    print("cluster sizes:",
          jnp.bincount(pred, length=est.config.k).tolist())


def serve_cluster_cached(args):
    """Serving demo for the Gram tile cache subsystem (repro.cache):

    fit with the nested sampler warming a device-resident tile cache, then
    serve repeated-row query batches through ``predict_cached`` — the
    hit/miss/eviction counters are the measured kernel-evaluation telemetry
    (every miss = tile x n evaluations; hits are pure gathers).

    ``--cache-mode precomputed`` swaps the LRU for the full-Gram fast path
    (PrecomputedGram) — the right call when n^2 fits on device."""
    from repro.api import KernelKMeans, SolverConfig
    from repro.cache import predict_cached, stats
    from repro.data import blobs

    x, _ = blobs(n=args.n, d=args.d, k=args.k, seed=args.seed)
    x = jnp.asarray(x)
    cfg = SolverConfig(k=args.k, batch_size=args.batch_size, tau=args.tau,
                       max_iters=args.max_iters, epsilon=-1.0,
                       kernel="rbf", kernel_params={"kappa": 1.0},
                       distribution="single", jit=False,
                       cache_tile=args.cache_tile,
                       cache_capacity=args.cache_capacity)

    if args.cache_mode == "precomputed":
        est = KernelKMeans(cfg.replace(cache="precomputed"))
        t0 = time.time()
        est.fit(x, key=args.seed)
        hist = est.history_
        print(f"precomputed-Gram fit [{est.plan_.name}]: {len(hist)} iters "
              f"in {(time.time() - t0) * 1e3:.1f} ms "
              f"({args.n * args.n} kernel evals once, 0 per iteration)")
        xq = jnp.tile(x, (-(-args.queries // args.n), 1))[:args.queries]
        est.predict(xq).block_until_ready()       # warm compile
        t0 = time.time()
        pred = est.predict(xq)
        pred.block_until_ready()
        t_pred = time.time() - t0
        print(f"serve: {xq.shape[0]} queries in {t_pred * 1e3:.1f} ms "
              f"({xq.shape[0] / max(t_pred, 1e-9):.0f} assignments/s)")
        print("cluster sizes:", jnp.bincount(pred, length=args.k).tolist())
        return

    est = KernelKMeans(cfg.replace(cache="lru", sampler="nested"))
    t0 = time.time()
    est.fit(x, key=args.seed)
    jax.block_until_ready(est.state_.sqnorm)
    t_fit = time.time() - t0
    state, ck, hist = est.state_, est.cache_, est.history_
    s = stats(ck.cache)
    print(f"cached fit [{est.plan_.name}]: {len(hist)} iters in "
          f"{t_fit * 1e3:.1f} ms — "
          f"hits {s['hits']} misses {s['misses']} "
          f"evictions {s['evictions']} "
          f"(hit rate {s['hit_rate']:.2%}, {s['evals']} kernel evals)")

    # repeated-row query stream: the serving regime the cache targets
    qidx = jnp.tile(jnp.arange(args.n, dtype=jnp.int32),
                    -(-args.queries // args.n))[:args.queries]
    pred, ck = predict_cached(ck, state, qidx, chunk=4096)  # warm compile
    pred.block_until_ready()
    before = stats(ck.cache)
    t0 = time.time()
    pred, ck = predict_cached(ck, state, qidx, chunk=4096)
    pred.block_until_ready()
    t_pred = time.time() - t0
    after = stats(ck.cache)
    print(f"serve: {qidx.shape[0]} queries in {t_pred * 1e3:.1f} ms "
          f"({qidx.shape[0] / max(t_pred, 1e-9):.0f} assignments/s) — "
          f"+{after['hits'] - before['hits']} hits "
          f"+{after['misses'] - before['misses']} misses "
          f"(lifetime hit rate {after['hit_rate']:.2%})")
    print("cluster sizes:", jnp.bincount(pred, length=args.k).tolist())
    # the uniform service telemetry shape (repro.service.telemetry):
    # cache counters + compile counter in the same dict every service
    # component reports through
    from repro.service import telemetry
    t = telemetry.poll(cache=ck.cache)
    print(telemetry.format_line(t))


def serve_dryrun(args):
    """``--dry-run``: resolve the clustering plan for the requested shape
    and print its lowering onto the fit-loop core (``KernelKMeans
    .explain()``) — which solver, which sampler/step body/placement, the
    donation signature, the active cross-cutting hooks and the canonical
    stage sequence — without touching data or compiling a fit.  With
    ``--cluster`` flags this describes exactly the plan ``serve
    --cluster`` would run."""
    from repro.api import KernelKMeans, SolverConfig
    from repro.launch.mesh import make_restart_mesh

    mesh = None
    kw = dict(k=args.k, batch_size=args.batch_size, tau=args.tau,
              max_iters=args.max_iters, kernel="rbf",
              kernel_params={"kappa": 1.0})
    if args.restarts > 1:
        kw.update(cache="none", distribution="single",
                  restarts=args.restarts)
        mesh = make_restart_mesh(args.restarts)
    est = KernelKMeans(SolverConfig(**kw), mesh=mesh)
    info = est.explain(n=args.n, d=args.d, deep=args.deep)
    print(f"plan [{info['plan']}] for n={info['n']}:")
    cfgline = ", ".join(f"{k}={v!r}" for k, v in info["config"].items())
    print(f"  config: {cfgline}")
    low = info["lowering"]
    for f in ("driver", "sampler", "step", "placement", "donation",
              "hooks"):
        print(f"  {f}: {low[f]}")
    print("  stages:")
    for i, s in enumerate(info["stages"]):
        print(f"    {i + 1}. {s}")
    if "compiled_step" in info:
        cs = info["compiled_step"]
        if "note" in cs:
            print(f"  compiled step: {cs['note']}")
        else:
            mem, cost = cs["memory"], cs["cost"]
            print(f"  compiled step: peak {mem['peak_bytes']} B, "
                  f"{cost['flops_per_device']:.3e} flops, "
                  f"{cost['bytes_per_device']:.3e} B accessed, "
                  f"collective {cs['collectives']['total']} B")


def serve_service(args):
    """Always-on clustering service demo (repro.service): a learner
    thread runs continuous partial_fit over the bounded ingest buffer and
    publishes versioned snapshots; an actor thread serves microbatched
    predictions from the latest snapshot with admission queueing and
    atomic swap.  Prints the uniform telemetry line per publish and a
    final summary."""
    from repro.service.demo import run_demo

    compress = "off"
    if args.compress_m:
        compress = {"m": args.compress_m, "every": args.compress_every,
                    "selector": args.compress_selector}
    t = run_demo(rounds=args.rounds, requests=args.requests,
                 request_rows=args.request_rows, seed=args.seed,
                 k=args.k, d=args.d, capacity=args.buffer_capacity,
                 batch_size=args.batch_size, tau=args.tau,
                 iters_per_round=args.iters_per_round,
                 publish_every=args.publish_every,
                 buffer_mode=args.buffer_mode,
                 arrivals_per_step=args.arrivals_per_step,
                 log_every=args.publish_every, compress=compress)
    demo = t["demo"]
    lat = t["latency_ms"]
    print(f"service: served {demo['served']} requests "
          f"(client saw {demo['client_rejected']} backpressure rejects) "
          f"over {demo['rounds']} learner rounds, snapshot versions "
          f"{demo['versions']}")
    print(f"service: p50 {lat['p50']:.2f} ms, p99 {lat['p99']:.2f} ms, "
          f"serve compiles {t['programs']['serve_compiles']}, "
          f"fit builds {t['programs']['fit_builds']}")
    sup = t.get("support")
    if sup:
        print(f"service: support rows={sup['rows']} (window W="
              f"{sup['window']}), compressions={sup['compressions']}, "
              f"m={sup['m']}, drift={sup['last_drift']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    # clustering serving path
    ap.add_argument("--cluster", action="store_true",
                    help="serve kernel k-means assignments instead of an LM")
    ap.add_argument("--snapshot", default=None,
                    help="serve --cluster from this saved snapshot "
                         "(KernelKMeans.load) instead of refitting "
                         "in-process")
    ap.add_argument("--save-snapshot", default=None,
                    help="after a --cluster fit, atomically save the "
                         "snapshot here (for later --snapshot serving)")
    ap.add_argument("--restarts", type=int, default=4)
    ap.add_argument("--n", type=int, default=8192)
    ap.add_argument("--d", type=int, default=16)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--queries", type=int, default=65536)
    ap.add_argument("--batch-size", type=int, default=256)
    ap.add_argument("--tau", type=int, default=128)
    ap.add_argument("--max-iters", type=int, default=40)
    # Gram tile cache serving demo (repro.cache)
    ap.add_argument("--cache", action="store_true",
                    help="serve through the Gram tile cache with hit/miss/"
                         "eviction counters (implies --cluster)")
    ap.add_argument("--cache-mode", choices=["lru", "precomputed"],
                    default="lru")
    ap.add_argument("--cache-tile", type=int, default=512)
    ap.add_argument("--cache-capacity", type=int, default=16)
    # plan inspection (docs/architecture.md)
    ap.add_argument("--dry-run", action="store_true",
                    help="print the resolved clustering plan's lowering "
                         "onto the fit-loop core (KernelKMeans.explain) "
                         "and exit — no data, no fit")
    ap.add_argument("--deep", action="store_true",
                    help="with --dry-run: also .lower().compile() the "
                         "step program and print its HLO memory/cost "
                         "analysis")
    # always-on service demo (repro.service)
    ap.add_argument("--service", action="store_true",
                    help="run the learner/actor service demo "
                         "(docs/serving.md)")
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--request-rows", type=int, default=256)
    ap.add_argument("--buffer-capacity", type=int, default=2048)
    ap.add_argument("--buffer-mode", choices=["reservoir", "nested"],
                    default="reservoir")
    ap.add_argument("--arrivals-per-step", type=int, default=512)
    ap.add_argument("--iters-per-round", type=int, default=4)
    ap.add_argument("--publish-every", type=int, default=4)
    # landmark compression (docs/compression.md)
    ap.add_argument("--compress-m", type=int, default=0,
                    help="landmark count m per center: > 0 enables "
                         "round-cadence compression in the --service "
                         "learner (serving cost O(k*m), flat in rounds)")
    ap.add_argument("--compress-every", type=int, default=0,
                    help="additionally compress in-loop every N fit "
                         "iterations (0: round cadence only)")
    ap.add_argument("--compress-selector", choices=["uniform", "leverage"],
                    default="uniform")
    args = ap.parse_args()

    if args.dry_run:
        serve_dryrun(args)
        return
    if args.service:
        serve_service(args)
        return
    if args.cache:
        serve_cluster_cached(args)
        return
    if args.cluster:
        serve_cluster(args)
        return
    if args.arch is None:
        raise SystemExit("--arch is required unless --cluster is given")
    serve_lm(args)


if __name__ == "__main__":
    main()
