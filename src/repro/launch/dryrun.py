import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: .lower().compile() every (architecture x input-shape x
mesh) cell on the production meshes and extract the roofline terms.

MUST be run as its own process (python -m repro.launch.dryrun ...): the
XLA_FLAGS line above executes before any jax import, giving this process 512
placeholder CPU devices so jax.make_mesh can build the 16x16 and 2x16x16
production meshes.  Nothing is allocated — inputs are ShapeDtypeStructs.

Outputs one JSON per cell under experiments/dryrun/ with:
  memory_analysis   (bytes per device — proves it fits)
  cost_analysis     (HLO flops / bytes accessed, per device)
  collective_bytes  (parsed from the compiled HLO: all-gather, all-reduce,
                     reduce-scatter, all-to-all, collective-permute)
  roofline terms    (compute / memory / collective seconds, §Roofline)
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import all_arch_names, get_config
from repro.configs.shapes import SHAPES, applicable
from repro.launch import sharding as shl
from repro.launch import specs as spx
from repro.launch.mesh import make_production_mesh
from repro.models import decode_step, forward_train, prefill
from repro.train import AdamWConfig, make_train_step

# --- TPU v5e hardware constants (roofline denominators) -----------------
PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link

# HLO analysis (collective-byte parsing, memory/cost summaries) lives in
# repro.launch.analysis so in-process callers (KernelKMeans.explain,
# serve --dry-run) can use it without this module's XLA_FLAGS side effect.
from repro.launch.analysis import (  # noqa: E402,F401
    COLLECTIVE_RE, DTYPE_BYTES, SHAPE_RE, collective_bytes_of,
)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) for train;
    2 N D for prefill; 2 N per token for decode (D = tokens processed)."""
    from repro.models import init_params
    struct = jax.eval_shape(lambda k: init_params(cfg, k),
                            jax.random.PRNGKey(0))

    def leaf_count(tree):
        return sum(int(x.size) for x in jax.tree.leaves(tree))

    n_total = leaf_count(struct)
    # active params: for MoE count top_k+shared of the expert stack
    if cfg.moe:
        flat, _ = jax.tree_util.tree_flatten_with_path(struct)
        expert_params = sum(
            int(leaf.size) for path, leaf in flat
            if "mlp" in str(path) and leaf.ndim >= 3 and "layers" in
            str(path) and any(s in str(path) for s in ("wg", "wu", "wd"))
            and leaf.shape[-3] == cfg.n_experts)
        n_active = (n_total - expert_params
                    + expert_params * cfg.top_k / cfg.n_experts)
    else:
        n_active = n_total
    toks = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                 else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * toks


def build_lowerable(arch: str, shape_name: str, mesh, opts=(),
                    cfg_mod=None):
    """Returns (fn, kwargs_structs, in_shardings, out_shardings, donate).

    opts (§Perf beyond-paper switches, default off = paper/naive baseline):
      moe_group            per-data-shard MoE dispatch (+ mesh constraints)
      rwkv_chunked         chunked-matmul WKV instead of sequential scan
      rwkv_dp              replicate rwkv time-mix weights (pure DP; fixes
                           40-heads-vs-16-axis resharding)
      cluster_sharded_gram shard the <C,C> Gram rows over the data axes
    """
    import dataclasses

    from jax.sharding import NamedSharding, PartitionSpec as P

    if arch == "paper_cluster":
        return build_cluster_lowerable(mesh, opts)
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    if "moe_group" in opts:
        cfg = dataclasses.replace(cfg, moe_group_dispatch=True)
    if "rwkv_chunked" in opts:
        cfg = dataclasses.replace(cfg, rwkv_chunked=True)
    if "attn_bf16" in opts:
        cfg = dataclasses.replace(cfg, attn_scores_bf16=True)
    if cfg_mod:
        cfg = dataclasses.replace(cfg, **cfg_mod)
    hybrid = cfg.family == "hybrid"

    rep = ("tm",) if "rwkv_dp" in opts else ()
    if shape.kind == "train":
        state_struct = spx.train_state_struct(cfg)
        batch_struct = spx.train_batch_specs(cfg, shape)
        st_specs = shl.train_state_specs(state_struct, mesh, hybrid,
                                         replicate_patterns=rep)
        b_specs = shl.batch_specs(batch_struct, mesh)
        step = make_train_step(cfg, AdamWConfig())
        in_sh = (shl.named(st_specs, mesh), shl.named(b_specs, mesh))
        out_sh = (shl.named(st_specs, mesh),
                  jax.tree.map(lambda _: NamedSharding(mesh, P()),
                               {"loss": 0, "grad_norm": 0, "lr": 0}))
        return (step, (state_struct, batch_struct), in_sh, out_sh, (0,))

    params_struct = spx.params_specs(cfg)
    p_specs = shl.param_specs(params_struct, mesh, hybrid,
                              replicate_patterns=rep)
    p_sh = shl.named(p_specs, mesh)

    if shape.kind == "prefill":
        batch_struct = spx.prefill_batch_specs(cfg, shape)
        b_sh = shl.named(shl.batch_specs(batch_struct, mesh), mesh)
        if cfg.is_encoder:
            def encode(params, batch):
                return forward_train(params, cfg, batch)
            return (encode, (params_struct, batch_struct), (p_sh, b_sh),
                    None, ())

        def prefill_step(params, batch):
            return prefill(params, cfg, batch,
                           cache_len=shape.seq_len + 128)
        return (prefill_step, (params_struct, batch_struct), (p_sh, b_sh),
                None, ())

    # decode
    cache_struct, tok_struct, pos_struct = spx.decode_specs(cfg, shape)
    c_specs = shl.cache_specs(cache_struct, mesh, hybrid)
    c_sh = shl.named(c_specs, mesh)
    dp = tuple(a for a in mesh.axis_names if a != "model")
    tok_sh = NamedSharding(mesh, P(dp if shape.global_batch %
                                   _dp_size(mesh) == 0 else None, None))
    pos_sh = NamedSharding(mesh, P(dp if shape.global_batch %
                                   _dp_size(mesh) == 0 else None))

    def serve_step(params, cache, tokens, pos):
        return decode_step(params, cfg, cache, tokens, pos)

    return (serve_step, (params_struct, cache_struct, tok_struct,
                         pos_struct),
            (p_sh, c_sh, tok_sh, pos_sh), None, (1,))


def _dp_size(mesh):
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in mesh.axis_names
                        if a != "model"]))


def build_cluster_lowerable(mesh, opts=()):
    """The paper's technique at production scale: one Algorithm-2 iteration
    of the distributed mini-batch kernel k-means service."""
    from repro.configs.paper_cluster import CONFIG as _MBCFG, EMBED_DIM, \
        KAPPA
    MBCFG = _MBCFG
    if "cluster_sharded_gram" in opts:
        MBCFG = MBCFG._replace(sqnorm_mode="recompute_sharded")
    if "cluster_bf16" in opts:
        MBCFG = MBCFG._replace(compute_dtype="bfloat16")
    from repro.core.kernel_fns import Gaussian
    from repro.core.distributed import (
        DistState, make_dist_step, state_shardings)
    from repro.core.state import window_size
    from jax.sharding import NamedSharding, PartitionSpec as P

    kern = Gaussian(kappa=jnp.float32(KAPPA))
    w = window_size(MBCFG.batch_size, MBCFG.tau)
    k, d = MBCFG.k, EMBED_DIM
    # bf16 mode stores the window and streams the batch natively in bf16 —
    # casting f32 state on the fly was REFUTED in §Perf (adds a convert +
    # double read); native storage halves both HBM and all-gather bytes.
    pdt = jnp.bfloat16 if MBCFG.compute_dtype == "bfloat16" else jnp.float32
    state_struct = DistState(
        pts=jax.ShapeDtypeStruct((k, w, d), pdt),
        coef=jax.ShapeDtypeStruct((k, w), jnp.float32),
        head=jax.ShapeDtypeStruct((k,), jnp.int32),
        sqnorm=jax.ShapeDtypeStruct((k,), jnp.float32),
        counts=jax.ShapeDtypeStruct((k,), jnp.float32),
        step=jax.ShapeDtypeStruct((), jnp.int32))
    xb_struct = jax.ShapeDtypeStruct((MBCFG.batch_size, d), pdt)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    step = make_dist_step(kern, MBCFG, mesh, data_axes=data_axes)
    st_sh = state_shardings(mesh)
    xb_sh = NamedSharding(mesh, P(data_axes, None))
    info_sh = None
    return (step, (state_struct, xb_struct), (st_sh, xb_sh), info_sh, (0,))


def _measure_terms(arch, shape_name, mesh, opts, cfg_mod):
    """Lower one variant and return raw per-device (flops, bytes, collective
    bytes) from the compiled artifact."""
    from repro.launch import context as ctx

    fn, structs, in_sh, out_sh, _ = build_lowerable(
        arch, shape_name, mesh, opts, cfg_mod)
    kw = dict(in_shardings=in_sh)
    if out_sh is not None:
        kw["out_shardings"] = out_sh
    with ctx.use_mesh(mesh):
        compiled = jax.jit(fn, **kw).lower(*structs).compile()
    cost = compiled.cost_analysis()
    coll = collective_bytes_of(compiled.as_text())
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), float(coll["total"]))


def scan_corrected_terms(arch: str, shape_name: str, mesh, opts=()):
    """XLA cost_analysis counts a while-loop body ONCE regardless of trip
    count (verified: a 4-layer scanned stack reports 1-layer flops).  We
    therefore lower two SMALL fully-unrolled variants, fit T(L) = a + b*L
    (exact for homogeneous stacks), and extrapolate to the full depth.

    rwkv (ssm) keeps an inner scan over TIME whose body is also counted
    once per layer; for the sequential baseline we add the analytic WKV
    recurrence cost (5 B H hd^2 flops + 2x state HBM traffic per step) —
    the chunked variant hoists that work out of the scan so its measured
    numbers need no adjustment (inter-chunk carry is negligible)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if cfg.family == "hybrid":
        u1, u2 = cfg.attn_every, 2 * cfg.attn_every
        units_full = cfg.n_layers
    else:
        u1, u2 = 1, 2
        units_full = cfg.n_layers

    t1 = _measure_terms(arch, shape_name, mesh, opts,
                        {"n_layers": u1, "scan_unroll": True})
    t2 = _measure_terms(arch, shape_name, mesh, opts,
                        {"n_layers": u2, "scan_unroll": True})
    per = [(b - a) / (u2 - u1) for a, b in zip(t1, t2)]
    corrected = [a + p * (units_full - u1) for a, p in zip(t1, per)]

    if (cfg.family == "ssm" and "rwkv_chunked" not in opts
            and shape.kind != "decode"):
        # analytic WKV sequential-scan interior (per device, per layer)
        dp = _dp_size(mesh)
        b_loc = max(shape.global_batch // dp, 1)
        nh = cfg.n_heads
        hd = cfg.ssm_head_dim
        s = shape.seq_len
        bwd = 3.0 if shape.kind == "train" else 1.0
        corrected[0] += bwd * cfg.n_layers * s * 5.0 * b_loc * nh * hd * hd
        corrected[1] += bwd * cfg.n_layers * s * 2.0 * b_loc * nh * hd * hd * 4
    return {
        "flops_per_device": corrected[0],
        "bytes_per_device": corrected[1],
        "collective_bytes": corrected[2],
        "fit_points": {"units": [u1, u2], "flops": [t1[0], t2[0]],
                       "bytes": [t1[1], t2[1]],
                       "collective": [t1[2], t2[2]]},
        "roofline": {
            "compute_s": corrected[0] / PEAK_FLOPS,
            "memory_s": corrected[1] / HBM_BW,
            "collective_s": corrected[2] / ICI_BW,
        },
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = "experiments/dryrun", opts=(),
             correct_scan: bool = False) -> dict:
    from repro.launch import context as ctx

    mesh_name = "2x16x16" if multi_pod else "16x16"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    t0 = time.time()
    fn, structs, in_sh, out_sh, donate = build_lowerable(arch, shape_name,
                                                         mesh, opts)
    jit_kw = dict(in_shardings=in_sh)
    if out_sh is not None:
        jit_kw["out_shardings"] = out_sh
    with ctx.use_mesh(mesh):       # model-internal sharding constraints
        lowered = jax.jit(fn, **jit_kw).lower(*structs)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_of(hlo)

    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": bytes_acc / HBM_BW,
        "collective_s": coll["total"] / ICI_BW,
    }
    dominant = max(terms, key=terms.get)

    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "opts": sorted(opts),
        "chips": n_chips,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost": {"flops_per_device": flops,
                 "bytes_per_device": bytes_acc},
        "collectives": coll,
        "roofline": {**terms, "dominant": dominant},
    }
    if correct_scan and arch != "paper_cluster":
        corr = scan_corrected_terms(arch, shape_name, mesh, opts)
        corr["roofline"]["dominant"] = max(
            corr["roofline"], key=corr["roofline"].get)
        result["corrected"] = corr

    if arch == "paper_cluster":
        # analytic kernel-eval flops of one Algorithm-2 iteration:
        # assignment k*b*W*d (x2 for the f_after pass) + Gram k*W^2*d
        from repro.configs.paper_cluster import CONFIG as MBCFG, EMBED_DIM
        from repro.core.state import window_size
        w = window_size(MBCFG.batch_size, MBCFG.tau)
        mf = 2.0 * (2 * MBCFG.k * MBCFG.batch_size * w * EMBED_DIM
                    + MBCFG.k * w * w * EMBED_DIM)
    else:
        mf = model_flops_estimate(get_config(arch), SHAPES[shape_name])
    result["model_flops_global"] = mf
    # cost_analysis flops are per device
    result["useful_flops_ratio"] = (
        mf / (flops * n_chips) if flops else None)
    if "corrected" in result:
        cf = result["corrected"]["flops_per_device"]
        result["corrected"]["useful_flops_ratio"] = (
            mf / (cf * n_chips) if cf else None)

    os.makedirs(out_dir, exist_ok=True)
    suffix = ("__opt-" + "-".join(sorted(opts))) if opts else ""
    fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(result, f, indent=1)
    return result


def cells_for(arch: str):
    if arch == "paper_cluster":
        return ["cluster_step"]
    cfg = get_config(arch)
    out = []
    for name in SHAPES:
        ok, _ = applicable(cfg, name)
        if ok:
            out.append(name)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--opt", action="append", default=[],
                    help="beyond-paper perf options (see build_lowerable)")
    ap.add_argument("--correct-scan", action="store_true",
                    help="add scan-trip-count-corrected roofline terms "
                    "(2-point unrolled fit; see scan_corrected_terms)")
    args = ap.parse_args()

    archs = (all_arch_names() + ["paper_cluster"] if args.arch == "all"
             else [args.arch.replace("-", "_").replace(".", "_")])
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        shapes = cells_for(arch) if args.shape == "all" else [args.shape]
        for shape_name in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape_name} x " \
                      f"{'2x16x16' if mp else '16x16'}"
                try:
                    r = run_cell(arch, shape_name, mp, args.out,
                                 tuple(args.opt), args.correct_scan)
                    roof = r.get("corrected", r)["roofline"]
                    print(f"OK   {tag}: compute {roof['compute_s']:.3e}s "
                          f"memory {roof['memory_s']:.3e}s collective "
                          f"{roof['collective_s']:.3e}s -> "
                          f"{roof['dominant']} "
                          f"(compile {r['compile_s']}s)", flush=True)
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, repr(e)))
                    print(f"FAIL {tag}: {e}", flush=True)
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nALL CELLS COMPILED.")


if __name__ == "__main__":
    main()
