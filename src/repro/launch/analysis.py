"""Compiled-program analysis (HLO memory / cost / collective bytes).

Extracted from :mod:`repro.launch.dryrun` so in-process callers —
``KernelKMeans.explain(deep=True)`` and ``serve --dry-run`` — can analyze
a compiled step program WITHOUT dryrun's import-time side effect (it
forces ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before
jax loads, which is only correct for a dedicated subprocess).
"""
from __future__ import annotations

import re

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
SHAPE_RE = re.compile(r"\b([a-z]+\d+)\[([\d,]*)\]")
DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}


def collective_bytes_of(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the compiled HLO (the
    spec's §Roofline recipe).  Falls back to the result shape when operand
    shapes are not printed on the line."""
    totals = {}
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        op = m.group(1)
        # shapes on the line: first = result, rest = operands
        shapes = SHAPE_RE.findall(line)
        if not shapes:
            continue
        operands = shapes[1:] if len(shapes) > 1 else shapes[:1]
        nbytes = 0
        for dt, dims in operands:
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        totals[op] = totals.get(op, 0) + nbytes
    totals["total"] = sum(v for k, v in totals.items() if k != "total")
    return totals


def analyze_compiled(compiled) -> dict:
    """Memory / cost / collective summary of one ``jax`` Compiled object —
    the per-cell analysis body of ``launch.dryrun.run_cell``, reusable on
    any compiled program."""
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):   # older jax: one dict per device
        cost = cost[0] if cost else {}
    coll = collective_bytes_of(compiled.as_text())
    return {
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0) or 0)
            + (getattr(mem, "temp_size_in_bytes", 0) or 0),
        },
        "cost": {"flops_per_device": float(cost.get("flops", 0.0)),
                 "bytes_per_device": float(cost.get("bytes accessed", 0.0))},
        "collectives": coll,
    }
