"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b \
        --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On this CPU container use --reduced (smoke-sized config); on a TPU pod the
full config + production mesh engage automatically when >1 device exists.
Fault tolerance: the loop runs under train.resilience.run_resilient —
crashes/stragglers restore from the last checkpoint and replay the
deterministic pipeline.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import EmbedPipeline, TokenPipeline
from repro.launch import sharding as shl
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.train import AdamWConfig, make_train_state, make_train_step
from repro.train.checkpoint import Checkpointer
from repro.train.resilience import run_resilient


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatch", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(dtype="float32")
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
          f"family={cfg.family}")

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"params: {n_params / 1e6:.2f}M")
    state = make_train_state(params, compress=args.compress_grads)

    step_fn = make_train_step(cfg, AdamWConfig(lr=args.lr),
                              microbatch=args.microbatch,
                              compress=args.compress_grads)
    shardings = None
    if len(jax.devices()) > 1:
        mesh = make_host_mesh()
        st_struct = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        specs = shl.train_state_specs(st_struct, mesh,
                                      hybrid=cfg.family == "hybrid")
        shardings = shl.named(specs, mesh)
        state = jax.device_put(state, shardings)
        step = jax.jit(step_fn, in_shardings=(shardings, None),
                       out_shardings=(shardings, None), donate_argnums=(0,))
        print(f"mesh: {dict(mesh.shape)}")
    else:
        step = jax.jit(step_fn, donate_argnums=(0,))

    if cfg.frontend == "stub":
        pipe = EmbedPipeline(cfg.frontend_dim, args.batch, args.seq,
                             seed=args.seed, vocab=cfg.vocab)
    else:
        pipe = TokenPipeline(cfg.vocab, args.batch, args.seq,
                             seed=args.seed)

    losses = []

    def logging_step(st, batch):
        st, m = step(st, batch)
        losses.append(float(m["loss"]))
        s = int(st.step)
        if s % args.log_every == 0 or s == 1:
            print(f"step {s:5d}  loss {losses[-1]:.4f}  "
                  f"gnorm {float(m['grad_norm']):.3f}")
        return st, m

    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir)
        state, hist = run_resilient(
            logging_step, pipe, state, args.steps, ck,
            ckpt_every=args.ckpt_every,
            make_state_like=lambda: jax.tree.map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state),
            shardings=shardings)
    else:
        for i in range(args.steps):
            state, _ = logging_step(state, pipe(i))

    print(f"final loss (mean of last 10): {np.mean(losses[-10:]):.4f}  "
          f"(first 10: {np.mean(losses[:10]):.4f})")
    return state


if __name__ == "__main__":
    main()
