"""Production meshes.  Defined as FUNCTIONS so importing this module never
touches jax device state (the dry-run process must set XLA_FLAGS before any
jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: (16, 16) = 256 chips single pod;
    (2, 16, 16) = 512 chips across 2 pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 2):
    """Small CPU mesh for tests (uses however many devices exist)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_cluster_mesh(model: int = 1):
    """Default data x model mesh for the sharded clustering plans: all
    devices on the data axis unless a model split is requested.  Used by
    ``repro.api`` when ``distribution='sharded'`` is asked for without an
    explicit mesh."""
    n = len(jax.devices())
    return jax.make_mesh((max(n // model, 1), model), ("data", "model"))


def make_restart_mesh(restarts: int, axis: str = "restart"):
    """1-axis mesh for the multi-restart clustering engine.

    The restart axis must DIVIDE the restart count (each device owns a
    whole number of restarts), so this picks the largest device count
    <= min(restarts, len(devices)) that divides ``restarts`` — e.g.
    R=4 on 8 devices -> a 4-device mesh; R=6 on 4 -> 3 devices;
    prime R=7 on 4 -> 1 device."""
    devs = jax.devices()
    size = next(d for d in range(min(restarts, len(devs)), 0, -1)
                if restarts % d == 0)
    return jax.make_mesh((size,), (axis,), devices=devs[:size])


def make_fused_mesh(restarts: int, model: int = 1,
                    axes: tuple = ("restart", "data", "model")):
    """3-axis mesh for the fused restart x data x model solver plan
    (``fused_restart_sharded``): the restart axis takes the largest device
    count <= min(restarts, n_devices) that DIVIDES ``restarts`` (each
    device owns a whole number of restart lanes, like
    :func:`make_restart_mesh`); the remaining devices split into
    data x model.  E.g. R=4 on 8 devices -> (4, 2, 1); R=2 on 8 with
    model=2 -> (2, 2, 2); 1 device -> (1, 1, 1) with all R restarts as
    sequential lanes on it."""
    devs = jax.devices()
    n = len(devs)
    r = next(d for d in range(min(restarts, n), 0, -1) if restarts % d == 0)
    rem = n // r
    if model < 1 or model > rem or rem % model:
        raise ValueError(
            f"model={model} does not divide the {rem} devices left after "
            f"the restart axis takes {r} of {n} (pick a model split that "
            f"divides {rem}, or shrink the restart count)")
    data = max(rem // model, 1)
    return jax.make_mesh((r, data, model), axes,
                         devices=devs[:r * data * model])


def data_axes_of(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a != "model")
