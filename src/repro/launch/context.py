"""Mesh context: lets deep model code apply sharding constraints without
threading the mesh through every call signature.

Model code calls `constrain(x, 'model', None, ...)`; when a mesh has been
installed (dry-run / production launchers) this becomes a
with_sharding_constraint, otherwise it is a no-op (single-device smoke
tests)."""
from __future__ import annotations

import contextlib
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_CURRENT: list = []


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    _CURRENT.append(mesh)
    try:
        yield mesh
    finally:
        _CURRENT.pop()


def current_mesh() -> Optional[Mesh]:
    return _CURRENT[-1] if _CURRENT else None


def data_axes() -> tuple:
    m = current_mesh()
    if m is None:
        return ()
    return tuple(a for a in m.axis_names if a != "model")


def dp_size() -> int:
    m = current_mesh()
    if m is None:
        return 1
    import numpy as np
    return int(np.prod([m.shape[a] for a in data_axes()]))


def constrain(x: jax.Array, *spec_parts):
    """with_sharding_constraint if a mesh is installed; else identity.
    Spec parts may name axes ('model'), the pseudo-axis 'data*' (all
    non-model axes), or None."""
    mesh = current_mesh()
    if mesh is None:
        return x
    parts = []
    for p in spec_parts:
        if p == "data*":
            parts.append(data_axes())
        else:
            parts.append(p)
    # drop axis names whose dimension size is not divisible
    fixed = []
    for dim, p in enumerate(parts):
        if p is None:
            fixed.append(None)
            continue
        names = p if isinstance(p, tuple) else (p,)
        size = 1
        for nm in names:
            size *= mesh.shape[nm]
        fixed.append(p if x.shape[dim] % size == 0 else None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*fixed)))
