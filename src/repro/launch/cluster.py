"""Clustering-service launcher — the paper's algorithm as a deployable job.

    PYTHONPATH=src python -m repro.launch.cluster --dataset circles \
        --kernel heat --k 2 --batch 256 --tau 200 --epsilon 1e-4
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    Gaussian, MBConfig, adjusted_rand_index, fit, gamma_of,
    median_sq_dist_heuristic, normalized_mutual_info, predict,
)
from repro.data import make_dataset
from repro.data.graph_kernels import heat_kernel, knn_kernel


def build_kernel(name: str, x: np.ndarray, kappa, knn, t):
    if name == "gaussian":
        xj = jnp.asarray(x)
        if kappa is None:
            kappa = float(median_sq_dist_heuristic(xj))
        return Gaussian(kappa=jnp.float32(kappa)), xj
    if name == "knn":
        kern, xi = knn_kernel(x, k=knn)
    elif name == "heat":
        kern, xi = heat_kernel(x, k=knn, t=t)
    else:
        raise SystemExit(f"unknown kernel {name}")
    return jax.tree.map(jnp.asarray, kern), jnp.asarray(xi)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="circles")
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--k", type=int, default=2)
    ap.add_argument("--kernel", default="heat",
                    choices=["gaussian", "knn", "heat"])
    ap.add_argument("--kappa", type=float, default=None)
    ap.add_argument("--knn", type=int, default=10)
    ap.add_argument("--t", type=float, default=2000.0)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--tau", type=int, default=200)
    ap.add_argument("--epsilon", type=float, default=1e-4)
    ap.add_argument("--rate", default="beta", choices=["beta", "sklearn"])
    ap.add_argument("--max-iters", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    x, y = make_dataset(args.dataset, n=args.n, seed=args.seed)
    kern, xj = build_kernel(args.kernel, x, args.kappa, args.knn, args.t)
    print(f"dataset={args.dataset} n={x.shape[0]} d={x.shape[1]} "
          f"k={args.k} kernel={args.kernel} "
          f"gamma={float(gamma_of(kern, xj)):.4f}")

    cfg = MBConfig(k=args.k, batch_size=args.batch, tau=args.tau,
                   rate=args.rate, epsilon=args.epsilon,
                   max_iters=args.max_iters)
    t0 = time.time()
    state, hist = fit(xj, kern, cfg, jax.random.PRNGKey(args.seed))
    dt = time.time() - t0
    pred = np.asarray(predict(state, xj, xj, kern))
    print(f"iterations: {len(hist)} (early stop @ eps={args.epsilon})  "
          f"wall: {dt:.2f}s")
    print(f"ARI: {adjusted_rand_index(y, pred):.4f}  "
          f"NMI: {normalized_mutual_info(y, pred):.4f}")
    print(f"objective: {hist[0]['f_before']:.4f} -> "
          f"{hist[-1]['f_after']:.4f}")


if __name__ == "__main__":
    main()
