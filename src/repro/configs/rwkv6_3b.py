"""rwkv6-3b "Finch" [ssm]: attention-free, data-dependent per-channel decay.
[arXiv:2404.05892; hf]  32L d_model=2560 d_ff=8960 vocab=65536.
Attention-free => runs the long_500k cell (O(1)-state decode)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, n_kv_heads=40,
    d_ff=8960, vocab=65536,
    ssm_head_dim=64,
)
