"""h2o-danube-1.8b [dense]: llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; hf]  24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000.
SWA window 4096 => sub-quadratic: runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_heads=32, n_kv_heads=8, head_dim=80,
    d_ff=6912, vocab=32000, mlp="swiglu",
    sliding_window=4096, rope_theta=10000.0,
)
