"""deepseek-v2-236b [moe]: MLA (kv_lora=512) + 2 shared + 160 routed top-6.
[arXiv:2405.04434; hf]  60L d_model=5120 128H d_ff=1536 vocab=102400."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                          # dense-equivalent (shared path)
    vocab=102400, mlp="swiglu",
    mla=True, kv_lora=512, q_lora=1536,
    rope_head_dim=64, nope_head_dim=128, v_head_dim=128,
    moe=True, n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
)
