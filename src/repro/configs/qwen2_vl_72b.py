"""qwen2-vl-72b [vlm]: qwen2-72b backbone + M-RoPE + dynamic-resolution
vision frontend (STUB: precomputed patch embeddings, per instructions).
[arXiv:2409.12191; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=29568, vocab=152064, mlp="swiglu",
    qkv_bias=True, rope_theta=1e6,
    mrope_sections=(16, 24, 24),
    frontend="stub", frontend_dim=1280,
)
