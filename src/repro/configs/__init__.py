"""Architecture registry: --arch <id> resolves here."""
from __future__ import annotations

import importlib

ARCHS = [
    "h2o_danube_1_8b",
    "qwen3_1_7b",
    "nemotron_4_340b",
    "qwen2_72b",
    "zamba2_2_7b",
    "arctic_480b",
    "deepseek_v2_236b",
    "qwen2_vl_72b",
    "hubert_xlarge",
    "rwkv6_3b",
    # the paper's own workload (clustering service) — see paper_cluster.py
    "paper_cluster",
]


def canon(name: str) -> str:
    return name.replace("-", "_").replace(".", "_")


def get_config(name: str):
    mod = importlib.import_module(f"repro.configs.{canon(name)}")
    return mod.CONFIG


def all_arch_names():
    return [a for a in ARCHS if a != "paper_cluster"]
