"""zamba2-2.7b [hybrid]: Mamba2 backbone + ONE shared attention(+MLP) block
applied every 6 layers (parameters reused — zamba2's signature).
[arXiv:2411.15242; hf]  54L d_model=2560 32H (kv=32) d_ff=10240 ssm_state=64.
Sub-quadratic (SSM): runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=10240, vocab=32000, mlp="swiglu",
    attn_every=6,                       # 9 groups x 6 mamba layers
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_conv=4, ssm_chunk=256,
)
