"""The assigned input-shape set (one per LM arch; 40 nominal cells) and the
applicability rules from DESIGN.md §6."""
from __future__ import annotations

from typing import NamedTuple, Optional

from repro.models.config import ModelConfig


class Shape(NamedTuple):
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": Shape("train_4k", "train", 4096, 256),
    "prefill_32k": Shape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": Shape("decode_32k", "decode", 32768, 128),
    "long_500k": Shape("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — the skip set recorded in DESIGN.md."""
    s = SHAPES[shape_name]
    if cfg.is_encoder and s.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, ("long_500k requires sub-quadratic attention; "
                       f"{cfg.name} is pure full-attention")
    return True, ""


def cells(cfg: ModelConfig):
    return [(n, SHAPES[n]) for n in SHAPES if applicable(cfg, n)[0]]
