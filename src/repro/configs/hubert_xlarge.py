"""hubert-xlarge [audio]: encoder-only (bidirectional), w2v2-style backbone;
conv feature extractor is a STUB (precomputed frame embeddings).
[arXiv:2106.07447]  48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504.
Encoder-only => decode_32k / long_500k cells are skipped (DESIGN.md)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504, mlp="gelu",
    causal=False,
    frontend="stub", frontend_dim=512,
)
