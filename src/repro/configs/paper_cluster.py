"""The paper's own workload as a production config: the distributed
mini-batch kernel k-means service (repro.core.distributed) at cluster scale
— e.g. clustering LM hidden states / embedding tables.

This is the (arch = paper technique) cell of the dry-run: the step lowered
is one Algorithm-2 iteration on the production mesh."""
from repro.core.minibatch import MBConfig

# Production-scale clustering: 256 centers over d=1024 embeddings,
# batch 8192/iteration, window tau = b (the paper's practical regime:
# tau <= b works well, §6 "even tiny tau far below theory").
CONFIG = MBConfig(
    k=256,
    batch_size=8192,
    tau=8192,
    rate="beta",
    sqnorm_mode="recompute",    # paper-faithful baseline
    eval_mode="direct",
    epsilon=1e-4,
    max_iters=200,
)

EMBED_DIM = 1024
KAPPA = 2.0
