"""arctic-480b [moe]: 128 experts top-2 + parallel dense residual FFN.
[hf:Snowflake/snowflake-arctic-base]  35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=4864, vocab=32000, mlp="swiglu",
    moe=True, n_experts=128, top_k=2, moe_d_ff=4864,
    dense_residual=True,
)
