"""Deterministic fault injection for the always-on service.

The service's robustness claims — crash recovery bit-identical to an
uninterrupted run, no torn or corrupt snapshot ever served, bounded
degradation under transient I/O errors — are only worth something if the
faults that threaten them can be REPLAYED.  This module is that replay
harness: a :class:`FaultPlan` is a schedule of named faults over the
service's injection sites, and every firing decision is a pure function
of ``(plan seed, site, occurrence index)`` — never of wall clock, thread
interleaving, or prior RNG state.  Running the same plan against the
same deterministic workload twice produces the same fault trace twice
(``BENCH_chaos.json`` asserts exactly this).

Sites (each component fires its site at one well-defined point; with
``faults=None`` — the default everywhere — the injection points are
dead branches and every path is bit-identical to the un-instrumented
code):

==================  ======================================================
``learner.step``    entry of one learner round (:meth:`Learner._step`)
``snapshot.publish``inside :meth:`SnapshotStore.publish`'s retry loop
``snapshot.load``   entry of :meth:`SnapshotStore.load` / ``load_version``
``actor.swap``      entry of :meth:`Actor.try_swap`
``actor.serve``     inside :meth:`Actor._serve`'s retry loop
``buffer.push``     :meth:`IngestBuffer.push`, keyed by the PUSH INDEX so
                    crash-recovery replay re-fires identically
``loop.carry``      the loop core's carry guard
                    (:func:`repro.core.loop.guard_carry`)
==================  ======================================================

Kinds:

* ``crash`` — raise :class:`InjectedFault` (recovery path: restore +
  replay).
* ``hang`` — block for ``delay_s`` (default 60s) and then raise: a hung
  step never silently resumes into restored state.  The watchdog in
  :func:`repro.train.resilience.run_resilient` aborts the wait early via
  :meth:`FaultPlan.abort_hangs`.
* ``slow`` — sleep ``delay_s`` (default 50ms) and continue; exercises
  latency bounds, not recovery.
* ``io`` — raise a transient ``OSError`` (retry/backoff paths).
* ``corrupt`` — returned to the site as a data event; the site flips
  bytes via :meth:`FaultPlan.corrupt_file` (snapshot integrity +
  quarantine paths).
* ``nan`` — returned to the site as a data event; the site poisons rows
  via :meth:`FaultPlan.nan_rows` (the non-finite guard + dead-center
  reseed paths, Tang & Monteleoni's degenerate-batch instability).

See docs/robustness.md for the recovery guarantee each site+kind pair
exercises, and ``benchmarks/run.py --only chaos`` for the soak harness.
"""
from __future__ import annotations

import threading
import time
from typing import List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

SITES = ("learner.step", "snapshot.publish", "snapshot.load",
         "actor.swap", "actor.serve", "buffer.push", "loop.carry")
KINDS = ("crash", "hang", "slow", "io", "corrupt", "nan")

# the kinds fire() resolves itself (control flow); 'corrupt'/'nan' are
# data kinds the SITE applies to its payload via the helpers below
_CONTROL_KINDS = ("crash", "hang", "slow", "io")

_DEFAULT_DELAYS = {"hang": 60.0, "slow": 0.05}


class InjectedFault(RuntimeError):
    """A fault fired by a :class:`FaultPlan` (crash / aborted hang)."""


class FaultRule(NamedTuple):
    """One scheduled fault.  Exactly one trigger should be given:

    ``at``     — fire at these occurrence indices of ``site`` (0-based).
    ``every``  — fire at every ``every``-th occurrence (occ > 0).
    ``prob``   — fire when the seeded draw for (seed, site, rule, occ)
                 falls below ``prob`` — random-looking but replayable.

    ``max_fires`` caps total firings (0 = unlimited); ``delay_s``
    overrides the hang/slow duration."""

    site: str
    kind: str
    at: Tuple[int, ...] = ()
    every: int = 0
    prob: float = 0.0
    max_fires: int = 0
    delay_s: Optional[float] = None


class FaultEvent(NamedTuple):
    """One firing, as recorded in the trace."""

    site: str
    kind: str
    occ: int            # occurrence index of the site at firing time
    rule: int           # index into the plan's rule list


class FaultPlan:
    """A deterministic, replayable fault schedule.

    Thread-safe: sites fire from the learner thread, the actor's worker
    and swapper threads, and test drivers concurrently; occurrence
    counters and the trace are guarded by one lock.  Determinism still
    requires the CALLER's occurrence order to be deterministic — sites
    driven by a deterministic workload (the learner round loop, the
    buffer push index) are; free-running poll loops (``actor.swap``) get
    a deterministic trace only relative to their own poll count.
    """

    def __init__(self, rules: Sequence[FaultRule], seed: int = 0):
        for r in rules:
            if r.site not in SITES:
                raise ValueError(f"unknown site {r.site!r} (not in {SITES})")
            if r.kind not in KINDS:
                raise ValueError(f"unknown kind {r.kind!r} (not in {KINDS})")
        self.rules = tuple(rules)
        self.seed = int(seed)
        self._lock = threading.Lock()
        self._occ = {s: 0 for s in SITES}
        self._fires = [0] * len(self.rules)
        self.trace: List[FaultEvent] = []
        self._abort = threading.Event()

    # ------------------------------------------------------------ firing
    def _matches(self, rule_no: int, rule: FaultRule, occ: int) -> bool:
        if rule.max_fires and self._fires[rule_no] >= rule.max_fires:
            return False
        if rule.at:
            return occ in rule.at
        if rule.every:
            return occ > 0 and occ % rule.every == 0
        if rule.prob > 0.0:
            site_id = SITES.index(rule.site)
            draw = np.random.default_rng(
                (self.seed, site_id, rule_no, occ)).random()
            return bool(draw < rule.prob)
        return False

    def fire(self, site: str, index: Optional[int] = None):
        """Advance ``site``'s occurrence counter (or use the caller's
        ``index`` — the buffer keys by push index so replay re-fires
        identically) and evaluate every matching rule.  Control kinds
        execute here (crash/io raise, hang/slow block); data kinds
        (``corrupt`` / ``nan``) are RETURNED for the site to apply.
        Returns the fired data event, or None."""
        with self._lock:
            occ = self._occ[site] if index is None else int(index)
            # caller-indexed sites still advance the high-water mark so
            # occurrences() stays meaningful (replays don't double-count)
            self._occ[site] = max(self._occ[site], occ + 1)
            fired = []
            for rule_no, rule in enumerate(self.rules):
                if rule.site != site or not self._matches(rule_no, rule,
                                                          occ):
                    continue
                self._fires[rule_no] += 1
                ev = FaultEvent(site, rule.kind, occ, rule_no)
                self.trace.append(ev)
                fired.append((rule, ev))
        data_event = None
        for rule, ev in fired:
            if ev.kind == "crash":
                raise InjectedFault(f"injected crash at {site}#{occ}")
            if ev.kind == "io":
                raise OSError(f"injected transient IOError at "
                              f"{site}#{occ}")
            if ev.kind == "slow":
                time.sleep(rule.delay_s if rule.delay_s is not None
                           else _DEFAULT_DELAYS["slow"])
            elif ev.kind == "hang":
                self._hang(rule.delay_s if rule.delay_s is not None
                           else _DEFAULT_DELAYS["hang"], site, occ)
            else:                       # corrupt / nan: the site applies
                data_event = ev
        return data_event

    def _hang(self, delay_s: float, site: str, occ: int) -> None:
        """Block until the watchdog aborts us or ``delay_s`` elapses —
        then RAISE either way: a hung step must never silently resume
        (the driver has long since restored from the last snapshot, and
        a resumed zombie would mutate shared state concurrently)."""
        aborted = self._abort.wait(delay_s)
        if aborted:
            self._abort.clear()
        raise InjectedFault(
            f"injected hang at {site}#{occ} "
            f"({'aborted by watchdog' if aborted else 'expired'})")

    def abort_hangs(self) -> None:
        """Wake every in-flight hang (they raise :class:`InjectedFault`
        on their own threads).  Wired as ``run_resilient``'s
        ``on_watchdog`` hook so an abandoned hung step dies instead of
        lingering."""
        self._abort.set()

    # ------------------------------------------------------- data faults
    def nan_rows(self, arr: np.ndarray, event: FaultEvent,
                 frac: float = 0.25) -> np.ndarray:
        """A copy of ``arr`` with a deterministic ``frac`` of its rows
        set to NaN — the degenerate-arrivals fault."""
        rng = np.random.default_rng((self.seed, SITES.index(event.site),
                                     event.rule, event.occ, 0x7AB))
        out = np.array(arr, copy=True)
        n = out.shape[0]
        rows = rng.choice(n, size=max(1, int(n * frac)), replace=False)
        out[rows] = np.nan
        return out

    def nan_leaf(self, arr: np.ndarray, event: FaultEvent,
                 count: int = 4) -> np.ndarray:
        """A copy of a float array with ``count`` deterministic entries
        poisoned to NaN — the carry-corruption fault."""
        rng = np.random.default_rng((self.seed, SITES.index(event.site),
                                     event.rule, event.occ, 0xCA4))
        out = np.array(arr, copy=True, dtype=np.float32)
        flat = out.reshape(-1)
        pos = rng.choice(flat.size, size=min(count, flat.size),
                         replace=False)
        flat[pos] = np.nan
        return out

    def corrupt_file(self, path: str, event: FaultEvent,
                     n_bytes: int = 8) -> None:
        """Flip ``n_bytes`` deterministic bytes of the file in place —
        the disk-corruption fault (the CRC footer must catch it and the
        store must quarantine + fall back)."""
        rng = np.random.default_rng((self.seed, SITES.index(event.site),
                                     event.rule, event.occ, 0xC0))
        with open(path, "r+b") as f:
            f.seek(0, 2)
            size = f.tell()
            # keep clear of the zip end-of-central-directory record so
            # the file still LOOKS like a snapshot — the integrity check,
            # not the container format, must be what catches it
            hi = max(1, size - 128)
            for off in rng.integers(0, hi, n_bytes):
                f.seek(int(off))
                b = f.read(1)
                f.seek(int(off))
                f.write(bytes([b[0] ^ 0xFF]))

    # --------------------------------------------------------- reporting
    def occurrences(self, site: str) -> int:
        with self._lock:
            return self._occ[site]

    def trace_list(self) -> List[Tuple[str, str, int]]:
        """The (site, kind, occurrence) trace — comparable across runs;
        two runs of the same plan against the same workload must match
        exactly."""
        with self._lock:
            return [(e.site, e.kind, e.occ) for e in self.trace]

    def stats(self) -> dict:
        with self._lock:
            return dict(seed=self.seed, rules=len(self.rules),
                        fired=len(self.trace),
                        by_site={s: sum(1 for e in self.trace
                                        if e.site == s)
                                 for s in SITES if any(e.site == s
                                                       for e in self.trace)})


def fire(faults: Optional[FaultPlan], site: str,
         index: Optional[int] = None):
    """The injection-point helper every site calls: a no-op returning
    None when ``faults`` is None (the default everywhere — the
    production path stays bit-identical to the un-instrumented code)."""
    if faults is None:
        return None
    return faults.fire(site, index)
