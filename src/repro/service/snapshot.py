"""Versioned, atomically-swapped snapshot store for the serving split.

The learner publishes ``KernelKMeans`` snapshots (the PR-4 ``save``/
``load`` round-trip, including the resumable :class:`FitCarry`) into a
directory; actors poll ``latest_version()`` and load whole files.  Two
invariants make the swap safe with zero coordination:

* **Never a torn read.**  Every write goes to a same-directory temp file
  and is ``os.replace``d into place (both the snapshot ``.npz`` and the
  ``LATEST`` pointer) — a reader either sees the complete previous file or
  the complete new one, never a partial write
  (tests/test_service.py::test_snapshot_never_torn).
* **Staleness is the reader's contract.**  ``load(max_age_s=...)`` raises
  :class:`StaleSnapshot` when the newest snapshot is older than the bound
  — an actor keeps serving its in-memory model (and reports the age via
  telemetry) rather than silently serving arbitrarily old centers.

The store also speaks the :class:`repro.train.checkpoint.Checkpointer`
protocol (``save`` / ``restore`` / ``latest_step`` / ``wait``) through
:meth:`as_checkpointer`, so :func:`repro.train.resilience.run_resilient`
drives learner crash-recovery against the SAME files the actors serve
from — the published snapshot IS the checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Any, Optional

import numpy as np

from repro.service.faults import fire

_SNAP_RE = re.compile(r"^snapshot_(\d+)\.npz$")


class StaleSnapshot(RuntimeError):
    """Newest snapshot is older than the caller's staleness bound."""


class SnapshotStore:
    """Directory of ``snapshot_<version>.npz`` files + a ``LATEST``
    pointer, all updated write-temp-then-rename.  ``keep`` bounds disk use
    (older versions are pruned after a successful publish).

    Hardening (PR 10): publishes retry transient ``OSError``s with
    deterministic backoff; loads verify the format-3 CRC footer, move any
    corrupt file aside to ``*.corrupt`` (``quarantined`` counts them) and
    FALL BACK through older intact versions (``load_fallbacks``) instead
    of raising — a reader never serves garbage centers and never dies to
    one rotten file while an older good one exists.  ``faults`` is the
    chaos harness hook (:mod:`repro.service.faults`); None — the default —
    keeps every path bit-identical to the un-instrumented store."""

    def __init__(self, directory: str, keep: int = 4, faults=None,
                 publish_retries: int = 2,
                 retry_backoff_s: float = 0.01):
        self.dir = directory
        self.keep = int(keep)
        self.faults = faults
        self.publish_retries = int(publish_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        os.makedirs(directory, exist_ok=True)
        self.publishes = 0
        self.publish_errors = 0
        self.quarantined = 0
        self.load_fallbacks = 0

    # ------------------------------------------------------------ paths
    def path_for(self, version: int) -> str:
        return os.path.join(self.dir, f"snapshot_{int(version)}.npz")

    def _replace(self, tmp: str, dst: str) -> None:
        os.replace(tmp, dst)        # atomic within one filesystem

    # ---------------------------------------------------------- publish
    def publish(self, estimator, version: int) -> str:
        """Atomically publish ``estimator``'s full snapshot (serving
        tuple + resumable carry) as ``version``.  Returns the path.

        Transient ``OSError``s (flaky disk / NFS, or the chaos harness's
        ``io`` fault at ``snapshot.publish``) are retried up to
        ``publish_retries`` times with deterministic exponential backoff
        — only then does the error propagate to the learner's recovery
        path."""
        dst = self.path_for(version)
        tmp = dst + f".tmp.{os.getpid()}"
        attempt = 0
        while True:
            try:
                ev = fire(self.faults, "snapshot.publish")
                estimator.save(tmp)
                self._replace(tmp, dst)
                break
            except OSError:
                self.publish_errors += 1
                attempt += 1
                if attempt > self.publish_retries:
                    raise
                time.sleep(self.retry_backoff_s * (2.0 ** (attempt - 1)))
        if ev is not None and ev.kind == "corrupt":
            # injected disk rot lands on the PUBLISHED file — the read
            # path's CRC check + quarantine + fallback must absorb it
            self.faults.corrupt_file(dst, ev)
        ptr = os.path.join(self.dir, "LATEST")
        with open(ptr + f".tmp.{os.getpid()}", "w") as f:
            json.dump({"version": int(version), "time": time.time()}, f)
        self._replace(ptr + f".tmp.{os.getpid()}", ptr)
        self.publishes += 1
        self._prune()
        return dst

    def _prune(self) -> None:
        versions = sorted(self.versions())
        for v in versions[:-self.keep] if self.keep > 0 else []:
            try:
                os.remove(self.path_for(v))
            except OSError:
                pass

    # ------------------------------------------------------------ reads
    def versions(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            m = _SNAP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_version(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        try:
            with open(ptr) as f:
                v = int(json.load(f)["version"])
        except (OSError, ValueError, KeyError):
            vs = self.versions()
            return vs[-1] if vs else None
        if os.path.exists(self.path_for(v)):
            return v
        # pointer target gone (pruned or quarantined) — fall back to the
        # newest file actually on disk rather than reporting an empty store
        vs = self.versions()
        return vs[-1] if vs else None

    def age_s(self, version: Optional[int] = None) -> Optional[float]:
        """Seconds since ``version`` (default: latest) was published."""
        v = self.latest_version() if version is None else version
        if v is None:
            return None
        try:
            return max(0.0, time.time() - os.path.getmtime(self.path_for(v)))
        except OSError:
            return None

    def _quarantine(self, version: int) -> None:
        """Move a failed-integrity snapshot aside to ``*.corrupt`` so it
        leaves the version chain (``versions()`` no longer lists it) but
        stays on disk for post-mortem."""
        p = self.path_for(version)
        try:
            os.replace(p, p + ".corrupt")
            self.quarantined += 1
        except OSError:
            pass

    def load_version(self, version: int):
        """Load exactly ``version`` with integrity checking: a CRC
        mismatch or undecodable container quarantines the file and
        re-raises :class:`~repro.api.estimator.SnapshotIntegrityError`."""
        from repro.api import KernelKMeans
        from repro.api.estimator import SnapshotIntegrityError

        path = self.path_for(version)
        ev = fire(self.faults, "snapshot.load")
        if ev is not None and ev.kind == "corrupt" \
                and os.path.exists(path):
            self.faults.corrupt_file(path, ev)
        try:
            return KernelKMeans.load(path)
        except SnapshotIntegrityError:
            self._quarantine(version)
            raise

    def load(self, version: Optional[int] = None,
             max_age_s: Optional[float] = None):
        """``(version, KernelKMeans)`` for ``version`` (default latest).
        With ``max_age_s``, a snapshot older than the bound raises
        :class:`StaleSnapshot` instead of loading.

        An EXPLICIT ``version`` is loaded as-is (integrity failures
        quarantine + raise).  The default (latest) FALLS BACK through
        older intact versions when the newest is corrupt or unreadable —
        each skipped version counts as a ``load_fallback`` — and only
        raises when no version on disk survives."""
        if version is not None:
            if max_age_s is not None:
                age = self.age_s(version)
                if age is None or age > max_age_s:
                    raise StaleSnapshot(
                        f"snapshot v{version} is "
                        f"{age if age is not None else '?'}s old "
                        f"(bound {max_age_s}s)")
            return version, self.load_version(version)
        v = self.latest_version()
        if v is None:
            raise FileNotFoundError(f"no snapshot in {self.dir}")
        if max_age_s is not None:
            age = self.age_s(v)
            if age is None or age > max_age_s:
                raise StaleSnapshot(
                    f"snapshot v{v} is {age if age is not None else '?'}s "
                    f"old (bound {max_age_s}s)")
        from repro.api.estimator import SnapshotIntegrityError

        tried: set = set()
        last_err: Optional[Exception] = None
        while True:
            cands = [c for c in sorted(self.versions(), reverse=True)
                     if c not in tried]
            if not cands:
                if last_err is not None:
                    raise last_err
                raise FileNotFoundError(f"no snapshot in {self.dir}")
            c = cands[0]
            tried.add(c)
            try:
                est = self.load_version(c)
            except (SnapshotIntegrityError, OSError) as e:
                # corrupt (already quarantined) or transiently unreadable
                # — fall back to the next older version
                self.load_fallbacks += 1
                last_err = e
                continue
            return c, est

    # ------------------------------- Checkpointer protocol (resilience)
    def as_checkpointer(self, estimator) -> "_SnapshotCheckpointer":
        """A :class:`repro.train.resilience.run_resilient`-compatible view
        whose ``save(step, carry)`` publishes ``estimator``'s CURRENT
        snapshot as version ``step`` and whose ``restore`` rehydrates the
        saved :class:`FitCarry` — crash recovery restarts from exactly
        what the actors are serving."""
        return _SnapshotCheckpointer(self, estimator)


class _SnapshotCheckpointer:
    def __init__(self, store: SnapshotStore, estimator):
        self.store = store
        self.est = estimator

    def wait(self) -> None:                 # publishes are synchronous
        pass

    def save(self, step: int, state: Any) -> None:
        # `state` is the learner's carry — already inside self.est, which
        # also holds the buffer snapshot the carry's indices refer to
        self.store.publish(self.est, step)

    def latest_step(self) -> Optional[int]:
        return self.store.latest_version()

    def steps(self) -> list:
        # run_resilient's restore-fallback chain: every intact version on
        # disk (quarantined files already left versions())
        return self.store.versions()

    def restore(self, step: int, like: Any, shardings: Any = None):
        from repro.api.executors import carry_of

        # load_version: CRC-checked, quarantines on corruption — the
        # raised SnapshotIntegrityError sends run_resilient to the next
        # older step in steps()
        loaded = self.store.load_version(step)
        carry = carry_of(loaded._outcome)
        if carry is None:
            raise ValueError(f"snapshot v{step} carries no resumable "
                             "FitCarry")
        return _host_carry(carry)


def _host_carry(carry):
    """FitCarry with every array leaf materialized to host numpy — safe to
    keep across donating ``partial_fit`` calls and to checkpoint."""
    import jax

    return type(carry)(
        state=jax.tree.map(lambda a: np.asarray(a), carry.state),
        key=np.asarray(carry.key), steps=carry.steps, iters=carry.iters)
