"""Versioned, atomically-swapped snapshot store for the serving split.

The learner publishes ``KernelKMeans`` snapshots (the PR-4 ``save``/
``load`` round-trip, including the resumable :class:`FitCarry`) into a
directory; actors poll ``latest_version()`` and load whole files.  Two
invariants make the swap safe with zero coordination:

* **Never a torn read.**  Every write goes to a same-directory temp file
  and is ``os.replace``d into place (both the snapshot ``.npz`` and the
  ``LATEST`` pointer) — a reader either sees the complete previous file or
  the complete new one, never a partial write
  (tests/test_service.py::test_snapshot_never_torn).
* **Staleness is the reader's contract.**  ``load(max_age_s=...)`` raises
  :class:`StaleSnapshot` when the newest snapshot is older than the bound
  — an actor keeps serving its in-memory model (and reports the age via
  telemetry) rather than silently serving arbitrarily old centers.

The store also speaks the :class:`repro.train.checkpoint.Checkpointer`
protocol (``save`` / ``restore`` / ``latest_step`` / ``wait``) through
:meth:`as_checkpointer`, so :func:`repro.train.resilience.run_resilient`
drives learner crash-recovery against the SAME files the actors serve
from — the published snapshot IS the checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Any, Optional

import numpy as np

_SNAP_RE = re.compile(r"^snapshot_(\d+)\.npz$")


class StaleSnapshot(RuntimeError):
    """Newest snapshot is older than the caller's staleness bound."""


class SnapshotStore:
    """Directory of ``snapshot_<version>.npz`` files + a ``LATEST``
    pointer, all updated write-temp-then-rename.  ``keep`` bounds disk use
    (older versions are pruned after a successful publish)."""

    def __init__(self, directory: str, keep: int = 4):
        self.dir = directory
        self.keep = int(keep)
        os.makedirs(directory, exist_ok=True)
        self.publishes = 0

    # ------------------------------------------------------------ paths
    def path_for(self, version: int) -> str:
        return os.path.join(self.dir, f"snapshot_{int(version)}.npz")

    def _replace(self, tmp: str, dst: str) -> None:
        os.replace(tmp, dst)        # atomic within one filesystem

    # ---------------------------------------------------------- publish
    def publish(self, estimator, version: int) -> str:
        """Atomically publish ``estimator``'s full snapshot (serving
        tuple + resumable carry) as ``version``.  Returns the path."""
        dst = self.path_for(version)
        tmp = dst + f".tmp.{os.getpid()}"
        estimator.save(tmp)
        self._replace(tmp, dst)
        ptr = os.path.join(self.dir, "LATEST")
        with open(ptr + f".tmp.{os.getpid()}", "w") as f:
            json.dump({"version": int(version), "time": time.time()}, f)
        self._replace(ptr + f".tmp.{os.getpid()}", ptr)
        self.publishes += 1
        self._prune()
        return dst

    def _prune(self) -> None:
        versions = sorted(self.versions())
        for v in versions[:-self.keep] if self.keep > 0 else []:
            try:
                os.remove(self.path_for(v))
            except OSError:
                pass

    # ------------------------------------------------------------ reads
    def versions(self) -> list:
        out = []
        for name in os.listdir(self.dir):
            m = _SNAP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_version(self) -> Optional[int]:
        ptr = os.path.join(self.dir, "LATEST")
        try:
            with open(ptr) as f:
                v = int(json.load(f)["version"])
        except (OSError, ValueError, KeyError):
            vs = self.versions()
            return vs[-1] if vs else None
        return v if os.path.exists(self.path_for(v)) else None

    def age_s(self, version: Optional[int] = None) -> Optional[float]:
        """Seconds since ``version`` (default: latest) was published."""
        v = self.latest_version() if version is None else version
        if v is None:
            return None
        try:
            return max(0.0, time.time() - os.path.getmtime(self.path_for(v)))
        except OSError:
            return None

    def load(self, version: Optional[int] = None,
             max_age_s: Optional[float] = None):
        """``(version, KernelKMeans)`` for ``version`` (default latest).
        With ``max_age_s``, a snapshot older than the bound raises
        :class:`StaleSnapshot` instead of loading."""
        from repro.api import KernelKMeans

        v = self.latest_version() if version is None else version
        if v is None:
            raise FileNotFoundError(f"no snapshot in {self.dir}")
        if max_age_s is not None:
            age = self.age_s(v)
            if age is None or age > max_age_s:
                raise StaleSnapshot(
                    f"snapshot v{v} is {age if age is not None else '?'}s "
                    f"old (bound {max_age_s}s)")
        return v, KernelKMeans.load(self.path_for(v))

    # ------------------------------- Checkpointer protocol (resilience)
    def as_checkpointer(self, estimator) -> "_SnapshotCheckpointer":
        """A :class:`repro.train.resilience.run_resilient`-compatible view
        whose ``save(step, carry)`` publishes ``estimator``'s CURRENT
        snapshot as version ``step`` and whose ``restore`` rehydrates the
        saved :class:`FitCarry` — crash recovery restarts from exactly
        what the actors are serving."""
        return _SnapshotCheckpointer(self, estimator)


class _SnapshotCheckpointer:
    def __init__(self, store: SnapshotStore, estimator):
        self.store = store
        self.est = estimator

    def wait(self) -> None:                 # publishes are synchronous
        pass

    def save(self, step: int, state: Any) -> None:
        # `state` is the learner's carry — already inside self.est, which
        # also holds the buffer snapshot the carry's indices refer to
        self.store.publish(self.est, step)

    def latest_step(self) -> Optional[int]:
        return self.store.latest_version()

    def restore(self, step: int, like: Any, shardings: Any = None):
        from repro.api import KernelKMeans
        from repro.api.executors import carry_of

        loaded = KernelKMeans.load(self.store.path_for(step))
        carry = carry_of(loaded._outcome)
        if carry is None:
            raise ValueError(f"snapshot v{step} carries no resumable "
                             "FitCarry")
        return _host_carry(carry)


def _host_carry(carry):
    """FitCarry with every array leaf materialized to host numpy — safe to
    keep across donating ``partial_fit`` calls and to checkpoint."""
    import jax

    return type(carry)(
        state=jax.tree.map(lambda a: np.asarray(a), carry.state),
        key=np.asarray(carry.key), steps=carry.steps, iters=carry.iters)
