"""The learner half of the serving split: continuous ``partial_fit`` over
the ingest buffer, with versioned snapshot publishing and crash recovery.

One learner round = (advance the ingest buffer one push) -> (resume the
estimator for ``iters_per_round`` mini-batch iterations on the buffer's
``(capacity, d)`` snapshot) -> (every ``publish_every`` rounds, atomically
publish the full estimator snapshot — serving tuple + resumable
:class:`FitCarry` — as version ``round``).

Why this is deterministic (and therefore recoverable): the buffer content
at round ``t`` is a pure function of ``(ingest seed, t)`` given the
deterministic arrival stream (:mod:`repro.service.buffer`), and the batch
indices drawn inside ``partial_fit`` are a pure function of the carried
PRNG fit key — which rides the published carry (the unified
:class:`repro.core.loop.FitCarry` every lowering threads through the
fit-loop core, so the learner resumes identically on whichever driver
the resolved plan uses — docs/architecture.md).  So
:func:`repro.train.resilience.run_resilient` can crash anywhere, restore
the last PUBLISHED snapshot (the snapshot is the checkpoint —
``SnapshotStore.as_checkpointer``), rewind the buffer by replaying the
stream, and converge to a carry BIT-IDENTICAL to an uninterrupted run
(tests/test_service.py, 8-virtual-device lane).

The fixed buffer capacity keeps the resume program's shapes constant, so
the PR-5 cross-executor program cache compiles it once —
``program_builds()`` stays flat across rounds (gated by
BENCH_service.json).  The per-round early stop (``epsilon`` on the
round's improvement) is the mini-batch termination bound of Schwartzman
(arXiv:2304.00419): O(1) iterations per round suffice for normalized
kernels at b = Theta(log n).
"""
from __future__ import annotations

import threading
from typing import Callable, Optional

import numpy as np

from repro.service.buffer import IngestBuffer
from repro.service.faults import fire
from repro.service.snapshot import SnapshotStore


class Learner:
    """Drives one estimator's fit stream from an ingest buffer.

    Parameters
    ----------
    estimator : a ``KernelKMeans`` on a ``partial_fit``-capable plan
        (``restarts=1, distribution='single', cache='none'``).
    buffer : the bounded ingest buffer (content pure in ``(seed, step)``).
    source : ``source(step) -> (m, d)`` deterministic arrival stream —
        in production the drained ingest queue keyed by sequence number,
        in tests/demos a synthetic generator.
    store : snapshot store shared with the actors.
    iters_per_round : mini-batch iterations per round (default: the
        config's ``max_iters``, which also governs the cold-start ``fit``
        of round 0; the config's ``epsilon`` early-stops within a round).
    publish_every : publish a snapshot every this many rounds.
    warmup_pushes : buffer pushes before round 0 (default: enough to
        fill — the learner never fits a part-empty buffer).
    seed : fit key for the initial ``fit`` (rounds resume its stream).
    """

    def __init__(self, estimator, buffer: IngestBuffer,
                 source: Callable[[int], np.ndarray], store: SnapshotStore,
                 *, iters_per_round: Optional[int] = None,
                 publish_every: int = 5,
                 warmup_pushes: Optional[int] = None, seed: int = 0,
                 on_round: Optional[Callable[[int], None]] = None,
                 log_every: int = 0, faults=None,
                 step_timeout_s: Optional[float] = None,
                 backoff_base_s: float = 0.0):
        self.est = estimator
        self.buffer = buffer
        self.source = source
        self.store = store
        self.iters_per_round = int(iters_per_round
                                   if iters_per_round is not None
                                   else estimator.config.max_iters)
        self.publish_every = int(publish_every)
        self.seed = seed
        self.on_round = on_round
        self.log_every = int(log_every)
        self.faults = faults
        self.step_timeout_s = step_timeout_s
        self.backoff_base_s = float(backoff_base_s)
        if warmup_pushes is None:
            warmup_pushes = (buffer.capacity if buffer.mode == "reservoir"
                             else 1)
        self.warmup_pushes = int(warmup_pushes)
        self.rounds = 0
        self.restores = 0
        self.last_improvement = None
        # degraded-mode counters: run_resilient fills events, the carry
        # guard fills guard_* — all surfaced via stats()/telemetry.poll()
        self.events: dict = {}
        self.guard_patched = 0
        self.guard_reseeded = 0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # ---------------------------------------------------------- plumbing
    def _round_buffer(self, rnd: int) -> np.ndarray:
        """Buffer snapshot for round ``rnd`` — pure in (seed, rnd); replays
        the stream when recovery rewound (or skipped ahead of) the
        cursor."""
        return self.buffer.replay_to(self.source,
                                     self.warmup_pushes + rnd + 1)

    def _step(self, carry, xbuf: np.ndarray):
        """One learner round under the ``run_resilient`` protocol:
        ``(carry, batch) -> (carry, metrics)``.  ``carry=None`` means
        cold start (initial ``fit`` draws init + key stream from
        ``seed``); afterwards the carry is always HOST-materialized, so
        the donating resume program can never invalidate it."""
        fire(self.faults, "learner.step")
        if carry is None:
            self.est.fit(xbuf, key=self.seed)
        else:
            self.est.restore_carry(carry)
            self.est.partial_fit(xbuf, iters=self.iters_per_round)
        self._guard(xbuf)
        if self.est.config.compress != "off":
            # round-cadence landmark compression: every published snapshot
            # carries the O(k*m) serving representation (stable serving
            # shapes across swaps -> zero actor recompiles), while the
            # resumable carry stays the full window.  Selection is keyed
            # by the carried step counter, so a crash-recovered learner
            # republishes bit-identical compressed models.
            self.est.compress()
        if self.on_round is not None:
            self.on_round(self.rounds)
        self.rounds += 1
        hist = self.est.history_
        if hist:
            self.last_improvement = hist[-1]["improvement"]
        if self.log_every and self.rounds % self.log_every == 0:
            from repro.service import telemetry
            print(telemetry.format_line(telemetry.poll(learner=self)),
                  flush=True)
        return self.est.snapshot_carry(), {"iters": int(self.est.iters_)}

    def _guard(self, xbuf: np.ndarray) -> None:
        """Non-finite-carry guard + dead-center reseed through the loop
        core (:func:`repro.core.loop.guard_carry`): degenerate arrivals
        (all-NaN rows, empty clusters — Tang & Monteleoni's stochastic
        k-means instability) can zero or poison center coefficients; the
        guard repairs the carry BEFORE it is compressed, published, or
        resumed.  Clean carries pass through untouched (same object), so
        the healthy path stays bit-identical."""
        from repro.core.loop import guard_carry

        host = self.est.snapshot_carry()
        if host is None:
            return
        kernel = (self.est.plan_.executor.kernel
                  if self.est.plan_ is not None else None)
        guarded, rep = guard_carry(host, x=xbuf, kernel=kernel,
                                   seed=self.seed, faults=self.faults)
        if rep.clean:
            return
        self.guard_patched += rep.patched
        self.guard_reseeded += rep.reseeded
        self.est.restore_carry(guarded)

    # --------------------------------------------------------------- run
    def run(self, n_rounds: int, max_restarts: int = 3,
            publish_final: bool = True):
        """Run ``n_rounds`` with crash recovery (``run_resilient`` over
        the snapshot-store checkpointer).  Returns the final host carry."""
        from repro.train.resilience import run_resilient

        ckpt = self.store.as_checkpointer(self.est)

        def on_restore(version: int) -> None:
            self.restores += 1
            self.rounds = version

        on_watchdog = (self.faults.abort_hangs
                       if self.faults is not None else None)
        carry, _ = run_resilient(
            self._step, self._round_buffer, None, n_rounds, ckpt,
            ckpt_every=self.publish_every, max_restarts=max_restarts,
            on_restore=on_restore, step_timeout_s=self.step_timeout_s,
            backoff_base_s=self.backoff_base_s,
            backoff_seed=int(self.seed) if np.isscalar(self.seed) else 0,
            on_watchdog=on_watchdog, events=self.events)
        if publish_final and self.rounds % self.publish_every != 0:
            self.store.publish(self.est, self.rounds)
        return carry

    # ------------------------------------------------- background thread
    def start(self, n_rounds: int, **kw) -> threading.Thread:
        """Run in a daemon thread (the ``--service`` demo wiring); the
        thread exits after ``n_rounds`` or on :meth:`stop`."""

        def _loop():
            try:
                self.run(n_rounds, **kw)
            except _Stopped:
                pass

        prev = self.on_round

        def _guard(rnd):
            if self._stop.is_set():
                raise _Stopped
            if prev is not None:
                prev(rnd)

        self.on_round = _guard
        self._thread = threading.Thread(target=_loop, daemon=True,
                                        name="service-learner")
        self._thread.start()
        return self._thread

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread is not None:
            self._thread.join(timeout)

    def stats(self) -> dict:
        return dict(rounds=self.rounds, publishes=self.store.publishes,
                    restores=self.restores,
                    last_improvement=self.last_improvement,
                    watchdog_fires=int(self.events.get(
                        "watchdog_fires", 0)),
                    restore_fallbacks=int(self.events.get(
                        "restore_fallbacks", 0)
                        + self.store.load_fallbacks),
                    guard_patched=self.guard_patched,
                    guard_reseeded=self.guard_reseeded)


class _Stopped(BaseException):
    """Cooperative stop signal.  Derives from BaseException so it passes
    straight through ``run_resilient``'s crash-recovery ``except
    Exception`` instead of triggering a restore."""

