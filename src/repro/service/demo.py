"""End-to-end service demo: learner thread + actor + synthetic traffic.

Used by ``python -m repro.launch.serve --service`` and smoke-run in CI.
Everything runs in one process (threads), but the only shared state
between learner and actor is the snapshot DIRECTORY — the same wiring
works across processes/hosts unchanged.
"""
from __future__ import annotations

import tempfile
import time

import numpy as np

from repro.service.actor import Actor, Backpressure
from repro.service.buffer import IngestBuffer
from repro.service.learner import Learner
from repro.service.snapshot import SnapshotStore
from repro.service import telemetry


def make_source(d: int, k: int, arrivals_per_step: int, seed: int = 0):
    """Deterministic arrival stream: step ``t``'s block of a fixed blob
    mixture, pure in ``(seed, t)`` (the replayability contract)."""
    from repro.data import blobs

    base, _ = blobs(n=max(4096, 4 * arrivals_per_step), d=d, k=k,
                    seed=seed)
    base = np.asarray(base, np.float32)

    def source(step: int) -> np.ndarray:
        rng = np.random.default_rng((seed, step, 0x50C))
        idx = rng.integers(0, base.shape[0], arrivals_per_step)
        return base[idx] + rng.normal(0, 0.01, (arrivals_per_step,
                                                base.shape[1])) \
            .astype(np.float32)

    return source


def build_service(snapshot_dir: str, *, k: int = 8, d: int = 16,
                  capacity: int = 2048, batch_size: int = 256,
                  tau: int = 128, iters_per_round: int = 4,
                  publish_every: int = 4, buffer_mode: str = "reservoir",
                  arrivals_per_step: int = 512, seed: int = 0,
                  buckets=(64, 256, 1024), queue_depth: int = 256,
                  max_wait_ms: float = 2.0, max_staleness_s=None,
                  log_every: int = 0, compress="off", faults=None,
                  step_timeout_s=None):
    """Wire (learner, actor, store, buffer, source) — unstarted.
    ``compress``: the SolverConfig landmark axis — e.g. ``{"m": 32}``
    makes the learner compress every round, so all published snapshots
    serve at O(k*m) (docs/compression.md).  ``faults``: one
    :class:`repro.service.faults.FaultPlan` shared by every component
    (None — the default — leaves all injection points dead);
    ``step_timeout_s`` arms the learner's watchdog."""
    from repro.api import KernelKMeans, SolverConfig

    cfg = SolverConfig(k=k, batch_size=batch_size, tau=tau,
                       max_iters=iters_per_round, epsilon=-1.0,
                       early_stop=False, kernel="rbf",
                       kernel_params={"kappa": 1.0}, cache="none",
                       distribution="single", jit=True,
                       compress=compress)
    est = KernelKMeans(cfg)
    store = SnapshotStore(snapshot_dir, faults=faults)
    buf = IngestBuffer(capacity, d, seed=seed, mode=buffer_mode,
                       faults=faults)
    source = make_source(d, k, arrivals_per_step, seed=seed)
    learner = Learner(est, buf, source, store,
                      iters_per_round=iters_per_round,
                      publish_every=publish_every, seed=seed,
                      log_every=log_every, faults=faults,
                      step_timeout_s=step_timeout_s)
    actor = Actor(store, buckets=buckets, queue_depth=queue_depth,
                  max_wait_ms=max_wait_ms, max_staleness_s=max_staleness_s,
                  faults=faults)
    return learner, actor, store, buf, source


def run_demo(*, rounds: int = 12, requests: int = 200,
             request_rows: int = 256, snapshot_dir=None, seed: int = 0,
             log_every: int = 4, verbose: bool = True, **build_kw) -> dict:
    """Learner fitting + publishing in the background while the actor
    serves ``requests`` query blocks; returns the final telemetry poll
    (plus served-label sanity fields)."""
    tmp = None
    if snapshot_dir is None:
        tmp = tempfile.TemporaryDirectory(prefix="repro_service_")
        snapshot_dir = tmp.name
    try:
        learner, actor, store, buf, _ = build_service(
            snapshot_dir, seed=seed, log_every=log_every, **build_kw)
        # round 0 synchronously: the actor needs a first snapshot
        learner.run(1)
        learner.start(rounds - 1)
        actor.start()

        rng = np.random.default_rng(seed + 1)
        d = buf.dim
        served = rejected = 0
        pending = []
        for i in range(requests):
            xq = rng.normal(0, 1, (request_rows, d)).astype(np.float32)
            try:
                pending.append(actor.submit(xq))
            except Backpressure:
                rejected += 1
                time.sleep(0.002)
            if len(pending) >= 8:
                for req in pending:
                    req.wait(60.0)
                    served += 1
                pending.clear()
        for req in pending:
            req.wait(60.0)
            served += 1

        learner.join(120.0)
        t = telemetry.poll(buffer=buf, learner=learner, actor=actor)
        t["demo"] = {"served": served, "client_rejected": rejected,
                     "rounds": learner.rounds,
                     "versions": store.versions()}
        if verbose:
            print(telemetry.format_line(t))
        actor.stop()
        learner.stop()
        return t
    finally:
        if tmp is not None:
            tmp.cleanup()
