"""Uniform service telemetry: one ``poll()`` dict shape + a log line.

Every service component (ingest buffer, learner, actor, Gram tile cache,
compiled-program registry) exports its counters through ONE shape so the
serving demo, the ``service`` benchmark and the existing ``--cache`` serve
path all report the same way:

    {
      "programs": {"fit_builds": int, "serve_compiles": int | None},
      "cache":    {hits, misses, evictions, hit_rate, evals, ...} | None,
      "ingest":   {mode, capacity, pushes, pushed, admitted, dropped, full},
      "queue":    {depth, capacity, submitted, served, rejected,
                   cancel_skipped, serve_retried},
      "snapshot": {version, age_s, swaps, swap_failures, quarantined,
                   last_swap_pause_ms, stale},
      "latency_ms": {p50, p99, count},
      "learner":  {rounds, publishes, restores, watchdog_fires,
                   restore_fallbacks, guard_patched, guard_reseeded,
                   last_improvement},
      "support":  {rows, active, window, k, compressions, m, last_drift,
                   ratio},
    }

The ``support`` section is the serving-cost gauge (docs/compression.md):
``rows`` is the live center-support size W*k the serving path pays per
query — reported whenever a learner or a swapped-in actor model exists,
even with ``compress="off"`` (that is how an operator notices unbounded
growth); the compression counters are populated once the landmark axis is
active.

Sections for components you did not pass are ``None`` — consumers key on
presence, not on argument plumbing.  ``fit_builds`` is always present: it
is the PR-5 cross-executor compile counter
(:func:`repro.api.executors.program_builds`), the "zero recompiles after
warmup" gate of BENCH_service.json.
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Optional

import numpy as np

_SECTIONS = ("programs", "cache", "ingest", "queue", "snapshot",
             "latency_ms", "learner", "support")


class LatencyWindow:
    """Thread-safe sliding window of latencies (ms) with percentiles."""

    def __init__(self, maxlen: int = 4096):
        self._buf = deque(maxlen=maxlen)
        self._lock = threading.Lock()
        self.count = 0

    def record(self, ms: float) -> None:
        with self._lock:
            self._buf.append(float(ms))
            self.count += 1

    def percentiles(self, qs=(50, 99)) -> dict:
        with self._lock:
            vals = np.asarray(self._buf, np.float64)
        out = {"count": self.count}
        for q in qs:
            out[f"p{q}"] = (float(np.percentile(vals, q)) if vals.size
                            else None)
        return out


def cache_section(cache) -> Optional[dict]:
    """GramTileCache counters in the uniform shape.  Accepts a
    ``GramTileCache``, a ``CachedKernel`` (unwraps ``.cache``), a stacked
    per-shard cache pytree (counters are summed over the stack), or
    None."""
    if cache is None:
        return None
    from repro.cache.tile_cache import GramTileCache, stats

    inner = getattr(cache, "cache", None)
    if isinstance(inner, GramTileCache):
        cache = inner
    if isinstance(cache, GramTileCache):
        if np.asarray(cache.hits).ndim == 0:
            return stats(cache)
        # stacked per-(restart,)shard caches: sum the counters, report the
        # per-shard geometry of one member
        hits = int(np.sum(np.asarray(cache.hits)))
        misses = int(np.sum(np.asarray(cache.misses)))
        tile = cache.store.shape[-2]
        n = cache.store.shape[-1]
        return dict(hits=hits, misses=misses,
                    evictions=int(np.sum(np.asarray(cache.evictions))),
                    resident=int(np.sum(np.asarray(cache.keys) >= 0)),
                    capacity=int(np.prod(cache.keys.shape)),
                    tile=tile, n_blocks=n // tile,
                    evals=misses * tile * n,
                    hit_rate=hits / max(hits + misses, 1))
    raise TypeError(f"unsupported cache object {type(cache).__name__}")


def poll(*, buffer=None, learner=None, actor=None, cache=None) -> dict:
    """Assemble the uniform telemetry dict from whichever components
    exist.  Always includes ``programs.fit_builds``."""
    from repro.api.executors import program_builds

    out = {name: None for name in _SECTIONS}
    out["programs"] = {
        "fit_builds": program_builds(),
        "serve_compiles": (actor.serve_compiles if actor is not None
                           else None),
    }
    out["cache"] = cache_section(cache)
    if buffer is not None:
        out["ingest"] = buffer.stats()
    if learner is not None:
        out["learner"] = learner.stats()
        if out["ingest"] is None and getattr(learner, "buffer", None) \
                is not None:
            out["ingest"] = learner.buffer.stats()
    if actor is not None:
        out["queue"] = actor.queue_stats()
        out["snapshot"] = actor.snapshot_stats()
        out["latency_ms"] = actor.latency.percentiles()
    # live learner support beats the actor's (possibly stale) snapshot view
    if learner is not None and getattr(learner, "est", None) is not None:
        out["support"] = learner.est.support_stats()
    if out["support"] is None and actor is not None:
        out["support"] = actor.support_stats()
    return out


def _fmt(v, spec=".3g"):
    return "-" if v is None else format(v, spec)


def format_line(t: dict) -> str:
    """One human log line from a ``poll()`` dict — the periodic heartbeat
    the learner/actor threads print."""
    parts = []
    ing = t.get("ingest")
    if ing:
        parts.append(f"ingest push={ing['pushes']} "
                     f"admit={ing['admitted']}/{ing['pushed']} "
                     f"drop={ing['dropped']}")
    lrn = t.get("learner")
    if lrn:
        line = (f"learner rounds={lrn['rounds']} "
                f"pub={lrn['publishes']} restore={lrn['restores']}")
        # degraded-mode counters appear only once nonzero — the healthy
        # heartbeat stays short
        degraded = {"wd": lrn.get("watchdog_fires"),
                    "fb": lrn.get("restore_fallbacks"),
                    "guard": lrn.get("guard_reseeded")}
        extra = " ".join(f"{k}={v}" for k, v in degraded.items() if v)
        parts.append(line + (" " + extra if extra else ""))
    q = t.get("queue")
    if q:
        parts.append(f"queue {q['depth']}/{q['capacity']} "
                     f"served={q['served']} rej={q['rejected']}"
                     + (f" cancel={q['cancel_skipped']}"
                        if q.get("cancel_skipped") else ""))
    snap = t.get("snapshot")
    if snap:
        v = snap["version"]
        parts.append(f"snap v{'-' if v is None else v}"
                     f" age={_fmt(snap['age_s'])}s "
                     f"swaps={snap['swaps']} "
                     f"pause={_fmt(snap['last_swap_pause_ms'])}ms"
                     + (f" fail={snap['swap_failures']}"
                        if snap.get("swap_failures") else "")
                     + (f" quar={snap['quarantined']}"
                        if snap.get("quarantined") else "")
                     + (" STALE" if snap.get("stale") else ""))
    lat = t.get("latency_ms")
    if lat:
        parts.append(f"lat p50={_fmt(lat['p50'])}ms "
                     f"p99={_fmt(lat['p99'])}ms n={lat['count']}")
    sup = t.get("support")
    if sup:
        s = (f"support rows={sup['rows']} active={sup['active']} "
             f"W={sup['window']}")
        if sup.get("compressions"):
            s += (f" m={sup['m']} ratio={_fmt(sup['ratio'])} "
                  f"drift={_fmt(sup['last_drift'])} "
                  f"n={sup['compressions']}")
        parts.append(s)
    cache = t.get("cache")
    if cache:
        parts.append(f"cache hit={cache['hits']} miss={cache['misses']} "
                     f"evict={cache['evictions']} "
                     f"rate={cache['hit_rate']:.2%}")
    prog = t.get("programs") or {}
    parts.append(f"builds fit={prog.get('fit_builds')}"
                 + (f" serve={prog['serve_compiles']}"
                    if prog.get("serve_compiles") is not None else ""))
    return "svc | " + " | ".join(parts)
