"""The actor half of the serving split: microbatched ``predict`` /
``transform`` from the latest published snapshot.

Request path: ``submit()`` places a request (any ``(m, d)`` query block)
on a BOUNDED admission queue — a full queue raises :class:`Backpressure`
immediately (the caller sheds load or retries; the queue never grows
unboundedly) — and returns a future.  The worker thread drains the queue
into microbatches, PADS each microbatch up to the smallest configured
bucket size that fits, runs one compiled assignment on the bucket shape,
and scatters the results back to the per-request futures.

Why buckets: the serving executable is compiled per query shape.  Padding
to a small fixed set of shapes means the warmup pass compiles each bucket
ONCE and steady-state serving recompiles NOTHING — the actor counts its
own trace-time compiles (``serve_compiles``), and together with the PR-5
``program_builds()`` counter this is the "zero recompiles after warmup"
gate of BENCH_service.json.

Snapshot swap: a dedicated swapper thread polls the store; a new version
is loaded and WARMED (one padded predict per bucket) entirely OFF the
serving path, then swapped in by one attribute assignment under a lock —
the serving thread never blocks on a load, in-flight requests finish on
the old model, later ones see the new one, and no request ever observes
a half-loaded estimator.  ``last_swap_pause_ms`` is the measured
load+warm duration (the swap's total cost; the serving-visible pause is
one lock acquisition).  A configurable staleness bound
(``max_staleness_s``) governs ACQUISITION: snapshots older than the bound
are refused (:class:`repro.service.snapshot.StaleSnapshot`), the actor
keeps its current model, and telemetry reports ``stale=True``.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Optional, Sequence

import jax
import numpy as np

from repro.core.minibatch import assign_chunked, center_distances_chunked
from repro.service.faults import fire
from repro.service.snapshot import SnapshotStore, StaleSnapshot
from repro.service.telemetry import LatencyWindow

_DEFAULT_BUCKETS = (64, 256, 1024)


class Backpressure(RuntimeError):
    """Admission queue is full — shed load or retry later."""


class _Request:
    __slots__ = ("xq", "kind", "event", "result", "error", "t_submit",
                 "deadline", "cancelled")

    def __init__(self, xq: np.ndarray, kind: str,
                 deadline_s: Optional[float] = None):
        self.xq = xq
        self.kind = kind                  # 'predict' | 'transform'
        self.event = threading.Event()
        self.result = None
        self.error: Optional[BaseException] = None
        self.t_submit = time.perf_counter()
        self.deadline = (time.monotonic() + deadline_s
                         if deadline_s is not None else None)
        self.cancelled = False

    # ------------------------------------------------------ future-ish
    def done(self) -> bool:
        return self.event.is_set()

    def cancel(self) -> None:
        """Mark the request dead to the worker: a cancelled (or
        deadline-expired) request is SKIPPED at serve time instead of
        being padded, computed, and delivered to nobody."""
        self.cancelled = True

    def expired(self) -> bool:
        return (self.cancelled
                or (self.deadline is not None
                    and time.monotonic() > self.deadline))

    def wait(self, timeout: Optional[float] = None):
        if not self.event.wait(timeout):
            self.cancel()       # the worker skips us instead of serving
            raise TimeoutError("request not served within timeout")
        if self.error is not None:
            raise self.error
        return self.result


class Actor:
    """Serves assignment requests from the latest snapshot.

    Parameters
    ----------
    store : snapshot store the learner publishes into.
    buckets : ascending microbatch pad shapes; requests larger than the
        biggest bucket are served in bucket-size slices.
    queue_depth : admission-queue bound (``submit`` raises
        :class:`Backpressure` beyond it).
    max_wait_ms : how long the worker waits to coalesce more requests
        into a non-full microbatch before serving it padded.
    max_staleness_s : refuse to ACQUIRE snapshots older than this
        (``None``: any age).
    poll_every_s : snapshot-version poll period.
    chunk : assignment chunk size (static arg of the compiled program).
    """

    def __init__(self, store: SnapshotStore, *,
                 buckets: Sequence[int] = _DEFAULT_BUCKETS,
                 queue_depth: int = 128, max_wait_ms: float = 2.0,
                 max_staleness_s: Optional[float] = None,
                 poll_every_s: float = 0.25, chunk: int = 4096,
                 faults=None, swap_backoff_cap_s: float = 2.0,
                 serve_retries: int = 1):
        if not buckets or list(buckets) != sorted(set(int(b)
                                                      for b in buckets)):
            raise ValueError("buckets must be ascending unique ints")
        self.store = store
        self.buckets = tuple(int(b) for b in buckets)
        self.max_wait_ms = float(max_wait_ms)
        self.max_staleness_s = max_staleness_s
        self.poll_every_s = float(poll_every_s)
        self.chunk = int(chunk)
        self.faults = faults
        self.swap_backoff_cap_s = float(swap_backoff_cap_s)
        self.serve_retries = int(serve_retries)

        self._queue: "queue.Queue[_Request]" = queue.Queue(
            maxsize=int(queue_depth))
        self._held: Optional[_Request] = None   # mismatched-kind head
        self._model_lock = threading.Lock()
        self._model = None                # (version, serving tuple)
        self._support = None              # support_stats() of served model
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

        # counters / telemetry
        self.latency = LatencyWindow()
        self.submitted = 0
        self.served = 0
        self.rejected = 0
        self.swaps = 0
        self.swap_failures = 0
        self.cancel_skipped = 0
        self.serve_retried = 0
        self.last_swap_pause_ms: Optional[float] = None
        self.stale = False
        self._last_poll = 0.0

        # trace-time compile counters: the wrapped python bodies run only
        # when jax (re)traces — steady state must not increment these
        self._compiles = [0]

        def _assign(kern, coef, sqnorm, sup, xq, chunk):
            self._compiles[0] += 1
            return assign_chunked(kern, coef, sqnorm, sup, xq, chunk)

        def _dists(kern, coef, sqnorm, sup, xq, chunk):
            self._compiles[0] += 1
            return center_distances_chunked(kern, coef, sqnorm, sup, xq,
                                            chunk)

        self._assign = jax.jit(_assign, static_argnames=("chunk",))
        self._dists = jax.jit(_dists, static_argnames=("chunk",))

    # ------------------------------------------------------------ model
    @property
    def serve_compiles(self) -> int:
        """Serving executables traced so far (flat after warmup)."""
        return self._compiles[0]

    @property
    def version(self) -> Optional[int]:
        m = self._model
        return m[0] if m is not None else None

    def _serving_tuple(self, est):
        kern, sup, coef, sqnorm = est._serving_tuple()
        return (kern, jax.numpy.asarray(sup), jax.numpy.asarray(coef),
                jax.numpy.asarray(sqnorm))

    def _warm(self, serving, dim: int) -> None:
        kern, sup, coef, sqnorm = serving
        for b in self.buckets:
            xq = np.zeros((b, dim), np.float32)
            self._assign(kern, coef, sqnorm, sup, xq,
                         self.chunk).block_until_ready()

    def try_swap(self, force: bool = False) -> bool:
        """Poll the store; acquire + warm + atomically swap in a newer
        snapshot.  Returns True when a swap happened.  Respects the
        staleness bound; never touches the served model on failure.

        The load goes through the store's integrity-checked fallback
        path: a corrupt latest snapshot is quarantined and the newest
        INTACT version is acquired instead — a corrupt file can delay a
        swap but can never be swapped in."""
        latest = self.store.latest_version()
        cur = self.version
        if latest is None or (latest == cur and not force):
            if self.max_staleness_s is not None:
                age = self.store.age_s()
                self.stale = age is None or age > self.max_staleness_s
            return False
        t0 = time.perf_counter()
        fire(self.faults, "actor.swap")
        try:
            v, est = self.store.load(max_age_s=self.max_staleness_s)
        except StaleSnapshot:
            self.stale = True
            return False
        except FileNotFoundError:
            return False
        if v == cur and not force:
            return False        # the newest INTACT version is already in
        serving = self._serving_tuple(est)
        self._warm(serving, int(np.asarray(serving[1]).shape[-1]))
        with self._model_lock:
            self._model = (v, serving)
            self._support = est.support_stats()
        self.stale = False
        self.swaps += 1
        self.last_swap_pause_ms = (time.perf_counter() - t0) * 1e3
        return True

    # ---------------------------------------------------------- serving
    def submit(self, xq, kind: str = "predict",
               deadline_s: Optional[float] = None) -> _Request:
        """Enqueue a query block; returns a future-like request.  Raises
        :class:`Backpressure` when the admission queue is full.  With
        ``deadline_s``, the worker skips the request (instead of serving
        it to nobody) once the deadline passes."""
        if kind not in ("predict", "transform"):
            raise ValueError(kind)
        req = _Request(np.asarray(xq, np.float32), kind, deadline_s)
        try:
            self._queue.put_nowait(req)
        except queue.Full:
            self.rejected += 1
            raise Backpressure(
                f"admission queue full ({self._queue.maxsize} deep)") \
                from None
        self.submitted += 1
        return req

    def predict(self, xq, timeout: Optional[float] = 30.0):
        return self.submit(xq, "predict", deadline_s=timeout).wait(timeout)

    def transform(self, xq, timeout: Optional[float] = 30.0):
        return self.submit(xq, "transform",
                           deadline_s=timeout).wait(timeout)

    # ------------------------------------------------------ worker loop
    def start(self) -> "Actor":
        if self._model is None:
            self.try_swap(force=True)
        self._thread = threading.Thread(target=self._serve_loop,
                                        daemon=True, name="service-actor")
        self._swapper = threading.Thread(target=self._swap_loop,
                                         daemon=True,
                                         name="service-actor-swap")
        self._thread.start()
        self._swapper.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> None:
        self._stop.set()
        for t in (self._thread, self._swapper):
            if t is not None:
                t.join(timeout)

    def _serve_loop(self) -> None:
        while not self._stop.is_set():
            batch = self._gather()
            if batch:
                self._serve(batch)

    def _swap_backoff_s(self, consec: int) -> float:
        """Poll period, stretched exponentially (with deterministic
        jitter keyed by the failure count) after ``consec`` consecutive
        swap failures — a broken store is polled gently, not hot."""
        if consec <= 0:
            return self.poll_every_s
        jitter = float(np.random.default_rng((0xB0FF, int(consec)))
                       .random())
        return min(self.swap_backoff_cap_s,
                   self.poll_every_s * (2.0 ** consec)) * (1.0
                                                           + 0.25 * jitter)

    def _swap_loop(self) -> None:
        """Load + warm off the serving path; the serving thread only ever
        sees the finished swap (one locked assignment).  Failures are
        COUNTED (``swap_failures``) and back the poll off — the actor
        keeps serving its current model either way."""
        consec = 0
        while not self._stop.wait(self._swap_backoff_s(consec)):
            try:
                self.try_swap()
            except Exception:           # noqa: BLE001 — keep serving
                self.swap_failures += 1
                consec += 1
            else:
                consec = 0

    def _gather(self) -> list:
        """Pop one request (blocking briefly), then coalesce more until
        the biggest bucket fills or ``max_wait_ms`` elapses.  A
        mismatched-kind request is HELD as the next microbatch's head —
        never re-queued to the back (which would reorder admitted
        requests and, on a full queue, error one with Backpressure)."""
        if self._held is not None:
            first, self._held = self._held, None
        else:
            try:
                first = self._queue.get(timeout=self.poll_every_s)
            except queue.Empty:
                return []
        batch, rows = [first], first.xq.shape[0]
        deadline = time.monotonic() + self.max_wait_ms / 1e3
        limit = self.buckets[-1]
        while rows < limit:
            remaining = deadline - time.monotonic()
            # same-kind coalescing keeps the scatter trivial
            try:
                nxt = self._queue.get(timeout=max(remaining, 0) or None) \
                    if remaining > 0 else self._queue.get_nowait()
            except queue.Empty:
                break
            if nxt.kind != first.kind:
                self._held = nxt        # head of the NEXT microbatch
                break
            batch.append(nxt)
            rows += nxt.xq.shape[0]
        return batch

    def _bucket_for(self, rows: int) -> int:
        for b in self.buckets:
            if rows <= b:
                return b
        return self.buckets[-1]

    def _serve(self, batch: list) -> None:
        # deadline check: a request whose caller already timed out (or
        # cancelled) is skipped, not padded + computed + delivered to
        # nobody
        live = []
        for req in batch:
            if req.expired():
                self.cancel_skipped += 1
                req.error = TimeoutError(
                    "request expired before serving")
                req.event.set()
            else:
                live.append(req)
        batch = live
        if not batch:
            return
        with self._model_lock:
            model = self._model
        if model is None:
            err = RuntimeError("no snapshot available to serve from")
            for req in batch:
                req.error = err
                req.event.set()
            return
        _, (kern, sup, coef, sqnorm) = model
        kind = batch[0].kind
        fn = self._assign if kind == "predict" else self._dists
        flat = None
        for attempt in range(self.serve_retries + 1):
            try:
                fire(self.faults, "actor.serve")
                xq = np.concatenate([r.xq for r in batch], axis=0)
                outs = []
                for lo in range(0, xq.shape[0], self.buckets[-1]):
                    sl = xq[lo:lo + self.buckets[-1]]
                    bucket = self._bucket_for(sl.shape[0])
                    pad = bucket - sl.shape[0]
                    if pad:
                        sl = np.concatenate(
                            [sl,
                             np.broadcast_to(sl[-1:],
                                             (pad,) + sl.shape[1:])])
                    out = fn(kern, coef, sqnorm, sup, sl, self.chunk)
                    outs.append(np.asarray(out)[:bucket - pad])
                flat = np.concatenate(outs, axis=0)
                break
            except Exception as e:        # noqa: BLE001 — retry, then fail
                if attempt >= self.serve_retries:
                    for req in batch:
                        req.error = e
                        req.event.set()
                    return
                self.serve_retried += 1
        t_done = time.perf_counter()
        lo = 0
        for req in batch:
            m = req.xq.shape[0]
            req.result = flat[lo:lo + m]
            lo += m
            self.latency.record((t_done - req.t_submit) * 1e3)
            req.event.set()
            self.served += 1

    # -------------------------------------------------------- telemetry
    def queue_stats(self) -> dict:
        return dict(depth=self._queue.qsize(),
                    capacity=self._queue.maxsize,
                    submitted=self.submitted, served=self.served,
                    rejected=self.rejected,
                    cancel_skipped=self.cancel_skipped,
                    serve_retried=self.serve_retried)

    def snapshot_stats(self) -> dict:
        return dict(version=self.version,
                    age_s=self.store.age_s(self.version),
                    swaps=self.swaps,
                    swap_failures=self.swap_failures,
                    quarantined=self.store.quarantined,
                    last_swap_pause_ms=self.last_swap_pause_ms,
                    stale=self.stale)

    def support_stats(self) -> Optional[dict]:
        """Support-size / compression counters of the SERVED model (the
        last swapped-in snapshot) — ``None`` before the first swap."""
        return self._support
