"""repro.service — the always-on clustering service (learner/actor split).

The paper's O(kb^2)-per-step mini-batch kernel k-means makes CONTINUOUS
clustering of live traffic affordable; this package is the serving story
around it:

* :class:`IngestBuffer` — bounded, deterministically-admitted ingest
  (reservoir / nested prefix-reuse), content pure in ``(seed, step)``.
* :class:`Learner` — continuous ``KernelKMeans.partial_fit`` over the
  buffer, publishing versioned snapshots; crash recovery through
  :func:`repro.train.resilience.run_resilient` is bit-identical to an
  uninterrupted run.
* :class:`SnapshotStore` — versioned, write-temp-then-rename snapshot
  files (the PR-4 save/load round-trip); readers never see a torn file.
* :class:`Actor` — microbatched ``predict``/``transform`` from the
  latest snapshot: bounded admission queue with :class:`Backpressure`,
  pad-to-bucket shapes (zero steady-state recompiles), atomic snapshot
  swap with a staleness bound.
* :mod:`repro.service.telemetry` — one ``poll()`` dict + log line for
  every counter (ingest/drops, queue depth, snapshot age/version,
  p50/p99 latency, compile counters, Gram-tile-cache hits).

See docs/serving.md for the architecture and knobs, and
``python -m repro.launch.serve --service`` for the demo.
"""
from repro.service.actor import Actor, Backpressure
from repro.service.buffer import IngestBuffer
from repro.service.learner import Learner
from repro.service.snapshot import SnapshotStore, StaleSnapshot
from repro.service import telemetry

__all__ = [
    "Actor", "Backpressure", "IngestBuffer", "Learner", "SnapshotStore",
    "StaleSnapshot", "telemetry",
]
