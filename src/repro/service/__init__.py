"""repro.service — the always-on clustering service (learner/actor split).

The paper's O(kb^2)-per-step mini-batch kernel k-means makes CONTINUOUS
clustering of live traffic affordable; this package is the serving story
around it:

* :class:`IngestBuffer` — bounded, deterministically-admitted ingest
  (reservoir / nested prefix-reuse), content pure in ``(seed, step)``.
* :class:`Learner` — continuous ``KernelKMeans.partial_fit`` over the
  buffer, publishing versioned snapshots; crash recovery through
  :func:`repro.train.resilience.run_resilient` is bit-identical to an
  uninterrupted run.
* :class:`SnapshotStore` — versioned, write-temp-then-rename snapshot
  files (the PR-4 save/load round-trip); readers never see a torn file.
* :class:`Actor` — microbatched ``predict``/``transform`` from the
  latest snapshot: bounded admission queue with :class:`Backpressure`,
  pad-to-bucket shapes (zero steady-state recompiles), atomic snapshot
  swap with a staleness bound.
* :mod:`repro.service.telemetry` — one ``poll()`` dict + log line for
  every counter (ingest/drops, queue depth, snapshot age/version,
  p50/p99 latency, compile counters, Gram-tile-cache hits).

* :mod:`repro.service.faults` — the deterministic chaos harness: a
  :class:`FaultPlan` of (site, kind) rules whose every firing is a pure
  function of (plan seed, site, occurrence index), threaded through all
  of the above as no-op-by-default injection points.

See docs/serving.md for the architecture and knobs, docs/robustness.md
for the fault sites and recovery guarantees, and
``python -m repro.launch.serve --service`` for the demo.
"""
from repro.api.estimator import SnapshotIntegrityError
from repro.service.actor import Actor, Backpressure
from repro.service.buffer import IngestBuffer
from repro.service.faults import FaultPlan, FaultRule, InjectedFault
from repro.service.learner import Learner
from repro.service.snapshot import SnapshotStore, StaleSnapshot
from repro.service import telemetry

__all__ = [
    "Actor", "Backpressure", "FaultPlan", "FaultRule", "IngestBuffer",
    "InjectedFault", "Learner", "SnapshotIntegrityError", "SnapshotStore",
    "StaleSnapshot", "telemetry",
]
