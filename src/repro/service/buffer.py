"""Bounded ingest buffer for the always-on clustering service.

The learner never fits live traffic directly: arrivals stream through a
fixed-capacity buffer whose content after ``t`` pushes is a PURE FUNCTION
of ``(seed, t)`` given a deterministic arrival stream.  That purity is the
whole fault-tolerance story — a crashed learner rebuilds the exact buffer
by replaying the stream (``replay_to``), so crash-recovery fits are
bit-identical to uninterrupted ones (tests/test_service.py).

Two admission modes:

* ``mode='reservoir'`` — Vitter's Algorithm R, derandomized: arrival ``m``
  lands in slot ``rng((seed, m)).integers(0, m + 1)`` iff that draw is
  below capacity.  The buffer is a uniform sample of the WHOLE history;
  every admission decision depends only on ``(seed, m)``.
* ``mode='nested'`` — the nested prefix-reuse idiom of
  :func:`repro.core.minibatch.sample_batch_nested` /
  ``ClusterBatchPipeline(mode='nested')`` (Newling & Fleuret 2016) turned
  into an admission policy: the first ``reuse * capacity`` slots are a
  slowly-refreshing prefix (slot ``i`` turns over once per ``refresh``
  pushes, staggered), the tail re-draws from the current push's arrivals
  every step.  Consecutive buffer snapshots share most rows, which keeps
  the learner's Gram working set (and the tile cache, when enabled) hot.

Fixed capacity means a fixed ``(capacity, d)`` snapshot shape, so the
learner's ``partial_fit`` resume program compiles ONCE and every later
round reuses it (``program_builds()`` stays flat — the service bench
gates on this).

Counters (``pushed`` / ``admitted`` / ``dropped``) feed
:func:`repro.service.telemetry.poll`.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

_MODES = ("reservoir", "nested")
_TAIL_SALT = 0x7A11      # matches sample_batch_nested's tail stream salt


class IngestBuffer:
    """Fixed-capacity, deterministically-admitted point buffer.

    Parameters
    ----------
    capacity : rows held (the learner's dataset size — fixed shape).
    dim : point dimensionality.
    seed : admission-stream seed; content is pure in ``(seed, pushes)``.
    mode : ``'reservoir'`` | ``'nested'`` (see module docs).
    reuse, refresh : nested-mode prefix fraction / turnover period
        (same meaning as ``SolverConfig.reuse`` / ``refresh``).
    """

    def __init__(self, capacity: int, dim: int, seed: int = 0,
                 mode: str = "reservoir", reuse: float = 0.5,
                 refresh: int = 8, dtype=np.float32, faults=None):
        if mode not in _MODES:
            raise ValueError(f"mode={mode!r} not in {_MODES}")
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity, self.dim, self.seed = int(capacity), int(dim), seed
        self.mode, self.reuse, self.refresh = mode, float(reuse), int(refresh)
        self.dtype = np.dtype(dtype)
        self.faults = faults
        self.reset()

    # ------------------------------------------------------------- state
    def reset(self) -> None:
        self._data = np.zeros((self.capacity, self.dim), self.dtype)
        self.pushes = 0          # completed push() calls
        self.pushed = 0          # arrival rows seen
        self.admitted = 0        # rows written into a slot
        self._seen = 0           # reservoir: lifetime arrival count

    @property
    def dropped(self) -> int:
        return self.pushed - self.admitted

    @property
    def full(self) -> bool:
        """Every slot holds a real arrival (learner readiness gate)."""
        if self.mode == "reservoir":
            return self._seen >= self.capacity
        # nested mode writes every slot on push 0 (prefix epoch rollover
        # at step 0 + full tail redraw)
        return self.pushes >= 1

    def snapshot(self) -> np.ndarray:
        """A host copy of the current ``(capacity, d)`` content."""
        return self._data.copy()

    # ------------------------------------------------------------ ingest
    def push(self, points: np.ndarray) -> int:
        """Admit one step's arrivals; returns rows admitted.  Decisions
        depend only on ``(seed, arrival index / push index)`` — never on
        wall clock or prior RNG state — so replaying the same stream
        reproduces the content bit-exactly."""
        pts = np.asarray(points, self.dtype)
        if pts.ndim != 2 or pts.shape[1] != self.dim:
            raise ValueError(f"expected (m, {self.dim}) arrivals, got "
                             f"{pts.shape}")
        if self.faults is not None:
            # keyed by PUSH INDEX (not a call counter) so a crash-recovery
            # replay_to re-fires the exact same faults at the exact same
            # pushes — buffer purity in (seed, pushes) extends to the
            # injected degenerate arrivals
            from repro.service.faults import fire

            ev = fire(self.faults, "buffer.push", index=self.pushes)
            if ev is not None and ev.kind == "nan":
                pts = self.faults.nan_rows(pts, ev)
        took = (self._push_reservoir(pts) if self.mode == "reservoir"
                else self._push_nested(pts))
        self.pushed += pts.shape[0]
        self.admitted += took
        self.pushes += 1
        return took

    def _push_reservoir(self, pts: np.ndarray) -> int:
        took = 0
        for row in pts:
            m = self._seen
            if m < self.capacity:
                slot = m
            else:
                slot = int(np.random.default_rng((self.seed, m))
                           .integers(0, m + 1))
                if slot >= self.capacity:
                    slot = -1
            if slot >= 0:
                self._data[slot] = row
                took += 1
            self._seen += 1
        return took

    def _push_nested(self, pts: np.ndarray) -> int:
        step, n_arr = self.pushes, pts.shape[0]
        if n_arr == 0:
            return 0
        m = int(self.capacity * self.reuse)
        taken = set()        # distinct arrival rows admitted this push
        # prefix: slot i refreshes when its (staggered) epoch rolls over
        for i in range(m):
            if (step + i) % self.refresh == 0 or step == 0:
                pick = int(np.random.default_rng(
                    (self.seed, i, (step + i) // self.refresh))
                    .integers(0, n_arr))
                self._data[i] = pts[pick]
                taken.add(pick)
        # tail: fresh uniform (with replacement) draw from this push's
        # arrivals — mirrors sample_batch_nested's fresh tail
        tail = self.capacity - m
        if tail > 0:
            picks = np.random.default_rng(
                (self.seed, step, _TAIL_SALT)).integers(0, n_arr, tail)
            self._data[m:] = pts[picks]
            taken.update(int(p) for p in picks)
        return len(taken)

    # ------------------------------------------------------------ replay
    def replay_to(self, source: Callable[[int], np.ndarray],
                  pushes: int) -> np.ndarray:
        """Drive the buffer to exactly ``pushes`` completed pushes of the
        deterministic ``source(step) -> (m, d)`` stream, rebuilding from
        scratch when the target lies in the past (crash recovery rewinds
        this way).  Returns a content snapshot."""
        if pushes < self.pushes:
            self.reset()
        while self.pushes < pushes:
            self.push(source(self.pushes))
        return self.snapshot()

    def stats(self) -> dict:
        """Counter snapshot — the ``ingest`` section of telemetry.poll."""
        return dict(mode=self.mode, capacity=self.capacity,
                    pushes=self.pushes, pushed=self.pushed,
                    admitted=self.admitted, dropped=self.dropped,
                    full=bool(self.full))
