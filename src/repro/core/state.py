"""Fixed-shape truncated-center state for Algorithm 2.

The paper maintains each center as a sparse combination of the points in the
most recent batches Q_i^j ("smallest suffix with >= tau points").  On TPU we
need fixed shapes, so each center owns a ring buffer of W = tau + b point
slots.  Overwriting the oldest slot individually (instead of dropping whole
batches) keeps the window at >= tau most-recent points once full, which is
exactly the property Lemma 3's decay bound needs (see DESIGN.md §3).

Invariants:
* slot with ``coef == 0`` is empty; its ``idx`` is 0 (a valid gather index —
  the zero coefficient nullifies the contribution).
* while the initial (k-means++) point has not been evicted, the truncated
  center EQUALS the exact Algorithm-1 center (paper's ``min Q = 1`` case).
* ``sqnorm[j] == <C_j, C_j>`` in feature space at all times.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import KernelFn, kernel_diag


class CenterState(NamedTuple):
    idx: jax.Array      # (k, W) int32 — indices into the dataset
    coef: jax.Array     # (k, W) f32   — coefficient on phi(X[idx])
    head: jax.Array     # (k,)   int32 — next ring write position
    sqnorm: jax.Array   # (k,)   f32   — <C_j, C_j>
    counts: jax.Array   # (k,)   f32   — lifetime #points assigned (sklearn rate)
    step: jax.Array     # ()     int32

    @property
    def k(self) -> int:
        return self.idx.shape[0]

    @property
    def window(self) -> int:
        return self.idx.shape[1]


def init_state(x: jax.Array, center_idx: jax.Array, kernel: KernelFn,
               window: int) -> CenterState:
    """Centers start as single data points (k-means++ / random init picks
    indices), occupying slot 0 with coefficient 1."""
    k = center_idx.shape[0]
    idx = jnp.zeros((k, window), jnp.int32).at[:, 0].set(center_idx)
    coef = jnp.zeros((k, window), jnp.float32).at[:, 0].set(1.0)
    return CenterState(
        idx=idx,
        coef=coef,
        head=jnp.ones((k,), jnp.int32),
        sqnorm=kernel_diag(kernel, x[center_idx]).astype(jnp.float32),
        counts=jnp.zeros((k,), jnp.float32),
        step=jnp.zeros((), jnp.int32),
    )


def window_size(batch_size: int, tau: int) -> int:
    """W = tau + b: a full ring always retains >= tau points newer than any
    evicted point (Lemma 3's requirement)."""
    return tau + batch_size
