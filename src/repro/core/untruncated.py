"""Algorithm 1 — untruncated mini-batch kernel k-means via dynamic
programming over the inner-product tables (paper §4, Appendix A).

State: P[x, j] = <phi(x), C_j> for EVERY x in X (n x k) and
sqnorm[j] = <C_j, C_j>.  One iteration costs O(n(b + k)) kernel
evaluations/flops — the paper's intermediate algorithm, and the exact
oracle for Algorithm 2 (while the truncation window has not evicted
anything, both algorithms produce IDENTICAL centers — tested).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import init as init_lib
from repro.core.kernel_fns import KernelFn, kernel_cross, kernel_diag
from repro.core.minibatch import MBConfig, sample_batch
from repro.core.rates import get_rate


class DPState(NamedTuple):
    p: jax.Array        # (n, k)  <phi(x), C_j>
    sqnorm: jax.Array   # (k,)
    counts: jax.Array   # (k,)
    step: jax.Array     # ()


class DPInfo(NamedTuple):
    f_before: jax.Array
    f_after: jax.Array
    improvement: jax.Array
    batch_counts: jax.Array
    assignments: jax.Array


def init_dp_state(x: jax.Array, center_idx: jax.Array,
                  kernel: KernelFn) -> DPState:
    p = kernel_cross(kernel, x, x[center_idx])              # (n, k)
    return DPState(p=p.astype(jnp.float32),
                   sqnorm=kernel_diag(kernel, x[center_idx]).astype(jnp.float32),
                   counts=jnp.zeros((center_idx.shape[0],), jnp.float32),
                   step=jnp.zeros((), jnp.int32))


def make_dp_step(kernel: KernelFn, cfg: MBConfig):
    rate_fn = get_rate(cfg.rate)
    b = cfg.batch_size

    def step(state: DPState, x: jax.Array, batch_idx: jax.Array):
        k = state.sqnorm.shape[0]
        xb = x[batch_idx]
        diag_b = kernel_diag(kernel, xb)
        pb = state.p[batch_idx]                              # (b, k)
        dists = diag_b[:, None] - 2.0 * pb + state.sqnorm[None, :]
        f_before = jnp.mean(jnp.min(dists, axis=1))
        assign = jnp.argmin(dists, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
        bj = jnp.sum(onehot, axis=0)
        alpha = rate_fn(bj, state.counts, b)
        decay = 1.0 - alpha

        # P update: <phi(x), C'_j> = decay_j P[x,j] + alpha_j <phi(x), cm(B_j)>
        onehot_n = onehot / jnp.maximum(bj, 1.0)[None, :]
        kxb = kernel_cross(kernel, x, xb)                    # (n, b)
        p_new = decay[None, :] * state.p + alpha[None, :] * (kxb @ onehot_n)

        # sqnorm update (exact, no truncation => no eviction corrections)
        kbb = kernel_cross(kernel, xb, xb)
        cm_cross = jnp.sum(onehot * pb, axis=0) / jnp.maximum(bj, 1.0)
        cm_sq = jnp.sum(onehot_n * (kbb @ onehot_n), axis=0)
        sq_new = (decay ** 2 * state.sqnorm
                  + 2.0 * decay * alpha * cm_cross + alpha ** 2 * cm_sq)

        d_new = diag_b[:, None] - 2.0 * p_new[batch_idx] + sq_new[None, :]
        f_after = jnp.mean(jnp.min(d_new, axis=1))

        new_state = DPState(p=p_new, sqnorm=sq_new,
                            counts=state.counts + bj, step=state.step + 1)
        return new_state, DPInfo(f_before, f_after, f_before - f_after,
                                 bj, assign)

    return step


def fit(x: jax.Array, kernel: KernelFn, cfg: MBConfig, key: jax.Array,
        init: str = "kmeans++", early_stop: bool = True, init_idx=None):
    n = x.shape[0]
    if init_idx is None:
        kinit, key = jax.random.split(key)
        if init == "kmeans++":
            init_idx = init_lib.kmeans_plus_plus(kinit, x, cfg.k, kernel)
        else:
            init_idx = init_lib.random_init(kinit, n, cfg.k)
    state = init_dp_state(x, init_idx, kernel)
    step = jax.jit(make_dp_step(kernel, cfg), donate_argnums=(0,))
    history = []
    for i in range(cfg.max_iters):
        key, kb = jax.random.split(key)
        bidx = sample_batch(kb, n, cfg.batch_size)
        state, info = step(state, x, bidx)
        imp = float(info.improvement)
        history.append(dict(step=i, f_before=float(info.f_before),
                            f_after=float(info.f_after), improvement=imp))
        if early_stop and imp < cfg.epsilon:
            break
    return state, history


def assignments(state: DPState, x: jax.Array, kernel: KernelFn) -> jax.Array:
    d = (kernel_diag(kernel, x)[:, None] - 2.0 * state.p
         + state.sqnorm[None, :])
    return jnp.argmin(d, axis=1).astype(jnp.int32)
