"""Algorithm 2 — truncated mini-batch kernel k-means (the paper's core).

One iteration (Theorem 1(1): O(k (tau+b)^2) kernel evaluations):

1. sample a batch B of b points uniformly with replacement (PRNG-keyed);
2. assign each batch point to the nearest truncated center
   (d(x, C_j) = K(x,x) - 2 <phi(x), C_j> + <C_j, C_j>, where
   <phi(x), C_j> = sum_w coef[j,w] K(x, X[idx[j,w]]));
3. per-center learning rate alpha_j (beta or sklearn, rates.py);
4. decay existing coefficients by (1 - alpha_j) and append the assigned
   batch points with coefficient alpha_j / b_j into the ring window;
5. refresh <C_j, C_j> (paper-faithful O(k W^2) recompute, or the
   beyond-paper O(k W b) incremental mode);
6. early stopping when the batch objective improves by less than epsilon.

Everything is fixed-shape and jit-compatible; ``make_step`` closes over the
static config and returns a pure step function.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.api import keys as api_keys
from repro.core.kernel_fns import (
    KernelFn, diag_of, gram_rows_fn, kernel_cross,
)
from repro.core.loop import (  # noqa: F401  (re-exported loop-core names)
    compress_hook, drive_fit_loop, precision_plan, run_early_stopped,
    run_early_stopped_keyed,
)
from repro.core.rates import get_rate
from repro.core.state import CenterState, init_state, window_size


class MBConfig(NamedTuple):
    """Static configuration for Algorithm 2 (hashable -> jit static arg)."""

    k: int
    batch_size: int
    tau: int
    rate: str = "beta"              # 'beta' (paper theory) | 'sklearn'
    sqnorm_mode: str = "recompute"  # 'recompute' (paper) | 'incremental'
    eval_mode: str = "direct"       # 'direct' (paper) | 'delta' (beyond-paper)
    epsilon: float = 1e-4
    max_iters: int = 200
    use_pallas: bool = False        # fused_assign Pallas kernel for step 2
    compute_dtype: str = "float32"  # 'bfloat16': MXU-native kernel evals
    step: str = "composed"          # 'fused': streaming one-pass step
    #   (repro.kernels.fused_step; online argmin, no (b, kW) strip in HBM;
    #   bit-identical to 'composed' at f32 — see docs/perf.md)
    compress: Optional[tuple] = None  # landmark CompressSpec (hashable) —
    #   every compress.every-th iteration ends with an in-place Nystrom
    #   projection of every window onto compress.m landmark rows
    #   (repro.landmark.compress; None emits the historical program
    #   unchanged — docs/compression.md)


class StepInfo(NamedTuple):
    f_before: jax.Array     # f_B(C_i)      — batch objective at entry
    f_after: jax.Array      # f_B(C_{i+1})  — batch objective after update
    improvement: jax.Array  # f_before - f_after (early stop: < epsilon)
    batch_counts: jax.Array  # (k,) b_i^j
    assignments: jax.Array   # (b,) int32


def _batch_center_dots(kernel: KernelFn, xb: jax.Array, x: jax.Array,
                       idx: jax.Array, coef: jax.Array,
                       use_pallas: bool, cdt=None) -> jax.Array:
    """P[x, j] = <phi(x), C_j> for batch xb against windowed centers.

    ``cdt``: optional kernel-eval compute dtype for the COORDINATES (the
    ``precision="bf16"`` axis); the coefficient contraction stays f32.
    None (the default) emits the historical program unchanged."""
    k, w = idx.shape
    if use_pallas:
        from repro.kernels import ops as kops
        rows_fn = gram_rows_fn(kernel)
        if rows_fn is not None:
            # gather-from-cache path: resolve the batch's full Gram rows
            # once (hits skip kernel evals), then the Pallas kernel fuses
            # the support-column gather with the coefficient contraction —
            # zero kernel evaluations for resident rows.
            return kops.cached_assign_dots(rows_fn(kernel, xb), idx, coef)
        xbc = xb if cdt is None else xb.astype(cdt)
        sup = x[idx.reshape(-1)]
        return kops.fused_batch_center_dots(
            kernel, xbc, sup if cdt is None else sup.astype(cdt), coef)
    sup = x[idx.reshape(-1)]                      # (k*W, d)
    if cdt is not None:
        cross = kernel_cross(kernel, xb.astype(cdt), sup.astype(cdt)) \
            .astype(jnp.float32)
    else:
        cross = kernel_cross(kernel, xb, sup)     # (b, k*W)
    return jnp.einsum("bkw,kw->bk", cross.reshape(xb.shape[0], k, w), coef)


def _append_to_windows(idx, coef, head, alpha, bj, onehot, batch_idx):
    """Masked ring-buffer append.  Returns new (idx, coef, head) plus the
    (post-decay) index/coefficient of every evicted slot — the incremental
    sqnorm path needs them.  b_j <= b <= W, so within one iteration the
    write positions never collide."""
    k, w = idx.shape
    b = batch_idx.shape[0]

    def one_center(idx_row, coef_row, head_j, alpha_j, bj_j, mask_j):
        # position among this center's assigned points, for each batch slot
        pos = jnp.cumsum(mask_j.astype(jnp.int32)) - 1            # (b,)
        slot = (head_j + pos) % w
        slot = jnp.where(mask_j, slot, w)                          # w => drop
        evict_coef = coef_row.at[slot].get(mode="fill", fill_value=0.0)
        evict_idx = idx_row.at[slot].get(mode="fill", fill_value=0)
        newc = alpha_j / jnp.maximum(bj_j, 1.0)
        coef_row = coef_row.at[slot].set(newc, mode="drop")
        idx_row = idx_row.at[slot].set(batch_idx, mode="drop")
        head_new = (head_j + bj_j.astype(jnp.int32)) % w
        return idx_row, coef_row, head_new, evict_idx, evict_coef

    mask = onehot.T.astype(bool)                                   # (k, b)
    return jax.vmap(one_center)(idx, coef, head, alpha, bj, mask)


def _sqnorm_recompute(kernel, x, idx, coef, cdt=None):
    """Paper-faithful <C_j, C_j>: per-center W x W Gram quadratic form.
    Empty slots (coef 0) contribute nothing.

    Kernels advertising the ``gram_rows`` capability (cached kernels)
    resolve all k*W support rows in ONE lookup outside the vmap and gather
    the per-center W x W blocks inside it — a cached lookup placed under
    the per-center vmap would lower its ``lax.cond`` to ``select`` and run
    the miss branch (a full strip recompute) on every hit.

    ``cdt``: optional compute dtype for the Gram COORDINATES (the fused
    step's bf16 mode); coefficients and the quadratic form stay f32."""
    rows_fn = gram_rows_fn(kernel)
    if rows_fn is not None:
        k, w = idx.shape
        rows = rows_fn(kernel, x[idx.reshape(-1)])                 # (kW, n)
        rows_k = rows.reshape(k, w, rows.shape[-1])

        def one_cached(rows_j, idx_row, coef_row):
            g = rows_j[:, idx_row]                                 # (W, W)
            return coef_row @ (g.astype(jnp.float32) @ coef_row)

        return jax.vmap(one_cached)(rows_k, idx, coef)

    def one(idx_row, coef_row):
        pts = x[idx_row]                                           # (W, d)
        if cdt is not None:
            pts = pts.astype(cdt)
        g = kernel_cross(kernel, pts, pts)                         # (W, W)
        if cdt is not None:
            g = g.astype(jnp.float32)
        return coef_row @ (g @ coef_row)

    return jax.vmap(one)(idx, coef)


def _make_fused_step(kernel: KernelFn, cfg: MBConfig):
    """The `step="fused"` Algorithm-2 iteration: both batch x window
    passes (assignment and the post-update objective) run through the
    streaming fused kernels (:mod:`repro.kernels.fused_step`) — online
    argmin carries instead of a materialized (b, k*W) cross strip or
    (b, k) distance matrix.  The O(k b) bookkeeping (rates, ring append)
    and the O(k W^2) sqnorm recompute are shared verbatim with the
    composed step, so at f32 the trajectories are BIT-IDENTICAL
    (tests/test_api_grid.py pins this across the plan grid).

    ``compute_dtype='bfloat16'`` (SolverConfig ``precision="bf16"``)
    casts kernel-eval coordinates to bf16; contractions, argmin carries
    and all state stay f32."""
    from repro.kernels import ops as kops

    if cfg.sqnorm_mode != "recompute" or cfg.eval_mode != "direct":
        raise ValueError(
            "step='fused' streams both batch x window passes, which "
            "exist only under the paper-faithful sqnorm_mode='recompute'"
            " / eval_mode='direct' (the incremental/delta variants need "
            "the materialized per-center dots the fused step never "
            "forms); use step='composed'")
    from repro.core.kernel_fns import is_index_data

    rate_fn = get_rate(cfg.rate)
    b = cfg.batch_size
    # index-data kernels (Precomputed / cached): never cast — their data
    # rows are gather KEYS, and their kernel values are cache/Gram
    # gathers, so the streaming slab loop would also just multiply
    # lookups with zero memory win.  They take the composed passes below.
    prec = precision_plan(kernel, cfg)
    index_data, precision, cdt = prec.index_data, prec.tag, prec.cdt

    def step(state: CenterState, x: jax.Array, batch_idx: jax.Array):
        k, w = state.idx.shape
        xb = x[batch_idx]                                          # (b, d)
        diag_b = diag_of(kernel, xb)                              # (b,)

        # ---- (2) streaming assignment: online argmin over centers ---------
        if index_data:
            # cached/precomputed: ONE bulk row resolve (the composed
            # dots), then min/argmin — per-slab lookups would re-run the
            # cache's key scan k/kc times for values that are gathers
            p = _batch_center_dots(kernel, xb, x, state.idx, state.coef,
                                   cfg.use_pallas)
            dists = diag_b[:, None] - 2.0 * p + state.sqnorm[None, :]
            best = jnp.min(dists, axis=1)
            assign = jnp.argmin(dists, axis=1).astype(jnp.int32)
        else:
            best, assign = kops.streaming_assign(
                kernel, xb, x[state.idx.reshape(-1)], state.coef,
                state.sqnorm, diag_b, precision=precision)
        f_before = jnp.mean(best)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)      # (b, k)
        bj = jnp.sum(onehot, axis=0)                               # (k,)

        # ---- (3)/(4) rates + ring append: shared with the composed step ---
        alpha = rate_fn(bj, state.counts, b)                       # (k,)
        coef_scaled = state.coef * (1.0 - alpha)[:, None]
        new_idx, new_coef, new_head, _, _ = _append_to_windows(
            state.idx, coef_scaled, state.head, alpha, bj, onehot,
            batch_idx)

        # ---- (5) center squared norms (paper-faithful recompute) ----------
        # streamed center-chunked recompute: the (k, W, W) Gram stack is
        # the step's LARGEST buffer — streaming it is most of the fused
        # step's peak-memory win.  Index-data kernels keep the composed
        # bulk-lookup recompute: one row resolve beats k/kc chunked
        # resolves, and their Gram values are gathers anyway.
        if index_data:
            new_sqnorm = _sqnorm_recompute(kernel, x, new_idx, new_coef)
        else:
            from repro.kernels.fused_step import streamed_sqnorm
            new_sqnorm = streamed_sqnorm(kernel, x, new_idx, new_coef,
                                         compute_dtype=cdt)

        # ---- (6) streaming objective on the NEW centers -------------------
        if index_data:
            p_new = _batch_center_dots(kernel, xb, x, new_idx, new_coef,
                                       cfg.use_pallas)
            d_new = diag_b[:, None] - 2.0 * p_new + new_sqnorm[None, :]
            best2 = jnp.min(d_new, axis=1)
        else:
            best2 = kops.streaming_min(
                kernel, xb, x[new_idx.reshape(-1)], new_coef, new_sqnorm,
                diag_b, precision=precision)
        f_after = jnp.mean(best2)

        new_state = CenterState(
            idx=new_idx, coef=new_coef, head=new_head, sqnorm=new_sqnorm,
            counts=state.counts + bj, step=state.step + 1)
        info = StepInfo(f_before=f_before, f_after=f_after,
                        improvement=f_before - f_after,
                        batch_counts=bj, assignments=assign)
        return new_state, info

    return step


def _maybe_compress(step, kernel: KernelFn, cfg: MBConfig):
    """The loop core's single compress-axis registration site
    (:func:`repro.core.loop.compress_hook`), applied to a CenterState
    step.  ``compress=None`` (and ``every=0``) return ``step`` itself —
    the emitted program is the historical one, bit-for-bit."""
    return compress_hook(step, kernel, cfg)


def make_step(kernel: KernelFn, cfg: MBConfig):
    """Returns step(state, x, batch_idx) -> (state, StepInfo): one Algorithm-2
    iteration.  Pure; jit/shard_map-able; x passed explicitly (never a baked
    constant).  ``cfg.step`` selects the implementation: 'composed' (the
    historical op chain below) or 'fused' (:func:`_make_fused_step` —
    streaming passes, bit-identical at f32).  An active ``cfg.compress``
    spec lands on BOTH implementations here (:func:`_maybe_compress`), so
    every CenterState executor gets in-loop compression for free."""
    if cfg.step == "fused":
        return _maybe_compress(_make_fused_step(kernel, cfg), kernel, cfg)
    if cfg.step != "composed":
        raise ValueError(f"step={cfg.step!r} (expected 'composed' or "
                         "'fused')")
    rate_fn = get_rate(cfg.rate)
    b = cfg.batch_size
    # kernel-eval compute dtype (SolverConfig precision="bf16"): resolved
    # by the loop core's single precision-axis site — cast the COORDINATES
    # entering kernel evaluations, accumulate in f32.  float32 (the
    # default) is the identity: the emitted program is unchanged.
    prec = precision_plan(kernel, cfg)
    cdt, _c, _f32 = prec.cdt, prec.cast, prec.f32

    def step(state: CenterState, x: jax.Array, batch_idx: jax.Array):
        k, w = state.idx.shape
        xb = x[batch_idx]                                          # (b, d)
        diag_b = diag_of(kernel, xb)                              # (b,)

        # ---- (2) assignment against current truncated centers -------------
        p = _batch_center_dots(kernel, xb, x, state.idx, state.coef,
                               cfg.use_pallas, cdt=cdt)            # (b, k)
        dists = diag_b[:, None] - 2.0 * p + state.sqnorm[None, :]
        f_before = jnp.mean(jnp.min(dists, axis=1))
        assign = jnp.argmin(dists, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)      # (b, k)
        bj = jnp.sum(onehot, axis=0)                               # (k,)

        # ---- (3) learning rate --------------------------------------------
        alpha = rate_fn(bj, state.counts, b)                       # (k,)
        decay = 1.0 - alpha

        # ---- (4) decay + ring append --------------------------------------
        coef_scaled = state.coef * decay[:, None]
        new_idx, new_coef, new_head, evict_idx, evict_coef = _append_to_windows(
            state.idx, coef_scaled, state.head, alpha, bj, onehot, batch_idx)

        # ---- (5) center squared norms --------------------------------------
        onehot_n = onehot / jnp.maximum(bj, 1.0)[None, :]          # (b, k)
        if cfg.sqnorm_mode == "recompute":
            new_sqnorm = _sqnorm_recompute(kernel, x, new_idx, new_coef,
                                           cdt=cdt)
            kbb = None
        elif cfg.sqnorm_mode == "incremental":
            # <C', C'> for the *untruncated* update, then subtract the
            # evicted component D:  <C-D, C-D> = <C,C> - 2<C-D, D> - <D,D>.
            kbb = _f32(kernel_cross(kernel, _c(xb), _c(xb)))       # (b, b)
            cm_cross = jnp.sum(onehot * p, axis=0) / jnp.maximum(bj, 1.0)
            cm_sq = jnp.sum(onehot_n * (kbb @ onehot_n), axis=0)   # (k,)
            sq_untrunc = (decay ** 2 * state.sqnorm
                          + 2.0 * decay * alpha * cm_cross
                          + alpha ** 2 * cm_sq)

            def corr(evict_i, evict_c, idx_row, coef_row):
                kd_w = _f32(kernel_cross(kernel, _c(x[evict_i]),
                                         _c(x[idx_row])))            # (b, W)
                c_d_new = evict_c @ (kd_w @ coef_row)     # <D, C_trunc>
                kdd = _f32(kernel_cross(kernel, _c(x[evict_i]),
                                        _c(x[evict_i])))
                dd = evict_c @ (kdd @ evict_c)            # <D, D>
                return 2.0 * c_d_new + dd

            new_sqnorm = sq_untrunc - jax.vmap(corr)(
                evict_idx, evict_coef, new_idx, new_coef)
        else:
            raise ValueError(cfg.sqnorm_mode)

        # ---- (6) batch objective on the NEW centers (early stopping) ------
        if cfg.eval_mode == "direct":
            p_new = _batch_center_dots(kernel, xb, x, new_idx, new_coef,
                                       cfg.use_pallas, cdt=cdt)
        elif cfg.eval_mode == "delta":
            # <phi(x), C'_j> = decay_j P[x,j] + alpha_j <phi(x), cm(B_j)>
            #                  - <phi(x), D_j>           — O(k b^2), no kW pass
            if kbb is None:
                kbb = _f32(kernel_cross(kernel, _c(xb), _c(xb)))
            cm_dot = kbb @ onehot_n                                # (b, k)

            def drop_dot(evict_i, evict_c):
                return _f32(kernel_cross(kernel, _c(xb),
                                         _c(x[evict_i]))) @ evict_c  # (b,)

            d_dot = jax.vmap(drop_dot)(evict_idx, evict_coef).T    # (b, k)
            p_new = decay[None, :] * p + alpha[None, :] * cm_dot - d_dot
        else:
            raise ValueError(cfg.eval_mode)

        d_new = diag_b[:, None] - 2.0 * p_new + new_sqnorm[None, :]
        f_after = jnp.mean(jnp.min(d_new, axis=1))

        new_state = CenterState(
            idx=new_idx, coef=new_coef, head=new_head, sqnorm=new_sqnorm,
            counts=state.counts + bj, step=state.step + 1)
        info = StepInfo(f_before=f_before, f_after=f_after,
                        improvement=f_before - f_after,
                        batch_counts=bj, assignments=assign)
        return new_state, info

    return _maybe_compress(step, kernel, cfg)


def batch_objective(kernel: KernelFn, state: CenterState, x: jax.Array,
                    batch_idx: jax.Array,
                    use_pallas: bool = False) -> jax.Array:
    """f_B(C) = mean_j min_j d(x, C_j) on an explicit batch — the quantity
    Algorithm 2 early-stops on, exposed standalone so the multi-restart
    engine can score every restart's centers on one SHARED eval batch
    (fair on-device model selection, no host sync).  vmap-safe over state."""
    xb = x[batch_idx]
    diag_b = diag_of(kernel, xb)
    p = _batch_center_dots(kernel, xb, x, state.idx, state.coef, use_pallas)
    dists = diag_b[:, None] - 2.0 * p + state.sqnorm[None, :]
    return jnp.mean(jnp.min(dists, axis=1))


def batch_objective_from_rows(gram_rows: jax.Array, diag_b: jax.Array,
                              state: CenterState) -> jax.Array:
    """``batch_objective`` from precomputed Gram rows K(x_B, x) (eb, n):
    the cross-kernel block against each center's support window becomes a
    column gather, so R restarts scored on one shared eval batch pay the
    eb x n kernel evaluations ONCE instead of R times (engine.py).
    vmap-safe over state."""
    k, w = state.idx.shape
    cross = gram_rows[:, state.idx.reshape(-1)]            # (eb, k*W)
    p = jnp.einsum("bkw,kw->bk", cross.reshape(gram_rows.shape[0], k, w),
                   state.coef)
    dists = diag_b[:, None] - 2.0 * p + state.sqnorm[None, :]
    return jnp.mean(jnp.min(dists, axis=1))


def sample_batch(key: jax.Array, n: int, b: int) -> jax.Array:
    """Uniform with replacement (paper's sampling model)."""
    return jax.random.randint(key, (b,), 0, n, dtype=jnp.int32)


def sample_batch_weighted(key: jax.Array, probs: jax.Array,
                          b: int) -> jax.Array:
    """Weighted case (paper footnote 1): sampling x with probability
    proportional to w_x makes the plain batch mean an unbiased estimator of
    the weighted objective and the plain cm(B_j) the weighted center update
    — Algorithm 2 itself is unchanged."""
    return jax.random.choice(key, probs.shape[0], (b,), p=probs) \
        .astype(jnp.int32)


def sample_batch_nested(key: jax.Array, step, n: int, b: int,
                        reuse: float = 0.5,
                        refresh: int = 8) -> jax.Array:
    """Nested batch sampling (Newling & Fleuret 2016 style reuse): the
    first ``reuse * b`` positions form a slowly-refreshing prefix — position
    ``i`` keeps its row for ``refresh`` steps (staggered, so ~m/refresh
    rows turn over per step) — and the tail is drawn fresh each step.

    Consecutive batches therefore share most of their rows, which is what
    keeps the Gram tile cache's hit rate high during fit.  Marginally each
    position is still uniform over [0, n).  Pure function of ``(key, step)``
    like :func:`sample_batch` — deterministic resume needs no sampler
    state."""
    m = int(b * reuse)
    step = jnp.asarray(step, jnp.int32)
    if m > 0:
        i = jnp.arange(m, dtype=jnp.int32)
        epoch = (step + i) // refresh

        def draw(ii, ee):
            kk = jax.random.fold_in(jax.random.fold_in(key, ii), ee)
            return jax.random.randint(kk, (), 0, n, dtype=jnp.int32)

        head = jax.vmap(draw)(i, epoch)
    else:
        head = jnp.zeros((0,), jnp.int32)
    kt = jax.random.fold_in(jax.random.fold_in(key, step), 0x7A11)
    tail = jax.random.randint(kt, (b - m,), 0, n, dtype=jnp.int32)
    return jnp.concatenate([head, tail])


def host_fit_loop(step, n: int, cfg: MBConfig, state, key: jax.Array,
                  probs: Optional[jax.Array] = None,
                  early_stop: bool = True, sampler: str = "iid",
                  reuse: float = 0.5, refresh: int = 8, step0: int = 0,
                  prefetch: bool = False):
    """The host-driven early-stopped driver shared by every non-jit fit
    path (plain / weighted / cached): per iteration draw the batch indices
    from the unified key stream (:mod:`repro.api.keys`), apply
    ``step(state, batch_idx) -> (state, StepInfo)``, and stop when the
    improvement drops below epsilon.

    ``sampler='iid'`` advances the stream (``next_batch_key``) each step;
    ``'nested'`` batches are pure functions of ``(key, step)`` and leave
    the stream untouched.  ``step0`` offsets the iteration counter so
    ``partial_fit`` resumption continues both the nested schedule and the
    history numbering.  Returns ``(state, history, key)`` — the carried key
    resumes the stream exactly (``KernelKMeans.partial_fit``).

    ``prefetch``: one-deep pipeline — draw (and ``device_put``) iteration
    i+1's batch indices after DISPATCHING step i but before blocking on
    its improvement, so sampling/transfer overlaps the device step.  The
    drawn values, the visited key stream and the returned carry key are
    identical to the blocking path (an early stop discards the prefetched
    draw without consuming its key advance) — results are bit-identical
    either way (tested).

    This is a thin lowering over the shared host driver
    (:func:`repro.core.loop.drive_fit_loop`): it supplies only the
    key-stream batch producer and the step dispatch; the loop skeleton
    (iteration/early-stop/prefetch/history) lives in the loop core."""
    if sampler not in ("iid", "nested"):
        raise ValueError(sampler)
    if sampler == "nested" and probs is not None:
        raise NotImplementedError("the nested sampler draws unweighted "
                                  "batches; sample weights need "
                                  "sampler='iid'")

    def draw(key, i):
        """-> (key', bidx): one batch draw at cursor i.  'nested' draws
        are pure functions of (key, i) and leave the stream untouched."""
        if sampler == "iid":
            key, kb = api_keys.next_batch_key(key)
            return key, (sample_batch(kb, n, cfg.batch_size)
                         if probs is None
                         else sample_batch_weighted(kb, probs,
                                                    cfg.batch_size))
        return key, sample_batch_nested(key, i, n, cfg.batch_size,
                                        reuse=reuse, refresh=refresh)

    def dispatch(bidx):
        nonlocal state
        state, info = step(state, bidx)
        return info

    history, key = drive_fit_loop(
        dispatch, draw, key, max_iters=cfg.max_iters, epsilon=cfg.epsilon,
        early_stop=early_stop, prefetch=prefetch, step0=step0)
    return state, history, key


def fit(x: jax.Array, kernel: KernelFn, cfg: MBConfig, key: jax.Array,
        init: str = "kmeans++", early_stop: bool = True,
        init_idx: Optional[jax.Array] = None,
        weights: Optional[jax.Array] = None):
    """Host-driven fit loop with the paper's early-stopping condition.

    .. deprecated::
        Use :class:`repro.api.KernelKMeans` with
        ``SolverConfig(cache="none", distribution="single", jit=False)`` —
        this shim resolves exactly that plan and delegates to it.

    ``weights``: optional (n,) positive point weights (footnote 1) —
    implemented as weighted batch sampling, see sample_batch_weighted.
    Returns (state, history) where history is a list of per-step StepInfo
    (as numpy scalars) — benchmarks consume it directly.
    """
    from repro.api import legacy as _legacy
    _legacy.warn_legacy(
        "repro.core.fit",
        "KernelKMeans(SolverConfig(cache='none', distribution='single', "
        "jit=False))")
    return _legacy.fit(x, kernel, cfg, key, init=init,
                       early_stop=early_stop, init_idx=init_idx,
                       weights=weights)


def fit_cached(x: jax.Array, kernel: KernelFn, cfg: MBConfig, key: jax.Array,
               tile: int = 256, capacity: int = 16,
               init: str = "kmeans++", early_stop: bool = True,
               init_idx: Optional[jax.Array] = None,
               sampler: str = "uniform", reuse: float = 0.5,
               refresh: int = 8, store_dtype=jnp.float32):
    """Cache-accelerated host-driven fit (the Gram-tile-cache fit path).

    .. deprecated::
        Use :class:`repro.api.KernelKMeans` with
        ``SolverConfig(cache="lru", sampler="iid"|"nested")`` — this shim
        resolves exactly that plan and delegates to it.

    Per iteration: warm the tile cache with the batch + window rows (only
    MISSING row blocks evaluate the kernel; the nested sampler keeps that
    set small), then run the unchanged Algorithm-2 step on the index-data
    view — every ``kernel_cross`` inside it is served from resident tiles.

    ``sampler='uniform'`` draws the exact batch sequence of :func:`fit`
    (same key handling), so cached and uncached fits are numerically
    equivalent; ``sampler='nested'`` uses :func:`sample_batch_nested` for
    higher hit rates.  Returns ``(state, history, ck)`` — the returned
    :class:`repro.cache.CachedKernel` carries the warm tiles plus measured
    hit/miss/eviction counters, and serves ``predict`` /
    ``predict_cached`` directly.
    """
    from repro.api import legacy as _legacy
    _legacy.warn_legacy(
        "repro.core.fit_cached",
        "KernelKMeans(SolverConfig(cache='lru'))")
    return _legacy.fit_cached(x, kernel, cfg, key, tile=tile,
                              capacity=capacity, init=init,
                              early_stop=early_stop, init_idx=init_idx,
                              sampler=sampler, reuse=reuse, refresh=refresh,
                              store_dtype=store_dtype)


# run_early_stopped_keyed / run_early_stopped — the paper's on-device
# early-stopped driver — moved to repro.core.loop (re-exported above): the
# lax.while_loop skeleton now exists exactly once, in the loop core.


def sampled_step_with_key(step, x: jax.Array, cfg: MBConfig):
    """Adapt make_step's (state, x, batch_idx) signature to the
    run_early_stopped protocol with the canonical uniform batch draw."""
    n = x.shape[0]

    def step_with_key(state, kb):
        state, info = step(state, x, sample_batch(kb, n, cfg.batch_size))
        return state, info.improvement

    return step_with_key


def fit_jit(x: jax.Array, kernel: KernelFn, cfg: MBConfig, key: jax.Array,
            init_idx: jax.Array):
    """Fully-on-device fit: lax.while_loop with the stopping condition in the
    loop — no per-step host sync (the production/TPU path).

    .. deprecated::
        Use :class:`repro.api.KernelKMeans` with ``SolverConfig(jit=True)``
        — this shim resolves exactly that plan and delegates to it (the
        estimator additionally caches the compiled program across fits).
    """
    from repro.api import legacy as _legacy
    _legacy.warn_legacy(
        "repro.core.fit_jit",
        "KernelKMeans(SolverConfig(cache='none', distribution='single', "
        "jit=True))")
    return _legacy.fit_jit(x, kernel, cfg, key, init_idx)


def assign_chunked(kernel: KernelFn, coef: jax.Array, sqnorm: jax.Array,
                   sup: jax.Array, xq: jax.Array, chunk: int) -> jax.Array:
    """Chunked nearest-center assignment against explicit (k*W, d) support
    points — the single serving kernel, shared by ``predict`` and the
    sharded ``distributed.predict_distributed`` body so their numerics can
    never diverge.

    Support-side invariants (the (k*W,) support squared norms of the
    Gaussian) are hoisted OUT of the chunk scan via
    :func:`repro.core.kernel_fns.cross_fixed_y` — they are fixed across
    every chunk, and recomputing them per chunk cost O(kWd) per chunk for
    nothing; the query side already uses the :func:`diag_of`
    normalized-kernel fast path.  Hoisting reuses the same ops on the same
    data, so labels are unchanged bit-for-bit."""
    from repro.core.kernel_fns import cross_fixed_y

    k, w = coef.shape
    cross_fn = cross_fixed_y(kernel, sup)     # sup stats computed ONCE

    def one_chunk(xc):
        cross = cross_fn(xc).reshape(xc.shape[0], k, w)
        p = jnp.einsum("bkw,kw->bk", cross, coef)
        d = diag_of(kernel, xc)[:, None] - 2.0 * p + sqnorm[None, :]
        return jnp.argmin(d, axis=1).astype(jnp.int32)

    nq = xq.shape[0]
    pad = (-nq) % chunk
    xp = jnp.pad(xq, ((0, pad),) + ((0, 0),) * (xq.ndim - 1))
    out = jax.lax.map(one_chunk, xp.reshape(-1, chunk, *xq.shape[1:]))
    return out.reshape(-1)[:nq]


def center_distances_chunked(kernel: KernelFn, coef: jax.Array,
                             sqnorm: jax.Array, sup: jax.Array,
                             xq: jax.Array, chunk: int) -> jax.Array:
    """Chunked feature-space distances d(x, C_j) against explicit (k*W, d)
    support points, (nq, k) — the ``KernelKMeans.transform`` / ``score``
    kernel.  Same distance expression as :func:`assign_chunked` (which only
    keeps the argmin), with the same support-invariant hoist."""
    from repro.core.kernel_fns import cross_fixed_y

    k, w = coef.shape
    cross_fn = cross_fixed_y(kernel, sup)     # sup stats computed ONCE

    def one_chunk(xc):
        cross = cross_fn(xc).reshape(xc.shape[0], k, w)
        p = jnp.einsum("bkw,kw->bk", cross, coef)
        return diag_of(kernel, xc)[:, None] - 2.0 * p + sqnorm[None, :]

    nq = xq.shape[0]
    pad = (-nq) % chunk
    xp = jnp.pad(xq, ((0, pad),) + ((0, 0),) * (xq.ndim - 1))
    out = jax.lax.map(one_chunk, xp.reshape(-1, chunk, *xq.shape[1:]))
    return out.reshape(-1, k)[:nq]


@functools.partial(jax.jit, static_argnames=("chunk",))
def predict(state: CenterState, x: jax.Array, xq: jax.Array,
            kernel: KernelFn, chunk: int = 4096) -> jax.Array:
    """Assign arbitrary points to the fitted (truncated) centers."""
    sup = x[state.idx.reshape(-1)]
    return assign_chunked(kernel, state.coef, state.sqnorm, sup, xq, chunk)
