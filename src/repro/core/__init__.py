"""repro.core — the paper's contribution: mini-batch kernel k-means.

NOTE: the preferred front door is now ``repro.api.KernelKMeans`` +
``SolverConfig`` (one estimator, registry-driven solver plans — see
docs/api.md).  The ``fit_*`` entry points below remain as thin
deprecation shims that delegate to the equivalent plan.

Public API:
    MBConfig, fit, fit_jit, predict          — Algorithm 2 (truncated)
    MultiRestartEngine, fit_restarts         — best-of-R engine (engine.py)
    distributed.{make_dist_step, fit_distributed_jit, predict_distributed}
                                             — shard_map multi-device path
    untruncated.fit                          — Algorithm 1 (DP)
    fullbatch.fit                            — full-batch baseline
    kernel_fns.{Gaussian,Laplacian,...}      — kernel functions
    kernel_fns.{make_kernel, list_kernels}   — name registry ("rbf", ...)
    init.kmeans_plus_plus                    — kernel k-means++
    metrics.{adjusted_rand_index, normalized_mutual_info}
"""
from repro.core.kernel_fns import (  # noqa: F401
    Gaussian, Laplacian, Linear, Polynomial, Precomputed, diag_is_one,
    gamma_of, kernel_cross, kernel_diag, kernel_spec, list_kernels,
    make_kernel, median_sq_dist_heuristic, register_kernel,
    register_kernel_factory,
)
from repro.core.minibatch import (  # noqa: F401
    MBConfig, StepInfo, batch_objective, center_distances_chunked, fit,
    fit_cached, fit_jit, host_fit_loop, make_step, predict, sample_batch,
    sample_batch_nested,
)
from repro.core.engine import (  # noqa: F401
    EngineResult, MultiRestartEngine, fit_restarts,
)
from repro.core.state import CenterState, init_state, window_size  # noqa: F401
from repro.core.metrics import (  # noqa: F401
    adjusted_rand_index, normalized_mutual_info,
)
