"""Kernel functions K(x, y) = <phi(x), phi(y)> as jit-friendly pytrees.

Every kernel is a NamedTuple (automatically a pytree) dispatched through
``kernel_cross`` / ``kernel_diag``.  Data is always an ``(n, d)`` float array;
for :class:`Precomputed` kernels (k-nn / heat graphs from the paper's
Appendix C) the "data" is an ``(n, 1)`` array of row indices into the
precomputed Gram matrix, which keeps every algorithm in :mod:`repro.core`
agnostic to the kernel type.
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp


class Gaussian(NamedTuple):
    """K(x, y) = exp(-||x - y||^2 / kappa).  Normalized: gamma = 1."""

    kappa: jax.Array  # scalar


class Laplacian(NamedTuple):
    """K(x, y) = exp(-||x - y||_1 / kappa).  Normalized: gamma = 1."""

    kappa: jax.Array  # scalar


class Polynomial(NamedTuple):
    """K(x, y) = (x . y / scale + bias)^degree  (degree static-ish, pass int)."""

    bias: jax.Array
    scale: jax.Array
    degree: int  # static


class Linear(NamedTuple):
    """K(x, y) = x . y  (plain k-means in disguise when used everywhere)."""


class Precomputed(NamedTuple):
    """Explicit Gram matrix (e.g. k-nn kernel D^-1 A D^-1, heat kernel).

    Data rows are (float) indices into ``gram``.
    """

    gram: jax.Array  # (n, n)


KernelFn = Union[Gaussian, Laplacian, Polynomial, Linear, Precomputed]


def _sq_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pairwise squared Euclidean distances, (m, d) x (n, d) -> (m, n).

    Uses the |x|^2 + |y|^2 - 2 x.y expansion so the inner term is a single
    MXU matmul.  Clamped at zero against round-off.
    """
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def kernel_cross(k: KernelFn, x: jax.Array, y: jax.Array) -> jax.Array:
    """Full cross-kernel matrix K(x_i, y_j), shape (m, n)."""
    if isinstance(k, Gaussian):
        return jnp.exp(-_sq_dists(x, y) / k.kappa)
    if isinstance(k, Laplacian):
        l1 = jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
        return jnp.exp(-l1 / k.kappa)
    if isinstance(k, Polynomial):
        return (x @ y.T / k.scale + k.bias) ** k.degree
    if isinstance(k, Linear):
        return x @ y.T
    if isinstance(k, Precomputed):
        xi = x[:, 0].astype(jnp.int32)
        yi = y[:, 0].astype(jnp.int32)
        return k.gram[xi][:, yi]
    raise TypeError(f"unknown kernel {type(k)}")


def kernel_diag(k: KernelFn, x: jax.Array) -> jax.Array:
    """K(x_i, x_i), shape (m,).  O(m) — never forms the cross matrix."""
    if isinstance(k, (Gaussian, Laplacian)):
        return jnp.ones(x.shape[0], x.dtype)
    if isinstance(k, Polynomial):
        return (jnp.sum(x * x, axis=-1) / k.scale + k.bias) ** k.degree
    if isinstance(k, Linear):
        return jnp.sum(x * x, axis=-1)
    if isinstance(k, Precomputed):
        xi = x[:, 0].astype(jnp.int32)
        return k.gram[xi, xi]
    raise TypeError(f"unknown kernel {type(k)}")


def gamma_of(k: KernelFn, x: jax.Array) -> jax.Array:
    """gamma = max_x ||phi(x)|| = sqrt(max_x K(x, x)) — Theorem 1's parameter."""
    return jnp.sqrt(jnp.max(kernel_diag(k, x)))


def median_sq_dist_heuristic(x: jax.Array, sample: int = 1024) -> jax.Array:
    """kappa heuristic of Wang et al. (2019): median pairwise squared distance
    over a subsample.  Used to set the Gaussian bandwidth."""
    s = x[: min(sample, x.shape[0])]
    d2 = _sq_dists(s, s)
    # exclude the zero diagonal from the median
    m = d2 + jnp.diag(jnp.full(s.shape[0], jnp.nan, d2.dtype))
    return jnp.nanmedian(m)
