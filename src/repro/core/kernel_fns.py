"""Kernel functions K(x, y) = <phi(x), phi(y)> as jit-friendly pytrees.

Every kernel is a NamedTuple (automatically a pytree) dispatched through
``kernel_cross`` / ``kernel_diag``.  Data is always an ``(n, d)`` float array;
for :class:`Precomputed` kernels (k-nn / heat graphs from the paper's
Appendix C) the "data" is an ``(n, 1)`` array of row indices into the
precomputed Gram matrix, which keeps every algorithm in :mod:`repro.core`
agnostic to the kernel type.
"""
from __future__ import annotations

from typing import NamedTuple, Union

import jax
import jax.numpy as jnp


class Gaussian(NamedTuple):
    """K(x, y) = exp(-||x - y||^2 / kappa).  Normalized: gamma = 1."""

    kappa: jax.Array  # scalar


class Laplacian(NamedTuple):
    """K(x, y) = exp(-||x - y||_1 / kappa).  Normalized: gamma = 1."""

    kappa: jax.Array  # scalar


class Polynomial(NamedTuple):
    """K(x, y) = (x . y / scale + bias)^degree  (degree static-ish, pass int)."""

    bias: jax.Array
    scale: jax.Array
    degree: int  # static


class Linear(NamedTuple):
    """K(x, y) = x . y  (plain k-means in disguise when used everywhere)."""


class Precomputed(NamedTuple):
    """Explicit Gram matrix (e.g. k-nn kernel D^-1 A D^-1, heat kernel).

    Data rows are (float) indices into ``gram``.
    """

    gram: jax.Array  # (n, n)


KernelFn = Union[Gaussian, Laplacian, Polynomial, Linear, Precomputed]

# Extension registry: packages outside core (e.g. repro.cache's CachedKernel)
# register new kernel pytree types here so ``kernel_cross`` / ``kernel_diag``
# dispatch to them — call sites throughout repro.core stay unchanged.
_EXT_CROSS: dict = {}
_EXT_DIAG: dict = {}
_EXT_DIAG_ONE: dict = {}
_EXT_ROWS: dict = {}

# Name registry: ``SolverConfig(kernel="rbf")`` strings resolve to kernel
# instances through these factories (repro.api's single front door).
_KERNEL_FACTORIES: dict = {}


def register_kernel_factory(name: str, factory, *,
                            overwrite: bool = False) -> None:
    """Register a kernel *name* -> factory, so config strings like
    ``SolverConfig(kernel="rbf")`` resolve through :func:`make_kernel`.
    Duplicate names are an error (two packages silently fighting over
    "rbf" would flip numerics under users' feet) unless ``overwrite``."""
    key = name.lower()
    if key in _KERNEL_FACTORIES and not overwrite:
        raise ValueError(
            f"kernel name {name!r} is already registered "
            f"(registered names: {', '.join(list_kernels())}); pick a "
            "distinct name or pass overwrite=True to replace it")
    _KERNEL_FACTORIES[key] = factory


def list_kernels() -> list:
    """Sorted names accepted by :func:`make_kernel` / ``SolverConfig.kernel``."""
    return sorted(_KERNEL_FACTORIES)


def make_kernel(spec, **params):
    """Resolve a kernel spec: a string name goes through the factory
    registry (with ``params`` forwarded); a kernel pytree passes through
    unchanged (``params`` must then be empty)."""
    if not isinstance(spec, str):
        if params:
            raise ValueError("kernel_params given with an already-built "
                             f"kernel instance ({type(spec).__name__})")
        return spec
    try:
        factory = _KERNEL_FACTORIES[spec.lower()]
    except KeyError:
        raise ValueError(f"unknown kernel {spec!r}; registered kernels: "
                         f"{list_kernels()}") from None
    return factory(**params)


def kernel_spec(k: "KernelFn"):
    """``(name, params)`` round-trippable through :func:`make_kernel` — the
    serialization hook ``KernelKMeans.save`` uses.  Only coordinate kernels
    with scalar params serialize; data-carrying kernels (Precomputed,
    CachedKernel) raise."""
    if isinstance(k, Gaussian):
        return "rbf", {"kappa": float(k.kappa)}
    if isinstance(k, Laplacian):
        return "laplacian", {"kappa": float(k.kappa)}
    if isinstance(k, Polynomial):
        return "polynomial", {"bias": float(k.bias), "scale": float(k.scale),
                              "degree": int(k.degree)}
    if isinstance(k, Linear):
        return "linear", {}
    raise ValueError(f"kernel {type(k).__name__} has no serializable spec "
                     "(data-carrying kernels cannot be saved by name)")


def register_kernel(cls, *, cross, diag, diag_one=None,
                    gram_rows=None, name=None, factory=None,
                    overwrite: bool = False) -> None:
    """Register an out-of-module kernel type.

    ``cross(k, x, y) -> (m, n)`` and ``diag(k, x) -> (m,)`` implement the
    :func:`kernel_cross` / :func:`kernel_diag` contract; ``diag_one(k) ->
    bool`` (optional, static) advertises K(x, x) == 1 for the normalized
    fast path (:func:`diag_is_one`); ``gram_rows(k, x) -> (m, n)``
    (optional) advertises cheap FULL Gram rows K(x_i, .) — the capability
    hook the hot paths use to restructure per-center loops into one
    row-resolve plus pure gathers (see :func:`gram_rows_fn`).  Keeping the
    capability in this registry means repro.core never names extension
    kernel types.

    ``name`` (optional) additionally registers the type under a config
    string (see :func:`register_kernel_factory`); ``factory`` defaults to
    the class itself."""
    _EXT_CROSS[cls] = cross
    _EXT_DIAG[cls] = diag
    if diag_one is not None:
        _EXT_DIAG_ONE[cls] = diag_one
    if gram_rows is not None:
        _EXT_ROWS[cls] = gram_rows
    if name is not None:
        register_kernel_factory(name, factory if factory is not None
                                else cls, overwrite=overwrite)


def gram_rows_fn(k: "KernelFn"):
    """The registered ``gram_rows(k, x) -> (m, n)`` capability, or None.

    Callers that would otherwise evaluate cross-kernels inside ``vmap``
    (where a cached kernel's ``lax.cond`` lowers to ``select`` and the miss
    branch runs on every hit) should resolve rows ONCE through this hook
    outside the vmap and gather columns inside it."""
    return _EXT_ROWS.get(type(k))


def _sq_dists(x: jax.Array, y: jax.Array, yy=None) -> jax.Array:
    """Pairwise squared Euclidean distances, (m, d) x (n, d) -> (m, n).

    Uses the |x|^2 + |y|^2 - 2 x.y expansion so the inner term is a single
    MXU matmul.  Clamped at zero against round-off.  ``yy``: optionally
    precomputed ``sum(y*y)[None, :]`` — :func:`cross_fixed_y` hoists it
    out of chunk scans; same ops on the same data, so results are
    bit-identical to passing None.
    """
    xx = jnp.sum(x * x, axis=-1)[:, None]
    if yy is None:
        yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def kernel_cross(k: KernelFn, x: jax.Array, y: jax.Array) -> jax.Array:
    """Full cross-kernel matrix K(x_i, y_j), shape (m, n)."""
    if isinstance(k, Gaussian):
        return jnp.exp(-_sq_dists(x, y) / k.kappa)
    if isinstance(k, Laplacian):
        l1 = jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)
        return jnp.exp(-l1 / k.kappa)
    if isinstance(k, Polynomial):
        return (x @ y.T / k.scale + k.bias) ** k.degree
    if isinstance(k, Linear):
        return x @ y.T
    if isinstance(k, Precomputed):
        xi = x[:, 0].astype(jnp.int32)
        yi = y[:, 0].astype(jnp.int32)
        return k.gram[xi][:, yi]
    if type(k) in _EXT_CROSS:
        return _EXT_CROSS[type(k)](k, x, y)
    raise TypeError(f"unknown kernel {type(k)}")


def kernel_diag(k: KernelFn, x: jax.Array) -> jax.Array:
    """K(x_i, x_i), shape (m,).  O(m) — never forms the cross matrix."""
    if isinstance(k, (Gaussian, Laplacian)):
        return jnp.ones(x.shape[0], x.dtype)
    if isinstance(k, Polynomial):
        return (jnp.sum(x * x, axis=-1) / k.scale + k.bias) ** k.degree
    if isinstance(k, Linear):
        return jnp.sum(x * x, axis=-1)
    if isinstance(k, Precomputed):
        xi = x[:, 0].astype(jnp.int32)
        return k.gram[xi, xi]
    if type(k) in _EXT_DIAG:
        return _EXT_DIAG[type(k)](k, x)
    raise TypeError(f"unknown kernel {type(k)}")


def diag_is_one(k: KernelFn) -> bool:
    """Static: does this kernel advertise K(x, x) == 1 for all x?

    True for the normalized kernels (Gaussian / Laplacian: gamma = 1, the
    paper's Table 1 setting).  Distance evaluations use it to substitute a
    constant for the :func:`kernel_diag` pass — for cached / precomputed
    kernels that skips a per-point Gram gather entirely."""
    if isinstance(k, (Gaussian, Laplacian)):
        return True
    fn = _EXT_DIAG_ONE.get(type(k))
    return bool(fn(k)) if fn is not None else False


def diag_of(k: KernelFn, x: jax.Array) -> jax.Array:
    """:func:`kernel_diag` with the normalized-kernel fast path: kernels
    advertising ``diag == 1`` get a constant instead of a per-point pass —
    for cached / precomputed kernels that skips a Gram gather entirely.
    The single implementation shared by fit, serving and the engine."""
    if diag_is_one(k):
        return jnp.ones(x.shape[0], x.dtype)
    return kernel_diag(k, x)


def is_index_data(k: KernelFn) -> bool:
    """Static: does this kernel consume (n, 1) row-INDEX data instead of
    coordinates?  True for :class:`Precomputed` and for extension kernels
    advertising the ``gram_rows`` capability (the cached kernels) — their
    data rows are gather keys, so precision casts must never touch them
    (``repro.kernels.fused_step`` gates its bf16 coordinate cast on
    this)."""
    return isinstance(k, Precomputed) or type(k) in _EXT_ROWS


def cross_fixed_y(k: KernelFn, y: jax.Array):
    """``cross(x) == kernel_cross(k, x, y)`` with the y-side invariants
    hoisted: the chunked serving scans (``minibatch.assign_chunked`` /
    ``center_distances_chunked``) evaluate many query chunks against ONE
    fixed support set, so recomputing the support squared norms inside
    every chunk is pure waste.  For kernels with no y-side statistic this
    is a plain closure over ``kernel_cross``.  The hoisted values are the
    same ops on the same data, so results are bit-identical to the
    unhoisted path."""
    if isinstance(k, Gaussian):
        yy = jnp.sum(y * y, axis=-1)[None, :]
        return lambda x: jnp.exp(-_sq_dists(x, y, yy=yy) / k.kappa)
    return lambda x: kernel_cross(k, x, y)


def gamma_of(k: KernelFn, x: jax.Array) -> jax.Array:
    """gamma = max_x ||phi(x)|| = sqrt(max_x K(x, x)) — Theorem 1's parameter."""
    return jnp.sqrt(jnp.max(kernel_diag(k, x)))


# Built-in kernels under their config names ("rbf" is the sklearn-style
# alias for the paper's normalized Gaussian).
register_kernel_factory("rbf", lambda kappa=1.0: Gaussian(
    kappa=jnp.float32(kappa)))
register_kernel_factory("gaussian", lambda kappa=1.0: Gaussian(
    kappa=jnp.float32(kappa)))
register_kernel_factory("laplacian", lambda kappa=1.0: Laplacian(
    kappa=jnp.float32(kappa)))
register_kernel_factory("polynomial", lambda bias=1.0, scale=1.0, degree=3:
                        Polynomial(bias=jnp.float32(bias),
                                   scale=jnp.float32(scale),
                                   degree=int(degree)))
register_kernel_factory("linear", lambda: Linear())
register_kernel_factory("precomputed", lambda gram: Precomputed(
    gram=jnp.asarray(gram)))


def median_sq_dist_heuristic(x: jax.Array, sample: int = 1024) -> jax.Array:
    """kappa heuristic of Wang et al. (2019): median pairwise squared distance
    over a subsample.  Used to set the Gaussian bandwidth."""
    s = x[: min(sample, x.shape[0])]
    d2 = _sq_dists(s, s)
    # exclude the zero diagonal from the median
    m = d2 + jnp.diag(jnp.full(s.shape[0], jnp.nan, d2.dtype))
    return jnp.nanmedian(m)
