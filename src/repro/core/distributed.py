"""Multi-chip mini-batch kernel k-means (shard_map).

Sharding layout (see DESIGN.md §4):

* **Centers are sharded over the 'model' axis** — each device owns k/m whole
  centers, so the window ring, eviction bookkeeping, <C,C> maintenance and
  the learning-rate state are all device-LOCAL.  (Index-free: the window
  stores point *coordinates*, so no cross-shard dataset gathers ever occur —
  this also lets activations stream in from a co-resident LM, see
  ``cluster_hidden_states``.)
* **The batch is sharded over ('pod', 'data')** — assignment distances are
  computed on local batch rows against local centers.

Collectives per iteration (the roofline collective term):
  1. all_gather over 'model'  of P_partial (b_loc, k_loc)  -> (b_loc, k)
  2. all_gather over ('pod','data') of the batch (b, d) + assignments (b,)
     [needed so center owners can append their assigned points]
  3. psum of (k,)/scalar reductions.

The step is paper-faithful (Algorithm 2 semantics identical to
repro.core.minibatch); tests assert bit-comparable trajectories against the
single-device implementation on a CPU mesh.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.kernel_fns import KernelFn, kernel_cross, kernel_diag
from repro.core.minibatch import MBConfig
from repro.core.rates import get_rate


class DistState(NamedTuple):
    """All leading-k arrays are sharded over 'model'."""

    pts: jax.Array      # (k, W, d) window point coordinates
    coef: jax.Array     # (k, W)
    head: jax.Array     # (k,)
    sqnorm: jax.Array   # (k,)
    counts: jax.Array   # (k,)
    step: jax.Array     # ()  replicated


class DistInfo(NamedTuple):
    f_before: jax.Array
    f_after: jax.Array
    improvement: jax.Array
    batch_counts: jax.Array  # (k,) sharded like centers


def init_dist_state(center_pts: jax.Array, kernel: KernelFn,
                    window: int) -> DistState:
    """center_pts: (k, d) initial centers (e.g. k-means++ points)."""
    k, d = center_pts.shape
    pts = jnp.zeros((k, window, d), center_pts.dtype).at[:, 0, :].set(center_pts)
    coef = jnp.zeros((k, window), jnp.float32).at[:, 0].set(1.0)
    return DistState(
        pts=pts, coef=coef,
        head=jnp.ones((k,), jnp.int32),
        sqnorm=kernel_diag(kernel, center_pts).astype(jnp.float32),
        counts=jnp.zeros((k,), jnp.float32),
        step=jnp.zeros((), jnp.int32))


def state_shardings(mesh: Mesh, model_axis: str = "model"):
    m = model_axis
    return DistState(
        pts=NamedSharding(mesh, P(m, None, None)),
        coef=NamedSharding(mesh, P(m, None)),
        head=NamedSharding(mesh, P(m)),
        sqnorm=NamedSharding(mesh, P(m)),
        counts=NamedSharding(mesh, P(m)),
        step=NamedSharding(mesh, P()))


def make_dist_step(kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                   data_axes: Sequence[str] = ("data",),
                   model_axis: str = "model"):
    """Returns step(state, xb) -> (state, info), a shard_map'd Algorithm-2
    iteration.  xb: (b, d) batch sharded over data_axes on rows."""
    rate_fn = get_rate(cfg.rate)
    b = cfg.batch_size
    data_axes = tuple(data_axes)

    cdt = jnp.bfloat16 if cfg.compute_dtype == "bfloat16" else None

    def _c(x):
        """kernel-eval compute dtype cast (bf16 = MXU native; coefficients
        and accumulations stay f32)."""
        return x.astype(cdt) if cdt is not None else x

    def local_step(state: DistState, xb_loc: jax.Array):
        k_loc, w, d = state.pts.shape
        m_idx = jax.lax.axis_index(model_axis)
        k_total = k_loc * jax.lax.axis_size(model_axis)
        center_gid0 = m_idx * k_loc  # first global center id on this device

        # ---- assignment: local batch rows x local centers ------------------
        diag_b = kernel_diag(kernel, xb_loc).astype(jnp.float32)   # (b_loc,)
        cross = kernel_cross(kernel, _c(xb_loc),
                             _c(state.pts.reshape(k_loc * w, d)))
        p_loc = jnp.einsum("bkw,kw->bk",
                           cross.reshape(xb_loc.shape[0], k_loc, w)
                           .astype(jnp.float32),
                           state.coef)                             # (b_loc,k_loc)
        d_loc = diag_b[:, None] - 2.0 * p_loc + state.sqnorm[None, :]
        d_all = jax.lax.all_gather(d_loc, model_axis, axis=1, tiled=True)
        f_before = jnp.mean(jnp.min(d_all, axis=1))
        for ax in data_axes:
            f_before = jax.lax.pmean(f_before, ax)
        assign_loc = jnp.argmin(d_all, axis=1).astype(jnp.int32)   # global ids

        # ---- gather the full batch so center owners can ingest it ---------
        xb_full, assign = xb_loc, assign_loc
        for ax in reversed(data_axes):
            xb_full = jax.lax.all_gather(xb_full, ax, axis=0, tiled=True)
            assign = jax.lax.all_gather(assign, ax, axis=0, tiled=True)

        onehot_loc = jax.nn.one_hot(assign - center_gid0, k_loc,
                                    dtype=jnp.float32)             # (b, k_loc)
        bj = jnp.sum(onehot_loc, axis=0)                           # (k_loc,)
        alpha = rate_fn(bj, state.counts, b)
        decay = 1.0 - alpha

        # ---- local ring append --------------------------------------------
        coef_scaled = state.coef * decay[:, None]

        def one_center(pts_row, coef_row, head_j, alpha_j, bj_j, mask_j):
            pos = jnp.cumsum(mask_j.astype(jnp.int32)) - 1
            slot = jnp.where(mask_j, (head_j + pos) % w, w)
            coef_row = coef_row.at[slot].set(
                alpha_j / jnp.maximum(bj_j, 1.0), mode="drop")
            pts_row = pts_row.at[slot].set(xb_full, mode="drop")
            return pts_row, coef_row, (head_j + bj_j.astype(jnp.int32)) % w

        mask = onehot_loc.T.astype(bool)                           # (k_loc, b)
        new_pts, new_coef, new_head = jax.vmap(one_center)(
            state.pts, coef_scaled, state.head, alpha, bj, mask)

        # ---- <C,C> recompute ----------------------------------------------
        if cfg.sqnorm_mode == "recompute_sharded":
            # Beyond-paper (§Perf cell A): the baseline recomputes every
            # center's full W x W Gram on EVERY data-row replica — R-fold
            # redundant.  Here each data row computes W/R Gram rows and the
            # quadratic form is psum'd: per-device flops drop by R.
            r_total = 1
            ridx = jnp.zeros((), jnp.int32)
            for ax in data_axes:
                ridx = ridx * jax.lax.axis_size(ax) + jax.lax.axis_index(ax)
                r_total *= jax.lax.axis_size(ax)
            rows = w // r_total

            def sq_one(pts_row, coef_row):
                sl = jax.lax.dynamic_slice_in_dim(pts_row, ridx * rows,
                                                  rows, 0)
                csl = jax.lax.dynamic_slice_in_dim(coef_row, ridx * rows,
                                                   rows, 0)
                g = kernel_cross(kernel, _c(sl), _c(pts_row))  # (W/R, W)
                return csl @ (g.astype(jnp.float32) @ coef_row)

            part = jax.vmap(sq_one)(new_pts, new_coef)
            new_sqnorm = part
            for ax in data_axes:
                new_sqnorm = jax.lax.psum(new_sqnorm, ax)
        else:
            # paper-faithful local Gram per center
            def sq_one(pts_row, coef_row):
                g = kernel_cross(kernel, _c(pts_row), _c(pts_row))
                return coef_row @ (g.astype(jnp.float32) @ coef_row)

            new_sqnorm = jax.vmap(sq_one)(new_pts, new_coef)

        # ---- batch objective on new centers (early stopping) ---------------
        cross2 = kernel_cross(kernel, _c(xb_loc),
                              _c(new_pts.reshape(k_loc * w, d)))
        p2 = jnp.einsum("bkw,kw->bk",
                        cross2.reshape(xb_loc.shape[0], k_loc, w)
                        .astype(jnp.float32), new_coef)
        d2 = diag_b[:, None] - 2.0 * p2 + new_sqnorm[None, :]
        d2_min = jax.lax.pmin(jnp.min(d2, axis=1), model_axis)     # (b_loc,)
        f_after = jnp.mean(d2_min)
        for ax in data_axes:
            f_after = jax.lax.pmean(f_after, ax)

        new_state = DistState(pts=new_pts, coef=new_coef, head=new_head,
                              sqnorm=new_sqnorm, counts=state.counts + bj,
                              step=state.step + 1)
        del k_total
        return new_state, DistInfo(f_before, f_after, f_before - f_after, bj)

    dspec = P(tuple(data_axes))
    state_specs = DistState(
        pts=P(model_axis, None, None), coef=P(model_axis, None),
        head=P(model_axis), sqnorm=P(model_axis), counts=P(model_axis),
        step=P())
    info_specs = DistInfo(P(), P(), P(), P(model_axis))

    step = jax.shard_map(
        local_step, mesh=mesh,
        in_specs=(state_specs, P(tuple(data_axes), None)),
        out_specs=(state_specs, info_specs),
        check_vma=False)
    del dspec
    return step


def fit_distributed(xb_stream, center_pts: jax.Array, kernel: KernelFn,
                    cfg: MBConfig, mesh: Mesh,
                    data_axes: Sequence[str] = ("data",),
                    model_axis: str = "model",
                    early_stop: bool = True):
    """Drive the sharded step from a host iterator of (b, d) batches —
    this is `cluster_hidden_states` when the iterator yields LM activations."""
    from repro.core.state import window_size

    w = window_size(cfg.batch_size, cfg.tau)
    state = init_dist_state(center_pts, kernel, w)
    shardings = state_shardings(mesh, model_axis)
    state = jax.device_put(state, shardings)
    step = jax.jit(make_dist_step(kernel, cfg, mesh, data_axes, model_axis),
                   donate_argnums=(0,))
    xspec = NamedSharding(mesh, P(tuple(data_axes), None))

    history = []
    for i, xb in enumerate(xb_stream):
        if i >= cfg.max_iters:
            break
        state, info = step(state, jax.device_put(xb, xspec))
        imp = float(info.improvement)
        history.append(dict(step=i, f_before=float(info.f_before),
                            f_after=float(info.f_after), improvement=imp))
        if early_stop and imp < cfg.epsilon:
            break
    return state, history


def cluster_hidden_states(activations_iter, k: int, kernel: KernelFn,
                          cfg: MBConfig, mesh: Mesh, init_batch=None,
                          **kw):
    """First-class integration with the LM substrate: cluster a stream of
    hidden-state batches (e.g. router inputs on MoE archs, HuBERT features).
    Initial centers = k-means++ on the first batch."""
    from repro.core.init import kmeans_plus_plus

    it = iter(activations_iter)
    first = init_batch if init_batch is not None else next(it)
    cidx = kmeans_plus_plus(jax.random.PRNGKey(cfg.k), jnp.asarray(first),
                            k, kernel)
    center_pts = jnp.asarray(first)[cidx]
    if init_batch is None:
        import itertools
        it = itertools.chain([first], it)
    return fit_distributed(it, center_pts, kernel, cfg, mesh, **kw)
