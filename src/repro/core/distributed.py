"""Multi-chip mini-batch kernel k-means (shard_map).

Sharding layout (see DESIGN.md §4):

* **Centers are sharded over the 'model' axis** — each device owns k/m whole
  centers, so the window ring, eviction bookkeeping, <C,C> maintenance and
  the learning-rate state are all device-LOCAL.  (Index-free: the window
  stores point *coordinates*, so no cross-shard dataset gathers ever occur —
  this also lets activations stream in from a co-resident LM, see
  ``cluster_hidden_states``.)
* **The batch is sharded over ('pod', 'data')** — assignment distances are
  computed on local batch rows against local centers.
* **The dataset itself is sharded over the data axes** in the fully
  on-device path (``fit_distributed_jit``): each data shard samples its
  slice of the batch locally, so no host ever materializes the batch.

Collectives per iteration (the roofline collective term):
  1. all_gather over 'model'  of P_partial (b_loc, k_loc)  -> (b_loc, k)
  2. all_gather over ('pod','data') of the batch (b, d) + assignments (b,)
     [needed so center owners can append their assigned points]
  3. psum of (k,)/scalar reductions.

The step is paper-faithful (Algorithm 2 semantics identical to
repro.core.minibatch); tests assert bit-comparable trajectories against the
single-device implementation on a CPU mesh.  ``shard_map`` itself comes
from :mod:`repro.core.compat` — the alias moved across JAX releases.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api import keys as api_keys
from repro.core.compat import shard_map
from repro.core.kernel_fns import (
    KernelFn, gram_rows_fn, kernel_cross, kernel_diag,
)
from repro.core.loop import compress_hook, drive_fit_loop, precision_plan
from repro.core.minibatch import MBConfig
from repro.core.rates import get_rate
from repro.core.state import CenterState


class DistState(NamedTuple):
    """All leading-k arrays are sharded over 'model'."""

    pts: jax.Array      # (k, W, d) window point coordinates
    coef: jax.Array     # (k, W)
    head: jax.Array     # (k,)
    sqnorm: jax.Array   # (k,)
    counts: jax.Array   # (k,)
    step: jax.Array     # ()  replicated


class DistInfo(NamedTuple):
    f_before: jax.Array
    f_after: jax.Array
    improvement: jax.Array
    batch_counts: jax.Array  # (k,) sharded like centers


def init_dist_state(center_pts: jax.Array, kernel: KernelFn,
                    window: int) -> DistState:
    """center_pts: (k, d) initial centers (e.g. k-means++ points)."""
    k, d = center_pts.shape
    pts = jnp.zeros((k, window, d), center_pts.dtype).at[:, 0, :].set(center_pts)
    coef = jnp.zeros((k, window), jnp.float32).at[:, 0].set(1.0)
    return DistState(
        pts=pts, coef=coef,
        head=jnp.ones((k,), jnp.int32),
        sqnorm=kernel_diag(kernel, center_pts).astype(jnp.float32),
        counts=jnp.zeros((k,), jnp.float32),
        step=jnp.zeros((), jnp.int32))


def state_shardings(mesh: Mesh, model_axis: str = "model"):
    m = model_axis
    return DistState(
        pts=NamedSharding(mesh, P(m, None, None)),
        coef=NamedSharding(mesh, P(m, None)),
        head=NamedSharding(mesh, P(m)),
        sqnorm=NamedSharding(mesh, P(m)),
        counts=NamedSharding(mesh, P(m)),
        step=NamedSharding(mesh, P()))


def shard_dataset(x: jax.Array, mesh: Mesh,
                  data_axes: Sequence[str] = ("data",)) -> jax.Array:
    """Place the dataset row-sharded over the data axes (replicated over
    'model').  Rows must divide evenly over the data shards — do NOT pad
    with synthetic rows: the on-device sampler (make_dist_sampling_step)
    draws uniformly from each local slice, so pad rows would silently enter
    training batches.  Subsample to a divisible n instead."""
    n_shards = _data_shard_count(mesh, data_axes)
    if x.shape[0] % n_shards:
        raise ValueError(
            f"dataset rows {x.shape[0]} must divide over {n_shards} data "
            f"shards (drop {x.shape[0] % n_shards} rows; naive padding "
            "would leak synthetic points into sampled batches — "
            "repro.api.KernelKMeans pads AND masks the per-shard sampler "
            "automatically via pad_for_mesh + the n_valid sampler bound)")
    return jax.device_put(x, NamedSharding(mesh, P(tuple(data_axes), None)))


def pad_for_mesh(x: jax.Array, mesh: Mesh,
                 data_axes: Sequence[str] = ("data",),
                 fill: float = 0.0, multiple: int = 1):
    """Pad ``x`` with ``fill`` rows to a row count divisible over the data
    shards (and by ``multiple`` — e.g. a Gram cache tile), returning
    ``(x_padded, n_valid)`` where ``n_valid`` is the real row count.  Feed
    ``n_valid`` to :func:`make_dist_sampling_step` /
    :func:`make_cached_dist_sampling_step` so the shard-local samplers mask
    pad rows out — the fill value then never reaches a batch, a window or
    a Gram evaluation (tested for fill-independence).  Pad rows land on the
    trailing data shards; a shard that ends up ALL padding (tiny n relative
    to the shard count, or a large ``multiple``) is zero-weighted out of
    every sampled batch by the step builders, so even then no synthetic
    point is ever trained on."""
    n = x.shape[0]
    n_shards = _data_shard_count(mesh, data_axes)
    pad = (-n) % math.lcm(n_shards, multiple)
    if pad == 0:
        return x, n
    fill_rows = jnp.full((pad,) + x.shape[1:], fill, x.dtype)
    return jnp.concatenate([x, fill_rows], axis=0), n


def _data_shard_count(mesh: Mesh, data_axes: Sequence[str]) -> int:
    return int(math.prod(mesh.shape[a] for a in data_axes))


def _replica_index(mesh: Mesh, data_axes: Sequence[str]) -> jax.Array:
    """Flat index of this device among the data replicas (row-major over
    data_axes) — must stay the single source of truth so shard-local batch
    sampling and sharded Gram-row ownership agree."""
    ridx = jnp.zeros((), jnp.int32)
    for ax in data_axes:
        ridx = ridx * mesh.shape[ax] + jax.lax.axis_index(ax)
    return ridx


def _make_local_step(kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                     data_axes: Sequence[str], model_axis: str):
    """The per-device Algorithm-2 iteration body (runs inside shard_map)."""
    if cfg.step not in ("composed", "fused"):
        raise ValueError(f"step={cfg.step!r} (expected 'composed' or "
                         "'fused')")
    if cfg.sqnorm_mode == "recompute_sharded":
        from repro.core.state import window_size
        w = window_size(cfg.batch_size, cfg.tau)
        r = _data_shard_count(mesh, data_axes)
        if w % r:
            raise ValueError(
                f"sqnorm_mode='recompute_sharded' needs window W={w} "
                f"divisible by the {r} data shards (else Gram rows "
                f"{w - w % r}..{w - 1} would be computed by no shard)")
    rate_fn = get_rate(cfg.rate)
    b = cfg.batch_size
    data_axes = tuple(data_axes)

    # index-data kernels carry row ids as data — a precision cast would
    # corrupt the gather keys, and the streaming slab loop would multiply
    # cache lookups for values that are gathers; they keep the composed
    # passes (and full precision) regardless of cfg.step / compute_dtype.
    # The resolution lives ONCE in the loop core (precision_plan).
    prec = precision_plan(kernel, cfg)
    index_data = prec.index_data
    stream = cfg.step == "fused" and not index_data
    cdt = prec.cdt
    _c = prec.cast  # bf16 = MXU native; coefficients/accumulations stay f32

    def p_of(pts, coef, xb_loc):
        """P[i,j] = <phi(xb_loc[i]), C_j> over this shard's centers.

        With ``cfg.use_pallas`` the fused Pallas kernel runs on the
        per-shard support tile (k_loc, W, d) — each device streams only its
        own centers' windows through VMEM, so tiles shrink with the model
        axis and never touch remote support points."""
        k_loc, w, d = pts.shape
        if cfg.use_pallas:
            from repro.kernels import ops as kops
            return kops.fused_batch_center_dots(
                kernel, _c(xb_loc), _c(pts.reshape(k_loc * w, d)), coef)
        cross = kernel_cross(kernel, _c(xb_loc), _c(pts.reshape(k_loc * w, d)))
        return jnp.einsum("bkw,kw->bk",
                          cross.reshape(xb_loc.shape[0], k_loc, w)
                          .astype(jnp.float32), coef)

    def _row_mean(vals_loc, w_loc, b_eff):
        """Mean of a per-local-row quantity over the REAL batch rows.
        ``w_loc=None`` (no fully-padded shard possible) keeps the exact
        historical mean-of-means operation order, so pre-existing
        trajectories stay bit-identical."""
        if w_loc is None:
            m = jnp.mean(vals_loc)
            for ax in data_axes:
                m = jax.lax.pmean(m, ax)
            return m
        m = jnp.sum(vals_loc * w_loc)
        for ax in data_axes:
            m = jax.lax.psum(m, ax)
        return m / b_eff

    def local_step(state: DistState, xb_loc: jax.Array, w_loc=None,
                   b_eff=None):
        """``w_loc``: optional (b_loc,) 0/1 row weights — rows of a fully
        padded data shard carry weight 0 and contribute to NOTHING (no
        window append, no count, no objective term); ``b_eff`` is then the
        real global batch size (static)."""
        k_loc, w, d = state.pts.shape
        m_idx = jax.lax.axis_index(model_axis)
        center_gid0 = m_idx * k_loc  # first global center id on this device

        # ---- assignment: local batch rows x local centers ------------------
        diag_b = kernel_diag(kernel, xb_loc).astype(jnp.float32)   # (b_loc,)
        if stream:
            # streaming per-shard distances: the (b_loc, k_loc) block is
            # required by the model-axis gather below, but the
            # (b_loc, k_loc*W) cross strip never materializes
            from repro.kernels import ops as kops
            d_loc = kops.streaming_dists(
                kernel, xb_loc, state.pts.reshape(k_loc * w, d),
                state.coef, state.sqnorm, diag_b,
                precision="bf16" if cdt is not None else "f32")
        else:
            p_loc = p_of(state.pts, state.coef, xb_loc)        # (b_loc,k_loc)
            d_loc = diag_b[:, None] - 2.0 * p_loc + state.sqnorm[None, :]
        d_all = jax.lax.all_gather(d_loc, model_axis, axis=1, tiled=True)
        f_before = _row_mean(jnp.min(d_all, axis=1), w_loc, b_eff)
        assign_loc = jnp.argmin(d_all, axis=1).astype(jnp.int32)   # global ids

        # ---- gather the full batch so center owners can ingest it ---------
        xb_full, assign, w_full = xb_loc, assign_loc, w_loc
        for ax in reversed(data_axes):
            xb_full = jax.lax.all_gather(xb_full, ax, axis=0, tiled=True)
            assign = jax.lax.all_gather(assign, ax, axis=0, tiled=True)
            if w_full is not None:
                w_full = jax.lax.all_gather(w_full, ax, axis=0, tiled=True)

        onehot_loc = jax.nn.one_hot(assign - center_gid0, k_loc,
                                    dtype=jnp.float32)             # (b, k_loc)
        if w_full is not None:
            onehot_loc = onehot_loc * w_full[:, None]
        bj = jnp.sum(onehot_loc, axis=0)                           # (k_loc,)
        alpha = rate_fn(bj, state.counts, b if w_loc is None else b_eff)
        decay = 1.0 - alpha

        # ---- local ring append --------------------------------------------
        coef_scaled = state.coef * decay[:, None]

        def one_center(pts_row, coef_row, head_j, alpha_j, bj_j, mask_j):
            pos = jnp.cumsum(mask_j.astype(jnp.int32)) - 1
            slot = jnp.where(mask_j, (head_j + pos) % w, w)
            coef_row = coef_row.at[slot].set(
                alpha_j / jnp.maximum(bj_j, 1.0), mode="drop")
            pts_row = pts_row.at[slot].set(xb_full, mode="drop")
            return pts_row, coef_row, (head_j + bj_j.astype(jnp.int32)) % w

        mask = onehot_loc.T.astype(bool)                           # (k_loc, b)
        new_pts, new_coef, new_head = jax.vmap(one_center)(
            state.pts, coef_scaled, state.head, alpha, bj, mask)

        # ---- <C,C> recompute ----------------------------------------------
        if cfg.sqnorm_mode == "recompute_sharded":
            # Beyond-paper (§Perf cell A): the baseline recomputes every
            # center's full W x W Gram on EVERY data-row replica — R-fold
            # redundant.  Here each data row computes W/R Gram rows and the
            # quadratic form is psum'd: per-device flops drop by R.
            r_total = _data_shard_count(mesh, data_axes)
            ridx = _replica_index(mesh, data_axes)
            rows = w // r_total

            def sq_one(pts_row, coef_row):
                sl = jax.lax.dynamic_slice_in_dim(pts_row, ridx * rows,
                                                  rows, 0)
                csl = jax.lax.dynamic_slice_in_dim(coef_row, ridx * rows,
                                                   rows, 0)
                g = kernel_cross(kernel, _c(sl), _c(pts_row))  # (W/R, W)
                return csl @ (g.astype(jnp.float32) @ coef_row)

            part = jax.vmap(sq_one)(new_pts, new_coef)
            new_sqnorm = part
            for ax in data_axes:
                new_sqnorm = jax.lax.psum(new_sqnorm, ax)
        elif gram_rows_fn(kernel) is not None:
            # cached kernel: resolve all local support rows in ONE lookup
            # outside the per-center vmap (a cached lookup under vmap
            # lowers its cond to select and recomputes strips on hits),
            # then gather each center's W x W block
            rows_fn = gram_rows_fn(kernel)
            rows = rows_fn(kernel, new_pts.reshape(k_loc * w, d))
            rows_k = rows.reshape(k_loc, w, rows.shape[-1])
            ids = new_pts[..., 0].astype(jnp.int32)            # (k_loc, W)

            def sq_one(rows_j, ids_j, coef_row):
                g = rows_j[:, ids_j]                           # (W, W)
                return coef_row @ (g.astype(jnp.float32) @ coef_row)

            new_sqnorm = jax.vmap(sq_one)(rows_k, ids, new_coef)
        elif stream:
            # streamed center-chunked recompute (same per-center ops as
            # the composed branch below — bit-identical): only one
            # (kc, W, W) Gram slab live per shard instead of the full
            # (k_loc, W, W) stack
            from repro.kernels.fused_step import streamed_sqnorm_pts
            new_sqnorm = streamed_sqnorm_pts(kernel, new_pts, new_coef,
                                             compute_dtype=cdt)
        else:
            # paper-faithful local Gram per center
            def sq_one(pts_row, coef_row):
                g = kernel_cross(kernel, _c(pts_row), _c(pts_row))
                return coef_row @ (g.astype(jnp.float32) @ coef_row)

            new_sqnorm = jax.vmap(sq_one)(new_pts, new_coef)

        # ---- batch objective on new centers (early stopping) ---------------
        if stream:
            from repro.kernels import ops as kops
            best2 = kops.streaming_min(
                kernel, xb_loc, new_pts.reshape(k_loc * w, d), new_coef,
                new_sqnorm, diag_b,
                precision="bf16" if cdt is not None else "f32")
        else:
            p2 = p_of(new_pts, new_coef, xb_loc)
            d2 = diag_b[:, None] - 2.0 * p2 + new_sqnorm[None, :]
            best2 = jnp.min(d2, axis=1)
        d2_min = jax.lax.pmin(best2, model_axis)                   # (b_loc,)
        f_after = _row_mean(d2_min, w_loc, b_eff)

        new_state = DistState(pts=new_pts, coef=new_coef, head=new_head,
                              sqnorm=new_sqnorm, counts=state.counts + bj,
                              step=state.step + 1)
        return new_state, DistInfo(f_before, f_after, f_before - f_after, bj)

    # in-loop landmark projection of the shard-local center windows
    # (fully center-local — zero collectives); compress=None emits the
    # historical program unchanged.  Single registration site: loop core.
    return compress_hook(local_step, kernel, cfg, local=True,
                         model_axis=model_axis)


def _state_specs(model_axis: str):
    return DistState(
        pts=P(model_axis, None, None), coef=P(model_axis, None),
        head=P(model_axis), sqnorm=P(model_axis), counts=P(model_axis),
        step=P())


def make_dist_step(kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                   data_axes: Sequence[str] = ("data",),
                   model_axis: str = "model"):
    """Returns step(state, xb) -> (state, info), a shard_map'd Algorithm-2
    iteration.  xb: (b, d) batch sharded over data_axes on rows."""
    data_axes = tuple(data_axes)
    local_step = _make_local_step(kernel, cfg, mesh, data_axes, model_axis)
    state_specs = _state_specs(model_axis)
    info_specs = DistInfo(P(), P(), P(), P(model_axis))

    return shard_map(
        local_step, mesh=mesh,
        in_specs=(state_specs, P(data_axes, None)),
        out_specs=(state_specs, info_specs),
        check_rep=False)


def _local_sample_bound(mesh: Mesh, data_axes: Sequence[str],
                        n_loc: int, n_valid: Optional[int]):
    """``(bound, has_real)`` for this shard's local randint draw.

    ``n_valid=None`` (no padding) keeps the historical static bound — the
    full local slice (``has_real=None``).  With ``n_valid`` set (the real
    global row count of a dataset padded by :func:`pad_for_mesh`), each
    shard samples only its REAL rows: shard s owns padded rows
    [s*L, (s+1)*L), of which ``clip(n_valid - s*L, 0, L)`` are real.  The
    bound is clamped to >= 1 so the draw stays well-formed on a shard that
    is ALL padding; such a shard's ``has_real`` flag is False and the step
    builders zero-weight its rows out of the batch (they never reach a
    window, a count or an objective — the docstring guarantee "pad rows
    are masked out of every batch" holds even then).  Shards with fewer
    real rows oversample them proportionally — an O(pad/n) stratification
    skew, traded for never training on synthetic points."""
    if n_valid is None:
        return n_loc, None
    start = _replica_index(mesh, data_axes) * n_loc
    real = jnp.clip(n_valid - start, 0, n_loc)
    return jnp.maximum(real, 1), real > 0


def _batch_mask(has_real, b_loc: int, n_shards: int, n_loc: int,
                n_valid: int):
    """``(w_loc, b_eff)`` zero-weighting the rows of fully-padded shards:
    shard s has real rows iff s < ceil(n_valid / L), so the effective
    global batch size is static."""
    w_loc = jnp.broadcast_to(has_real.astype(jnp.float32), (b_loc,))
    n_active = min(n_shards, -(-n_valid // n_loc))
    return w_loc, b_loc * n_active


def _make_sampling_body(kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                        data_axes: Sequence[str] = ("data",),
                        model_axis: str = "model",
                        n_valid: Optional[int] = None):
    """The UNWRAPPED shard-local sampled step (state, x_loc, key) ->
    (state, info) — shared by :func:`make_dist_sampling_step` (which
    shard_maps it over a data x model mesh) and the fused restart program
    (:func:`repro.core.engine.make_fused_restart_run`, which runs it per
    restart lane inside a restart x data x model shard_map)."""
    data_axes = tuple(data_axes)
    n_shards = _data_shard_count(mesh, data_axes)
    if cfg.batch_size % n_shards:
        raise ValueError(f"batch_size {cfg.batch_size} must divide over "
                         f"{n_shards} data shards (repro.api.KernelKMeans "
                         "rounds the batch size up automatically)")
    b_loc = cfg.batch_size // n_shards
    local_step = _make_local_step(kernel, cfg, mesh, data_axes, model_axis)

    def sampled(state: DistState, x_loc: jax.Array, key: jax.Array):
        kb = api_keys.shard_key(key, _replica_index(mesh, data_axes))
        n_loc = x_loc.shape[0]
        hi, has_real = _local_sample_bound(mesh, data_axes, n_loc, n_valid)
        bidx = jax.random.randint(kb, (b_loc,), 0, hi, dtype=jnp.int32)
        if n_valid is not None and n_valid <= (n_shards - 1) * n_loc:
            w_loc, b_eff = _batch_mask(has_real, b_loc, n_shards, n_loc,
                                       n_valid)
            return local_step(state, x_loc[bidx], w_loc=w_loc, b_eff=b_eff)
        return local_step(state, x_loc[bidx])

    return sampled


def make_dist_sampling_step(kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                            data_axes: Sequence[str] = ("data",),
                            model_axis: str = "model",
                            n_valid: Optional[int] = None):
    """Returns step(state, x, key) -> (state, info) where x is the FULL
    dataset row-sharded over the data axes and the batch is sampled
    on-device: each data shard draws b / n_shards rows uniformly from its
    local slice (stratified-uniform over equal shards — same marginal as
    the paper's uniform-with-replacement model).

    ``n_valid``: real row count of a :func:`pad_for_mesh`-padded dataset —
    masks pad rows out of the shard-local draws (see
    :func:`_local_sample_bound`); the rows of a shard that is ALL padding
    are zero-weighted out of the batch entirely."""
    data_axes = tuple(data_axes)
    sampled = _make_sampling_body(kernel, cfg, mesh, data_axes, model_axis,
                                  n_valid)
    state_specs = _state_specs(model_axis)
    info_specs = DistInfo(P(), P(), P(), P(model_axis))

    return shard_map(
        sampled, mesh=mesh,
        in_specs=(state_specs, P(data_axes, None), P()),
        out_specs=(state_specs, info_specs),
        check_rep=False)


def _fit_distributed_impl(xb_stream, center_pts: jax.Array,
                          kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                          data_axes: Sequence[str] = ("data",),
                          model_axis: str = "model",
                          early_stop: bool = True,
                          prefetch: bool = False):
    """Stream-driven sharded fit loop (shared by the ``sharded`` host plan
    and :func:`cluster_hidden_states`).

    ``prefetch``: one-deep double buffering — the NEXT batch is pulled
    from the host iterator and its ``device_put`` transfer issued right
    after step i is dispatched, before the loop blocks on step i's
    improvement, so host-to-device transfer overlaps the sharded step
    (the ROADMAP async-prefetch item).  The step consumes the same batch
    values in the same order, so results are bit-identical to the
    blocking path (tested); the only observable difference is that an
    early stop may have consumed one extra item from the iterator.

    Lowered onto the shared host driver
    (:func:`repro.core.loop.drive_fit_loop`): this function supplies only
    the iterator-backed batch producer, the mesh staging (``device_put``
    to the data-axes sharding) and the sharded step dispatch."""
    from repro.core.state import window_size

    w = window_size(cfg.batch_size, cfg.tau)
    state = init_dist_state(center_pts, kernel, w)
    shardings = state_shardings(mesh, model_axis)
    state = jax.device_put(state, shardings)
    step = jax.jit(make_dist_step(kernel, cfg, mesh, data_axes, model_axis),
                   donate_argnums=(0,))
    xspec = NamedSharding(mesh, P(tuple(data_axes), None))

    it = iter(xb_stream)

    def draw(cursor, i):
        # stream-driven: the cursor is unused, the iterator is the state
        return cursor, next(it, None)

    def dispatch(xb):
        nonlocal state
        state, info = step(state, jax.device_put(xb, xspec))
        return info

    history, _ = drive_fit_loop(
        dispatch, draw, None, max_iters=cfg.max_iters, epsilon=cfg.epsilon,
        early_stop=early_stop, prefetch=prefetch,
        stage=lambda xb: jax.device_put(xb, xspec))
    return state, history


def fit_distributed(xb_stream, center_pts: jax.Array, kernel: KernelFn,
                    cfg: MBConfig, mesh: Mesh,
                    data_axes: Sequence[str] = ("data",),
                    model_axis: str = "model",
                    early_stop: bool = True):
    """Drive the sharded step from a host iterator of (b, d) batches —
    this is `cluster_hidden_states` when the iterator yields LM activations.

    .. deprecated::
        Use :class:`repro.api.KernelKMeans` with
        ``SolverConfig(distribution="sharded", jit=False)`` (the estimator
        samples its batches through the unified key stream) — this shim
        resolves exactly that plan and delegates the stream to it.
    """
    from repro.api import legacy as _legacy
    _legacy.warn_legacy(
        "repro.core.distributed.fit_distributed",
        "KernelKMeans(SolverConfig(distribution='sharded', jit=False))")
    return _legacy.fit_distributed(xb_stream, center_pts, kernel, cfg, mesh,
                                   data_axes=data_axes,
                                   model_axis=model_axis,
                                   early_stop=early_stop)


def fit_distributed_jit(x: jax.Array, center_pts: jax.Array,
                        kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                        key: jax.Array,
                        data_axes: Sequence[str] = ("data",),
                        model_axis: str = "model"):
    """Fully on-device distributed fit: the dataset stays sharded across the
    mesh, batches are sampled shard-locally, and the whole early-stopped loop
    is ONE compiled program — zero per-step host sync (the production path).

    .. deprecated::
        Use :class:`repro.api.KernelKMeans` with
        ``SolverConfig(distribution="sharded", jit=True)`` — this shim
        resolves exactly that plan and delegates to it (the estimator
        additionally pads-and-masks non-divisible datasets and caches the
        compiled program across fits).

    Returns (state, iters) like :func:`repro.core.minibatch.fit_jit`."""
    from repro.api import legacy as _legacy
    _legacy.warn_legacy(
        "repro.core.distributed.fit_distributed_jit",
        "KernelKMeans(SolverConfig(distribution='sharded', jit=True))")
    return _legacy.fit_distributed_jit(x, center_pts, kernel, cfg, mesh,
                                       key, data_axes=data_axes,
                                       model_axis=model_axis)


# --------------------------------------------------------------------------
# Per-shard Gram tile caches (repro.cache subsystem under the shard_map shim)
#
# In the cached distributed fit the dataset flows as (n, 1) index-data (the
# CachedKernel convention, same as Precomputed), so locally sampled batch
# rows carry their GLOBAL row ids — each data shard warms its own tile
# cache with exactly the blocks its local samples touch ("shard-local
# keys"), and the unchanged local Algorithm-2 step then serves every
# cross-kernel block from resident tiles.  The caches are stacked on a
# leading data-shard axis and ride the while_loop carry, so warmth persists
# across the whole zero-host-sync fit.


def init_shard_caches(mesh: Mesh, n: int, tile: int, capacity: int,
                      data_axes: Sequence[str] = ("data",),
                      dtype=jnp.float32, restarts: Optional[int] = None,
                      restart_axis: str = "restart"):
    """One empty GramTileCache per data shard, stacked on a leading axis
    that is sharded over ``data_axes`` (replicated over 'model' — devices
    along the model axis see the same batch rows, so their cache contents
    evolve identically).

    ``restarts=R`` (the fused restart x data x model plan) prepends a
    restart axis: one cache per (restart, data-shard) pair, leaves stacked
    ``(R, S, ...)`` and sharded ``P(restart_axis, data_axes, ...)`` —
    restarts draw independent batches, so their working sets (and caches)
    evolve independently."""
    from repro.cache import tile_cache

    data_axes = tuple(data_axes)
    s = _data_shard_count(mesh, data_axes)
    c0 = tile_cache.create_cache(n, tile, capacity, dtype)
    lead = (s,) if restarts is None else (restarts, s)
    axes = (data_axes,) if restarts is None else (restart_axis, data_axes)
    stacked = jax.tree.map(
        lambda a: jnp.tile(a[(None,) * len(lead)],
                           lead + (1,) * a.ndim), c0)
    return jax.device_put(stacked, jax.tree.map(
        lambda a: NamedSharding(
            mesh, P(*axes, *([None] * (a.ndim - len(lead))))),
        stacked))


def _make_cached_sampling_body(base_kernel: KernelFn, x_real: jax.Array,
                               cfg: MBConfig, mesh: Mesh,
                               data_axes: Sequence[str] = ("data",),
                               model_axis: str = "model",
                               n_valid: Optional[int] = None):
    """The UNWRAPPED cached shard-local sampled step
    (state, caches_loc, x_loc, key) -> (state, caches_loc, info) — shared
    by :func:`make_cached_dist_sampling_step` and the fused restart
    program.  ``caches_loc`` leaves carry the leading length-1 data-shard
    stacking axis (what shard_map hands a data shard of the
    :func:`init_shard_caches` stack)."""
    from repro.cache import tile_cache
    from repro.cache.cached_kernel import CachedKernel

    if cfg.compute_dtype != "float32":
        raise ValueError("cached distributed fit carries row indices as "
                         "data; compute_dtype casts would corrupt them")
    if cfg.sqnorm_mode != "recompute":
        raise ValueError("cached distributed fit supports sqnorm_mode="
                         "'recompute' (the sharded variant slices window "
                         "rows inside per-center vmaps, which defeats the "
                         "cache's cond-skip)")
    data_axes = tuple(data_axes)
    n_shards = _data_shard_count(mesh, data_axes)
    if cfg.batch_size % n_shards:
        raise ValueError(f"batch_size {cfg.batch_size} must divide over "
                         f"{n_shards} data shards (repro.api.KernelKMeans "
                         "rounds the batch size up automatically)")
    b_loc = cfg.batch_size // n_shards

    def cached_sampled(state: DistState, caches, x_loc: jax.Array,
                       key: jax.Array):
        kb = api_keys.shard_key(key, _replica_index(mesh, data_axes))
        n_loc = x_loc.shape[0]
        hi, has_real = _local_sample_bound(mesh, data_axes, n_loc, n_valid)
        bidx = jax.random.randint(kb, (b_loc,), 0, hi, dtype=jnp.int32)
        xb_loc = x_loc[bidx]                       # (b_loc, 1) global ids
        w_loc = b_eff = None
        if n_valid is not None and n_valid <= (n_shards - 1) * n_loc:
            w_loc, b_eff = _batch_mask(has_real, b_loc, n_shards, n_loc,
                                       n_valid)
            # a fully-padded shard's (zero-weighted) draws point at pad
            # rows — rewrite them to row 0 so the warm set and every
            # cached lookup stay on REAL, resident tiles
            xb_loc = jnp.where(has_real, xb_loc[:, 0],
                               jnp.zeros((), x_loc.dtype))[:, None]
        # Warm set = FULL batch + this shard's current window rows: the
        # local step all_gathers the batch into the center windows, so
        # window rows originate from every data shard — warming only the
        # local slice would leave them missing on each sqnorm recompute.
        ids_full = xb_loc[:, 0].astype(jnp.int32)
        for ax in reversed(data_axes):
            ids_full = jax.lax.all_gather(ids_full, ax, axis=0, tiled=True)
        # windows are model-sharded: gather ALL centers' window ids so the
        # warm set (and thus the cache contents, replicated over 'model')
        # is identical on every device of a data shard
        win_ids = jax.lax.all_gather(
            state.pts[..., 0].reshape(-1).astype(jnp.int32), model_axis,
            axis=0, tiled=True)
        cache = jax.tree.map(lambda a: a[0], caches)
        cache = tile_cache.warm(cache, base_kernel, x_real,
                                jnp.concatenate([ids_full, win_ids]))
        ck = CachedKernel(base=base_kernel, x=x_real, cache=cache)
        local_step = _make_local_step(ck, cfg, mesh, data_axes, model_axis)
        new_state, info = local_step(state, xb_loc, w_loc=w_loc,
                                     b_eff=b_eff)
        return new_state, jax.tree.map(lambda a: a[None], cache), info

    return cached_sampled


def make_cached_dist_sampling_step(base_kernel: KernelFn, x_real: jax.Array,
                                   cfg: MBConfig, mesh: Mesh,
                                   data_axes: Sequence[str] = ("data",),
                                   model_axis: str = "model",
                                   n_valid: Optional[int] = None):
    """Cached variant of :func:`make_dist_sampling_step`: returns
    step(state, caches, x_idx, key) -> (state, caches, info), where x_idx is
    the (n, 1) index-data dataset row-sharded over ``data_axes`` and
    ``caches`` the stacked per-shard tile caches of
    :func:`init_shard_caches`.  ``base_kernel`` / ``x_real`` (the actual
    coordinates) are closed over and replicated."""
    data_axes = tuple(data_axes)
    cached_sampled = _make_cached_sampling_body(
        base_kernel, x_real, cfg, mesh, data_axes, model_axis, n_valid)

    from repro.cache.tile_cache import GramTileCache

    state_specs = _state_specs(model_axis)
    info_specs = DistInfo(P(), P(), P(), P(model_axis))
    # stacked cache ranks: store (S,C,tile,n); keys/stamp (S,C); scalars (S,)
    cache_specs = GramTileCache(
        store=P(data_axes, None, None, None), keys=P(data_axes, None),
        stamp=P(data_axes, None), clock=P(data_axes), hits=P(data_axes),
        misses=P(data_axes), evictions=P(data_axes))

    return shard_map(
        cached_sampled, mesh=mesh,
        in_specs=(state_specs, cache_specs, P(data_axes, None), P()),
        out_specs=(state_specs, cache_specs, info_specs),
        check_rep=False)


def fit_distributed_cached_jit(x: jax.Array, init_idx: jax.Array,
                               base_kernel: KernelFn, cfg: MBConfig,
                               mesh: Mesh, key: jax.Array,
                               tile: int = 256, capacity: int = 16,
                               data_axes: Sequence[str] = ("data",),
                               model_axis: str = "model",
                               cache_dtype=jnp.float32):
    """Cached :func:`fit_distributed_jit`: same fully on-device
    early-stopped loop (one compiled program, zero per-step host sync), but
    every data shard carries a Gram tile cache in the while_loop state —
    repeated rows across sampled batches stop re-evaluating the kernel.

    .. deprecated::
        Use :class:`repro.api.KernelKMeans` with
        ``SolverConfig(distribution="sharded", cache="lru", jit=True)`` —
        this shim resolves exactly that plan and delegates to it.

    ``x``: (n, d) real coordinates; ``init_idx``: (k,) initial center row
    indices.  Sampling is identical to the uncached path (same fold_in /
    randint stream), so trajectories are numerically equivalent.
    Returns (state, caches, iters); ``repro.cache.stats`` on a
    ``jax.tree.map(lambda a: a[s], caches)`` slice reports shard s's
    hit/miss telemetry."""
    from repro.api import legacy as _legacy
    _legacy.warn_legacy(
        "repro.core.distributed.fit_distributed_cached_jit",
        "KernelKMeans(SolverConfig(distribution='sharded', cache='lru', "
        "jit=True))")
    return _legacy.fit_distributed_cached_jit(
        x, init_idx, base_kernel, cfg, mesh, key, tile=tile,
        capacity=capacity, data_axes=data_axes, model_axis=model_axis,
        cache_dtype=cache_dtype)


def dist_to_center_state(dst: DistState) -> CenterState:
    """View a coordinate-window DistState as an index-free CenterState-like
    tuple for serving: ``idx`` is a placeholder arange since predict paths
    below consume coordinates directly."""
    k, w, _ = dst.pts.shape
    return CenterState(idx=jnp.arange(k * w, dtype=jnp.int32).reshape(k, w),
                       coef=dst.coef, head=dst.head, sqnorm=dst.sqnorm,
                       counts=dst.counts, step=dst.step)


# Compiled serving programs, keyed by everything baked into the closure;
# array shapes/dtypes are handled by each cached function's own jit cache.
_PREDICT_FNS: dict = {}


def _predict_fn(mesh: Mesh, data_axes, treedef, loc_chunk: int):
    key = (mesh, data_axes, treedef, loc_chunk)
    fn = _PREDICT_FNS.get(key)
    if fn is None:
        from repro.core.minibatch import assign_chunked

        def local_predict(kern_leaves, coef, sqnorm, sup, xq_loc):
            kern = jax.tree_util.tree_unflatten(treedef, kern_leaves)
            return assign_chunked(kern, coef, sqnorm, sup, xq_loc,
                                  loc_chunk)

        fn = jax.jit(shard_map(
            local_predict, mesh=mesh,
            in_specs=([P()] * treedef.num_leaves, P(), P(), P(),
                      P(data_axes, None)),
            out_specs=P(data_axes),
            check_rep=False))
        _PREDICT_FNS[key] = fn
    return fn


def predict_distributed(state: CenterState, x: jax.Array, xq: jax.Array,
                        kernel: KernelFn, mesh: Mesh,
                        data_axes: Optional[Sequence[str]] = None,
                        chunk: int = 4096) -> jax.Array:
    """Sharded serving variant of :func:`repro.core.minibatch.predict`:
    query rows are sharded over the mesh's data axes, support windows are
    replicated, and each device classifies its rows with zero collectives
    (the chunked kernel itself is ``minibatch.assign_chunked``, shared with
    the single-device path).  Handles arbitrary (non-divisible) query
    counts by padding.  The compiled program is cached per
    (mesh, axes, kernel structure, chunk) so repeated serving calls don't
    re-trace."""
    if data_axes is None:
        data_axes = tuple(a for a in mesh.axis_names if a != "model")
    data_axes = tuple(data_axes)
    n_shards = _data_shard_count(mesh, data_axes)
    nq = xq.shape[0]
    pad = (-nq) % n_shards
    xq_p = jnp.pad(xq, ((0, pad),) + ((0, 0),) * (xq.ndim - 1))

    sup = x[state.idx.reshape(-1)]                   # (k*W, d) replicated
    loc_chunk = min(chunk, max(xq_p.shape[0] // n_shards, 1))

    leaves, treedef = jax.tree_util.tree_flatten(kernel)
    fn = _predict_fn(mesh, data_axes, treedef, loc_chunk)
    xq_sh = jax.device_put(xq_p, NamedSharding(mesh, P(data_axes, None)))
    out = fn(leaves, state.coef, state.sqnorm, sup, xq_sh)
    return out[:nq]


def cluster_hidden_states(activations_iter, k: int, kernel: KernelFn,
                          cfg: MBConfig, mesh: Mesh, init_batch=None,
                          **kw):
    """First-class integration with the LM substrate: cluster a stream of
    hidden-state batches (e.g. router inputs on MoE archs, HuBERT features).
    Initial centers = k-means++ on the first batch."""
    from repro.core.init import kmeans_plus_plus

    it = iter(activations_iter)
    first = init_batch if init_batch is not None else next(it)
    cidx = kmeans_plus_plus(jax.random.PRNGKey(cfg.k), jnp.asarray(first),
                            k, kernel)
    center_pts = jnp.asarray(first)[cidx]
    if init_batch is None:
        import itertools
        it = itertools.chain([first], it)
    return _fit_distributed_impl(it, center_pts, kernel, cfg, mesh, **kw)
