"""Center initialization.

Kernel k-means++ (Arthur & Vassilvitskii 2007, run in feature space): pick
the first center uniformly, then sample each next center with probability
proportional to the squared feature-space distance to the closest chosen
center.  Because chosen centers are single data points, d^2(x, c) =
K(x,x) + K(c,c) - 2 K(x,c) — O(n) kernel evaluations per center, O(nk)
total.  Theorem 1(3): this initialization gives the O(log k) expected
approximation ratio.

All functions return center INDICES into X — every algorithm in repro.core
represents centers as (sparse) combinations of data points, so an index is
the canonical initial center.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import KernelFn, kernel_cross, kernel_diag


def kmeans_plus_plus(key: jax.Array, x: jax.Array, k: int,
                     kernel: KernelFn) -> jax.Array:
    """D^2-sampling in feature space; returns (k,) int32 indices into x."""
    n = x.shape[0]
    diag = kernel_diag(kernel, x)  # (n,) = K(x,x)

    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)

    def dist_to(idx):
        c = x[idx][None, :]
        cross = kernel_cross(kernel, x, c)[:, 0]  # (n,)
        return jnp.maximum(diag + diag[idx] - 2.0 * cross, 0.0)

    def body(t, carry):
        mind, chosen, key = carry
        key, sub = jax.random.split(key)
        # Guard against an all-zero distance vector (duplicate data): fall
        # back to uniform.
        total = jnp.sum(mind)
        p = jnp.where(total > 0, mind / jnp.maximum(total, 1e-30),
                      jnp.full_like(mind, 1.0 / n))
        nxt = jax.random.choice(sub, n, p=p)
        chosen = chosen.at[t].set(nxt)
        mind = jnp.minimum(mind, dist_to(nxt))
        return mind, chosen, key

    chosen = jnp.zeros((k,), jnp.int32).at[0].set(first)
    mind = dist_to(first)
    mind, chosen, _ = jax.lax.fori_loop(1, k, body, (mind, chosen, key))
    return chosen


def kmeans_plus_plus_subsampled(key: jax.Array, x: jax.Array, k: int,
                                kernel: KernelFn, m: int) -> jax.Array:
    """k-means++ over a uniform subsample of size m — sublinear-in-n init
    for the truly huge regime (composes with the paper's O(1)-iteration
    result for b = Theta(log n))."""
    ks, kp = jax.random.split(key)
    sub = jax.random.choice(ks, x.shape[0], (m,), replace=False)
    local = kmeans_plus_plus(kp, x[sub], k, kernel)
    return sub[local]


def random_init(key: jax.Array, n: int, k: int) -> jax.Array:
    return jax.random.choice(key, n, (k,), replace=False).astype(jnp.int32)


def draw_init(key: jax.Array, x: jax.Array, k: int, kernel: KernelFn,
              method: str = "kmeans++") -> jax.Array:
    """The one init-drawing entry every fit path shares (it used to be
    copy-pasted across ``fit`` / ``fit_cached`` / the engine): dispatch on
    the method name, return (k,) int32 indices into ``x``."""
    if method == "kmeans++":
        return kmeans_plus_plus(key, x, k, kernel)
    if method == "random":
        return random_init(key, x.shape[0], k)
    raise ValueError(f"unknown init method {method!r} "
                     "(expected 'kmeans++' or 'random')")
