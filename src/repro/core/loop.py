"""The ONE fit-loop core behind every solver plan.

The paper's algorithm is a single loop — sample a batch, assign against
the current truncated centers, update, check the early-stop condition —
but the repo used to re-implement that loop once per executor family, and
every cross-cutting axis (precision, compress, prefetch, donation,
program caching) had to be threaded through all of them by hand.  This
module owns the loop skeleton exactly once:

* **Drivers** — the two ways the canonical stage sequence executes:

  - :func:`drive_fit_loop`: the host-driven early-stopped loop (python
    ``for`` + per-step improvement sync).  Generic over where batches
    come from: the single-device plans draw from the unified key stream,
    the sharded stream plan pulls from a host iterator — both are thin
    adapters (``minibatch.host_fit_loop``,
    ``distributed._fit_distributed_impl``).  One-deep **prefetch** is
    implemented HERE and nowhere else.
  - :func:`run_early_stopped_keyed` / :func:`run_early_stopped`: the
    on-device driver — the whole early-stopped loop as one
    ``lax.while_loop`` (jit / shard_map / vmap'd restart plans all close
    over it).

* **Cross-cutting axis hooks**, each registered once:

  - :func:`precision_plan` — the ``compute_dtype`` axis (bf16 kernel
    evals, f32 accumulation; index-data kernels exempt).
  - :func:`compress_hook` — the landmark-compression cadence hook, for
    both the single-device step and the shard-local step.
  - :func:`lookup_program` — donation-aware compiled-program caching
    (the ``program_builds()`` counter lives here).

* **Carry/telemetry** — :class:`FitOutcome` (what a fit produced) and
  :class:`FitCarry` (the resumable part ``partial_fit`` / ``save`` need).

* **Lowering description** — :class:`LoopSpec` + :func:`stages`: every
  executor family describes itself as a declarative lowering (sampler,
  step body, placement, donation, active hooks) over this core;
  ``KernelKMeans.explain()`` renders it.

Adding a new axis to the fit loop means touching the one relevant hook
here plus the lowerings that opt in — not seven executor families
(ROADMAP: multi-host mesh, tile autotuner, embedding-stream producer).
The refactor contract is bit-identity: every emitted program is the
historical one (tests/test_api_grid.py pins the full plan grid).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import keys as api_keys

# ---------------------------------------------------------------------------
# Carry / telemetry — the loop's outputs, shared by every lowering.


@dataclasses.dataclass
class FitOutcome:
    """What a plan's ``fit`` produced.  ``state`` is a ``CenterState``
    (single-device plans) or ``DistState`` (sharded plans); the optional
    fields carry plan-specific artifacts (tile cache, engine diagnostics,
    the carried PRNG key for ``partial_fit`` resumption)."""

    state: Any
    iters: Any                              # python int or on-device scalar
    history: Optional[List[dict]] = None    # host-driven plans only
    key: Optional[jax.Array] = None         # carried fit-stream key
    steps: int = 0                          # completed host-loop steps
    cache: Any = None                       # CachedKernel (single lru plan)
    caches: Any = None                      # stacked per-shard tile caches
    engine: Any = None                      # EngineResult (multi-restart)
    x_view: Any = None                      # index-data view (lru/precomp)


class FitCarry(NamedTuple):
    """The resumable part of a fit — everything ``partial_fit`` needs to
    continue the batch stream bit-exactly, and therefore everything
    ``KernelKMeans.save`` must round-trip: the full center state, the
    carried PRNG fit key, the completed-step cursor (the nested sampler's
    schedule position), and the iteration count."""

    state: Any                    # CenterState (single-device plans)
    key: jax.Array                # carried fit-stream key
    steps: Optional[int]          # host-loop cursor; None on jit-only fits
    iters: int


def carry_of(outcome: FitOutcome) -> Optional[FitCarry]:
    """The serializable resume carry of an outcome, or None when the plan
    that produced it cannot resume (no carried key)."""
    if outcome is None or outcome.key is None:
        return None
    return FitCarry(state=outcome.state, key=outcome.key,
                    steps=outcome.steps, iters=int(outcome.iters))


def outcome_from_carry(carry: FitCarry) -> FitOutcome:
    """Rehydrate a deserialized carry into a resumable outcome."""
    return FitOutcome(state=carry.state, iters=carry.iters, key=carry.key,
                      steps=carry.steps)


# ---------------------------------------------------------------------------
# The carry-guard axis (non-finite repair + dead-center reseed), registered
# once — like the compress/precision hooks, the clean path is the identity.


class CarryGuardReport(NamedTuple):
    """What :func:`guard_carry` did to one carry.  ``patched`` counts
    non-finite float entries zeroed across the state leaves; ``reseeded``
    counts dead centers re-initialized from the dataset.  Both zero means
    the carry was returned UNTOUCHED (same object — bit-identity by
    construction)."""

    patched: int
    reseeded: int

    @property
    def clean(self) -> bool:
        return self.patched == 0 and self.reseeded == 0


def guard_carry(carry: Optional[FitCarry], *, x=None, kernel=None,
                seed: int = 0, faults=None):
    """THE carry-guard registration site: repair a host
    :class:`FitCarry` whose center state went degenerate — non-finite
    coefficients/norms/counts (a poisoned batch, a bad reduction, a
    hardware fault) are zeroed, and DEAD centers (no finite nonzero
    coefficient left — the empty-cluster instability Tang & Monteleoni
    analyze for stochastic k-means) are reseeded as single data points
    drawn deterministically from ``(seed, fit step, center)``.

    A CLEAN carry is returned as the SAME object with a zero report —
    callers on the clean path stay bit-identical to not calling the
    guard at all (the ``compress="off"`` / ``cdt=None`` identity
    convention).  Reseeding needs ``x`` (host dataset the carry's
    indices refer to; non-finite rows are never picked) and ``kernel``
    (for the reseeded center's ``sqnorm``); without them dead centers
    are left zeroed but still counted.

    ``faults``: an optional :class:`repro.service.faults.FaultPlan`
    whose ``loop.carry`` site fires here — a ``nan`` event poisons the
    carry deterministically BEFORE the check, so the chaos harness
    exercises exactly this repair path."""
    if carry is None:
        return carry, CarryGuardReport(0, 0)
    if faults is not None:
        ev = faults.fire("loop.carry")
        if ev is not None and ev.kind == "nan" and \
                hasattr(carry.state, "coef"):
            carry = carry._replace(state=carry.state._replace(
                coef=faults.nan_leaf(np.asarray(carry.state.coef), ev)))
    state = carry.state
    if not hasattr(state, "coef"):          # only CenterState-shaped
        return carry, CarryGuardReport(0, 0)
    coef = np.asarray(state.coef)
    sqnorm = np.asarray(state.sqnorm)
    counts = np.asarray(state.counts)
    fin_coef = np.isfinite(coef)
    fin_sq = np.isfinite(sqnorm)
    fin_ct = np.isfinite(counts)
    patched = int((~fin_coef).sum() + (~fin_sq).sum() + (~fin_ct).sum())
    dead = ~np.any(fin_coef & (coef != 0), axis=1)
    if patched == 0 and not dead.any():
        return carry, CarryGuardReport(0, 0)     # identity: same object
    coef = np.where(fin_coef, coef, 0.0).astype(coef.dtype)
    sqnorm = np.where(fin_sq, sqnorm, 0.0).astype(sqnorm.dtype)
    counts = np.where(fin_ct, counts, 0.0).astype(counts.dtype)
    idx = np.array(state.idx, copy=True)
    head = np.array(state.head, copy=True)
    reseeded = 0
    if dead.any() and x is not None and kernel is not None:
        from repro.core.kernel_fns import kernel_diag

        xh = np.asarray(x)
        ok_rows = np.flatnonzero(np.isfinite(xh).all(axis=1))
        step = int(np.asarray(state.step))
        for j in np.flatnonzero(dead):
            if ok_rows.size == 0:
                break
            pick = int(ok_rows[int(np.random.default_rng(
                (int(seed), step, int(j))).integers(0, ok_rows.size))])
            idx[j] = 0
            idx[j, 0] = pick
            coef[j] = 0.0
            coef[j, 0] = 1.0
            head[j] = 1
            sqnorm[j] = float(np.asarray(
                kernel_diag(kernel, xh[pick:pick + 1]))[0])
            counts[j] = 0.0
            reseeded += 1
    guarded = carry._replace(state=state._replace(
        idx=idx, coef=coef, head=head, sqnorm=sqnorm, counts=counts))
    return guarded, CarryGuardReport(patched, reseeded)


# ---------------------------------------------------------------------------
# Cross-executor compiled-program cache (the donation / program-cache axis).
#
# Executors cache their compiled programs on the instance, but the
# instance is rebuilt whenever a plan is re-resolved (a fresh KernelKMeans
# per fit, the legacy shims, plan signature changes) — and every rebuild
# used to re-bind (re-trace, re-compile) programs whose closure is
# IDENTICAL: same Algorithm-2 statics, same kernel values, same mesh, same
# donated-argnum signature.  This registry keys compiled programs on
# exactly that closure signature, so repeated ``fit`` / ``partial_fit`` on
# same-shape data reuses ONE executable across executor instances.
# Kernels with large array leaves (Precomputed grams, cached kernels) are
# not value-keyed — id() reuse after GC could alias two different datasets
# — so those programs stay instance-local, the historical behaviour.
#
# ``program_builds()`` counts actual program constructions (the
# compile-counter hook tests/test_fused_step.py regresses against).

_PROGRAM_CACHE: dict = {}        # insertion-ordered (LRU via re-insert)
_PROGRAM_CACHE_MAX = 128         # distinct (config, kernel, mesh) closures
_PROGRAM_BUILDS = [0]

# Loop-core entries: bumped whenever a fit actually runs (host driver) or
# traces (device driver) through this module — the structural-guard hook
# (tests/test_loop_guard.py) asserting every registered solver routes
# through the loop core rather than owning a private fit loop.
_LOOP_RUNS = [0]


def loop_runs() -> int:
    """How many times a fit has entered a loop-core driver (host runs +
    device-driver traces) since import — monotone, like
    :func:`program_builds`."""
    return _LOOP_RUNS[0]


def program_builds() -> int:
    """How many compiled fit programs have been BUILT (not reused) since
    import — a monotone counter; snapshot it around a fit to assert the
    fit re-bound nothing."""
    return _PROGRAM_BUILDS[0]


def clear_program_cache() -> None:
    _PROGRAM_CACHE.clear()


def _cache_put(key, prog) -> None:
    """Insert with LRU eviction: the registry is process-lifetime, and
    keys carry dataset-dependent parts (padded sizes, max_iters), so a
    long-running service fitting many shapes must not pin every
    executable it ever compiled.  Evicted programs stay alive as long as
    some executor instance still holds them (``self._programs``)."""
    _PROGRAM_CACHE[key] = prog
    while len(_PROGRAM_CACHE) > _PROGRAM_CACHE_MAX:
        _PROGRAM_CACHE.pop(next(iter(_PROGRAM_CACHE)))


def _cache_get(key):
    prog = _PROGRAM_CACHE.pop(key, None)
    if prog is not None:
        _PROGRAM_CACHE[key] = prog        # refresh recency
    return prog


def _kernel_sig(kernel):
    """Value signature of a kernel pytree, or None when any leaf is too
    large to key by value (then programs must stay instance-local)."""
    leaves, treedef = jax.tree_util.tree_flatten(kernel)
    sig = []
    for leaf in leaves:
        a = np.asarray(leaf)
        if a.size > 64:
            return None
        sig.append((a.dtype.str, a.shape, a.tobytes()))
    return (treedef, tuple(sig))


def lookup_program(programs: dict, owner: str, key, build, kernel=None,
                   kernel_free: bool = False):
    """Compiled-program lookup: the instance cache ``programs`` first,
    then the cross-executor registry above.  ``key`` must capture the
    FULL closure signature minus the kernel — loop statics, mesh/axes,
    and the donated-argnum signature.  The kernel is value-keyed when its
    leaves are small; ``kernel_free`` marks programs that take the kernel
    as a traced ARGUMENT (nothing kernel-shaped in the closure), which
    share unconditionally."""
    prog = programs.get(key)
    if prog is None:
        ksig = True if kernel_free else _kernel_sig(kernel)
        if ksig is None:
            _PROGRAM_BUILDS[0] += 1
            prog = build()
        else:
            gkey = (owner, key, ksig)
            prog = _cache_get(gkey)
            if prog is None:
                _PROGRAM_BUILDS[0] += 1
                prog = build()
                _cache_put(gkey, prog)
        programs[key] = prog
    return prog


def _x_keyed_run(runs: dict, key, x_real, build):
    """Compile-cache lookup for programs that CLOSE OVER a dataset
    (``x_real``): the entry is valid only for that exact array object,
    never merely for its shape — refitting on new same-shaped data must
    rebuild (regression: stale coordinates baked in as jit constants)."""
    entry = runs.get(key)
    if entry is not None and entry[0] is x_real:
        return entry[1]
    run = build()
    runs[key] = (x_real, run)
    return run


def loop_config(mb, early_stop: bool, max_iters=None):
    """The MBConfig a jitted early-stopped loop should run with:
    ``early_stop=False`` lowers to an epsilon no improvement can undercut
    (the ``run_early_stopped`` condition is baked into the compiled loop,
    unlike the host loop's python check)."""
    if max_iters is not None:
        mb = mb._replace(max_iters=max_iters)
    if not early_stop:
        mb = mb._replace(epsilon=float("-inf"))
    return mb


# ---------------------------------------------------------------------------
# The precision axis (SolverConfig ``precision`` / MBConfig
# ``compute_dtype``), registered once for every step builder.


class PrecisionPlan(NamedTuple):
    """Resolved kernel-eval precision for one (kernel, config) point.

    ``cdt=None`` is the IDENTITY: both cast helpers are no-ops and the
    emitted program is the historical f32 one, bit-for-bit.  With
    ``cdt=bfloat16`` the COORDINATES entering kernel evaluations are cast
    to bf16 (MXU-native) while coefficients, argmin carries and every
    accumulation stay f32.  Index-data kernels (Precomputed / cached)
    carry row ids as data — a cast would corrupt the gather keys — so
    they always resolve to the identity regardless of the config."""

    cdt: Any                # jnp.bfloat16 or None (None = identity)
    index_data: bool        # kernel rows are gather keys, never cast
    tag: str                # "bf16" | "f32" (the fused kernels' static)

    def cast(self, v):
        """Kernel-eval compute-dtype cast (the step builders' ``_c``)."""
        return v.astype(self.cdt) if self.cdt is not None else v

    def f32(self, v):
        """Back to f32 for accumulation (the step builders' ``_f32``)."""
        return v.astype(jnp.float32) if self.cdt is not None else v


def precision_plan(kernel, cfg) -> PrecisionPlan:
    """THE precision-axis registration site: every step builder
    (``minibatch.make_step``, ``minibatch._make_fused_step``,
    ``distributed._make_local_step``) resolves its compute dtype here, so
    a new precision mode lands in one place."""
    from repro.core.kernel_fns import is_index_data

    index_data = is_index_data(kernel)
    cdt = jnp.bfloat16 if (cfg.compute_dtype == "bfloat16"
                           and not index_data) else None
    return PrecisionPlan(cdt=cdt, index_data=index_data,
                         tag="bf16" if cdt is not None else "f32")


# ---------------------------------------------------------------------------
# The compress axis (landmark projection cadence), registered once.


def compress_hook(step, kernel, cfg, *, local: bool = False,
                  model_axis: str = "model"):
    """THE compress-axis registration site: wrap a step so every
    ``cfg.compress.every``-th iteration ends with an in-place landmark
    projection (:mod:`repro.landmark.compress`).  ``compress=None`` (and
    ``every=0``, the round-cadence-only mode) return ``step`` itself —
    the emitted program is the historical one, bit-for-bit (the
    ``cdt=None`` identity convention).  ``local=True`` wraps the
    shard-local step body instead (model-sharded centers; selection keys
    fold in the global center id via the model-axis index)."""
    spec = cfg.compress
    if spec is None or spec.every <= 0:
        return step
    from repro.landmark.compress import wrap_local_step, wrap_step

    if local:
        return wrap_local_step(step, kernel, spec, model_axis)
    return wrap_step(step, kernel, spec)


# ---------------------------------------------------------------------------
# Driver 1: the host-driven early-stopped loop (THE prefetch site).


def drive_fit_loop(dispatch, draw, cursor, *, max_iters: int,
                   epsilon: float, early_stop: bool = True,
                   prefetch: bool = False, step0: int = 0,
                   stage=jax.device_put):
    """The host-driven early-stopped fit loop — the single driver behind
    every non-jit plan (single/precomputed/lru via
    ``minibatch.host_fit_loop``; the sharded stream plan via
    ``distributed._fit_distributed_impl``).

    Per iteration: ``draw(cursor, i) -> (cursor', item)`` produces the
    next batch (``item=None`` ends the loop — an exhausted stream);
    ``dispatch(item) -> StepInfo`` issues the device step (asynchronous —
    state threads through the adapter's closure); the loop then blocks on
    ``float(info.improvement)`` and stops early when it drops below
    ``epsilon``.  ``step0`` offsets the iteration counter so
    ``partial_fit`` resumption continues both the nested-sampler schedule
    and the history numbering.  Returns ``(history, cursor)``.

    ``prefetch``: one-deep pipeline — iteration i+1's item is drawn (and
    staged on device via ``stage``) after DISPATCHING step i but before
    blocking on its improvement, so sampling/transfer overlaps the device
    step.  The drawn values and the returned cursor are identical to the
    blocking path: an early stop discards the prefetched item without
    advancing the cursor (key-stream draws consume nothing; a caller-owned
    iterator may observably have yielded one extra item).  Results are
    bit-identical either way (tested)."""
    _LOOP_RUNS[0] += 1
    history = []
    end = step0 + max_iters
    pending = None
    for i in range(step0, end):
        cur, item = pending if pending is not None else draw(cursor, i)
        pending = None
        if item is None:
            break
        info = dispatch(item)                 # async dispatch
        if prefetch and i + 1 < end:
            nxt_cur, nxt = draw(cur, i + 1)   # overlaps the device step
            if nxt is not None:
                pending = (nxt_cur, stage(nxt))
        imp = float(info.improvement)         # host sync point
        cursor = cur
        history.append(dict(step=i, f_before=float(info.f_before),
                            f_after=float(info.f_after), improvement=imp))
        if early_stop and imp < epsilon:
            break
    return history, cursor


# ---------------------------------------------------------------------------
# Driver 2: the on-device early-stopped loop (one compiled while_loop).


def run_early_stopped_keyed(cfg, step_with_key, state, key: jax.Array):
    """The paper's on-device early-stopped driver, shared by every jitted
    fit path (the single jit plan, the multi-restart engine, the sharded
    while_loop): while i < max_iters and the last improvement >= epsilon,
    advance the unified batch-key stream
    (:func:`repro.api.keys.next_batch_key`) and apply
    ``step_with_key(state, kb) -> (state, improvement)``.
    Returns (state, iters, key) — the carried key resumes the stream
    exactly where the loop stopped (``KernelKMeans.partial_fit``)."""
    _LOOP_RUNS[0] += 1    # bumped at trace time (the device driver)

    def cond(carry):
        _, _, i, imp = carry
        return (i < cfg.max_iters) & (imp >= cfg.epsilon)

    def body(carry):
        state, key, i, _ = carry
        key, kb = api_keys.next_batch_key(key)
        state, imp = step_with_key(state, kb)
        return state, key, i + 1, imp

    init_carry = (state, key, jnp.zeros((), jnp.int32),
                  jnp.full((), jnp.inf, jnp.float32))
    state, key, iters, _ = jax.lax.while_loop(cond, body, init_carry)
    return state, iters, key


def run_early_stopped(cfg, step_with_key, state, key: jax.Array):
    """:func:`run_early_stopped_keyed` without the carried key — the
    historical signature, kept for callers that never resume."""
    state, iters, _ = run_early_stopped_keyed(cfg, step_with_key, state, key)
    return state, iters


# ---------------------------------------------------------------------------
# LoopSpec: the declarative lowering description every executor supplies.


class LoopSpec(NamedTuple):
    """How one solver plan lowers onto the fit-loop core — exactly the
    parts that genuinely differ between families.  Everything else (the
    stage sequence, early stop, prefetch, precision/compress hooks,
    program caching, carry) is the shared core above.  Rendered by
    :func:`stages` / ``KernelKMeans.explain()``."""

    lowering: str           # registered solver name
    driver: str             # 'host' | 'device' | 'stream'
    sampler: str            # how batches are drawn
    step: str               # the step body this lowering supplies
    placement: str          # mesh / sharding description
    donation: tuple         # donated argnums of the main fit program
    hooks: tuple            # active cross-cutting axes (subset of
    #                         'prefetch', 'precision:bf16', 'compress')


_DRIVERS = {
    "host": "host-driven python loop (drive_fit_loop; per-step "
            "improvement sync)",
    "device": "one compiled lax.while_loop (run_early_stopped_keyed; "
              "zero per-step host sync)",
    "stream": "host iterator loop (drive_fit_loop over a batch stream)",
}


def stages(spec: LoopSpec) -> list:
    """The canonical stage sequence of ``spec``'s fit loop, specialized
    with the lowering's own sampler/step/hooks — what
    ``KernelKMeans.explain()`` and ``serve --dry-run`` print."""
    if spec.driver not in _DRIVERS:
        raise ValueError(f"unknown driver {spec.driver!r} "
                         f"(expected one of {sorted(_DRIVERS)})")
    out = ["derive keys (repro.api.keys: one audited derivation tree)",
           f"sample batch [{spec.sampler}]"]
    if "prefetch" in spec.hooks:
        out.append("prefetch next batch (one-deep pipeline, overlaps the "
                   "device step)")
    step = f"step body [{spec.step}]"
    if "precision:bf16" in spec.hooks:
        step += " @ bf16 kernel evals, f32 accumulation"
    out.append(step)
    if "compress" in spec.hooks:
        out.append("compress cadence hook (in-loop landmark projection)")
    out.append(f"early stop via {_DRIVERS[spec.driver]}")
    out.append("carry/telemetry (FitCarry resume key + step history)")
    return out
