"""Multi-restart clustering engine.

Mini-batch (kernel) k-means is a stochastic descent: Tang & Monteleoni's
analysis (and sklearn practice) motivates running R independent restarts
and keeping the best.  Naively that multiplies wall-clock by R; here the
R restarts become ONE compiled program:

* ``fit_restarts`` vmaps the fully-on-device ``fit_jit`` loop (init ->
  while_loop -> early stop) over R PRNG keys and R init index sets.  The
  vmapped ``lax.while_loop`` keeps stepping until every restart has
  terminated (finished lanes are masked), so early-stopping still works
  per-restart.
* Every restart's final centers are scored on one SHARED eval batch
  (``batch_objective``) and the argmin state is selected on-device — the
  host only ever sees the winner.
* With a ``mesh`` the restart axis is sharded across devices: R restarts
  x D devices run in a single compiled program, XLA partitioning the
  batched kernel evaluations over the 'restart' axis.  On top of a
  multi-axis mesh the same engine serves sharded prediction via
  ``repro.core.distributed.predict_distributed``.

``MultiRestartEngine`` is the stateful convenience wrapper (caches the
compiled program across fits of same-shaped data).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.api import keys as api_keys
from repro.core import init as init_lib
from repro.core.kernel_fns import KernelFn, diag_of
from repro.core.loop import run_early_stopped, run_early_stopped_keyed
from repro.core.minibatch import (
    MBConfig, batch_objective, batch_objective_from_rows,
    make_step, sample_batch, sampled_step_with_key,
)
from repro.core.state import CenterState, init_state, window_size

# Auto-enable shared eval-Gram scoring while the (eb, n) row strip stays
# under ~64 MB f32 — beyond that, per-restart recomputation is cheaper than
# the memory.
_SHARED_EVAL_GRAM_MAX_ELEMS = 16 * 2 ** 20


class EngineResult(NamedTuple):
    state: CenterState       # best restart's centers
    objective: jax.Array     # ()  best shared-eval-batch objective
    objectives: jax.Array    # (R,) per-restart eval objectives
    iters: jax.Array         # (R,) iterations each restart ran
    best: jax.Array          # ()  int32 winning restart index


def _restart_axis_of(mesh: Mesh, restart_axis: Optional[str]) -> str:
    if restart_axis is not None:
        return restart_axis
    return mesh.axis_names[0]


def make_init_run(kernel: KernelFn, cfg: MBConfig, init: str = "kmeans++"):
    """Jitted, vmapped per-restart init draw: (ikeys (R, 2), x) -> (R, k)
    center indices.  Cache alongside make_restart_run's program (as
    MultiRestartEngine does) so repeated fits pay no re-trace."""
    if init == "kmeans++":
        def one(kk, x):
            return init_lib.kmeans_plus_plus(kk, x, cfg.k, kernel)
    elif init == "random":
        def one(kk, x):
            return init_lib.random_init(kk, x.shape[0], cfg.k)
    else:
        raise ValueError(init)
    return jax.jit(jax.vmap(one, in_axes=(0, None)))


def _fit_restarts(x: jax.Array, kernel: KernelFn, cfg: MBConfig,
                  key: jax.Array, restarts: int,
                  init: str = "kmeans++",
                  init_idx: Optional[jax.Array] = None,
                  mesh: Optional[Mesh] = None,
                  restart_axis: Optional[str] = None,
                  eval_batch_size: Optional[int] = None,
                  share_eval_gram: Optional[bool] = None,
                  _run=None, _init_run=None) -> EngineResult:
    """Implementation behind :func:`fit_restarts` and the ``multi_restart``
    solver plan (repro.api.executors)."""
    n = x.shape[0]
    k_init, k_fit, k_eval = api_keys.restart_keys(key)
    if init_idx is None:
        ikeys = api_keys.per_restart(k_init, restarts)
        draw = _init_run if _init_run is not None \
            else make_init_run(kernel, cfg, init)
        init_idx = draw(ikeys, x)
    if init_idx.shape[0] != restarts:
        raise ValueError(f"init_idx has {init_idx.shape[0]} rows, "
                         f"expected {restarts}")
    fit_keys = api_keys.per_restart(k_fit, restarts)
    eb = eval_batch_size or min(4 * cfg.batch_size, n)
    eval_idx = sample_batch(k_eval, n, eb)

    if mesh is not None:
        from repro.launch.sharding import restart_placements
        ax = _restart_axis_of(mesh, restart_axis)
        if restarts % mesh.shape[ax]:
            raise ValueError(
                f"restarts={restarts} not divisible by mesh axis "
                f"'{ax}' of size {mesh.shape[ax]}")
        (fit_keys, init_idx), (x, eval_idx) = restart_placements(
            mesh, ax, (fit_keys, init_idx), (x, eval_idx))

    run = _run if _run is not None \
        else make_restart_run(kernel, cfg, share_eval_gram)
    return run(x, fit_keys, init_idx, eval_idx)


def fit_restarts(x: jax.Array, kernel: KernelFn, cfg: MBConfig,
                 key: jax.Array, restarts: int,
                 init: str = "kmeans++",
                 init_idx: Optional[jax.Array] = None,
                 mesh: Optional[Mesh] = None,
                 restart_axis: Optional[str] = None,
                 eval_batch_size: Optional[int] = None,
                 share_eval_gram: Optional[bool] = None,
                 _run=None, _init_run=None) -> EngineResult:
    """Run R independent mini-batch kernel k-means fits in one compiled
    program and return the best (plus per-restart diagnostics).

    .. deprecated::
        Use :class:`repro.api.KernelKMeans` with
        ``SolverConfig(restarts=R)`` — this shim resolves exactly that plan
        and delegates to it (the estimator additionally caches the compiled
        R-restart program across fits, like ``MultiRestartEngine`` does).

    ``init_idx``: optional (R, k) precomputed initial center indices —
    otherwise R independent k-means++ (or random) draws are made, vmapped
    on-device.  With ``mesh``, R must be divisible by the restart-axis size
    (see ``launch.mesh.make_restart_mesh``).
    """
    from repro.api import legacy as _legacy
    _legacy.warn_legacy("repro.core.fit_restarts",
                        "KernelKMeans(SolverConfig(restarts=R))")
    return _legacy.fit_restarts(
        x, kernel, cfg, key, restarts, init=init, init_idx=init_idx,
        mesh=mesh, restart_axis=restart_axis,
        eval_batch_size=eval_batch_size, share_eval_gram=share_eval_gram,
        _run=_run, _init_run=_init_run)


def make_restart_run(kernel: KernelFn, cfg: MBConfig,
                     share_eval_gram: Optional[bool] = None):
    """Build the jitted R-restart program: (x, fit_keys(R,2), init_idx(R,k),
    eval_idx(eb,)) -> EngineResult.  Kernel params are closed over (they are
    array pytrees, so they cannot be static jit args); callers that fit
    repeatedly should cache the returned function — MultiRestartEngine does.

    ``share_eval_gram``: score every restart from ONE precomputed
    K(x_eval, x) row strip (a Gram-tile-cache-style reuse: the strip is
    computed once and each restart's support cross block is a column
    gather) instead of R independent cross-kernel evaluations.  Default
    ``None`` auto-enables while the strip stays small (eb * n <=
    ``_SHARED_EVAL_GRAM_MAX_ELEMS``)."""
    w = window_size(cfg.batch_size, cfg.tau)
    step = make_step(kernel, cfg)

    def fit_one(x, key, idx0):
        state0 = init_state(x, idx0, kernel, w)
        return run_early_stopped(cfg, sampled_step_with_key(step, x, cfg),
                                 state0, key)

    @jax.jit
    def run(x, fit_keys, init_idx, eval_idx):
        states, iters = jax.vmap(
            lambda kk, ii: fit_one(x, kk, ii))(fit_keys, init_idx)
        share = share_eval_gram
        if share is None:
            share = (x.shape[0] * eval_idx.shape[0]
                     <= _SHARED_EVAL_GRAM_MAX_ELEMS)
        if share:
            from repro.core.kernel_fns import kernel_cross
            xe = x[eval_idx]
            gram_rows = kernel_cross(kernel, xe, x)        # (eb, n), once
            diag_e = diag_of(kernel, xe)
            objs = jax.vmap(
                lambda s: batch_objective_from_rows(gram_rows, diag_e,
                                                    s))(states)
        else:
            objs = jax.vmap(
                lambda s: batch_objective(kernel, s, x, eval_idx))(states)
        best = jnp.argmin(objs).astype(jnp.int32)
        best_state = jax.tree.map(lambda a: a[best], states)
        return EngineResult(state=best_state, objective=objs[best],
                            objectives=objs, iters=iters, best=best)

    return run


def make_fused_restart_run(kernel: KernelFn, cfg: MBConfig, mesh: Mesh,
                           restarts: int,
                           data_axes=("data",), model_axis: str = "model",
                           restart_axis: str = "restart",
                           n_valid: Optional[int] = None,
                           eval_size: int = 512,
                           x_real: Optional[jax.Array] = None):
    """Build the jitted fused restart x data x model program — the
    ROADMAP's "one compiled program" for R restarts of the SHARDED step,
    landed behind the ``fused_restart_sharded`` solver registration.

    Composition: the mesh carries a ``restart_axis`` alongside the
    data/model axes; each restart group runs the unchanged shard-local
    sampled Algorithm-2 body (``distributed._make_sampling_body``) in its
    own early-stopped ``lax.while_loop`` — devices of one group share
    bit-identical improvements, so their loop trip counts (and collectives)
    agree, while different groups stop independently with no cross-restart
    sync inside the loop.  Restarts beyond the restart-axis size run as
    sequential lanes on their group (``R_loc = R / r_size``), which is
    exactly R sequential sharded fits per group — trajectories are
    BIT-EXACT against running each restart through
    :func:`distributed.make_dist_sampling_step` with the same key.

    Winner selection runs sharded on one shared eval batch: per-lane
    objectives are psum'd over the data axes, all_gather'd over
    ``restart_axis``, and the argmin state is broadcast back with a masked
    psum — the host only ever sees the winner.

    ``cfg`` must already be the LOOP config (epsilon lowered for
    ``early_stop=False`` — see ``repro.core.loop.loop_config``).  ``eval_size`` is
    the global eval-batch row count (must divide the data shards).

    Uncached (``x_real=None``): returns
    ``run(state0, x, xe, fit_keys) -> EngineResult`` where ``state0`` is
    the restart-stacked coordinate-window DistState, ``x`` the (padded)
    dataset sharded over ``data_axes``, ``xe`` the (eval_size, d) eval
    rows sharded likewise, ``fit_keys`` (R, 2) sharded over
    ``restart_axis``.  Cached (``x_real`` = real coordinates): ``x`` is
    the (n, 1) index-data view, ``state0`` index windows, and the
    signature becomes ``run(state0, caches, x_idx, xe, fit_keys) ->
    (EngineResult, caches)`` with per-(restart, data-shard) tile caches
    from ``init_shard_caches(..., restarts=R)`` (``xe`` stays REAL
    coordinates — scoring resolves window ids through ``x_real``)."""
    from jax.sharding import PartitionSpec as P

    from repro.core import distributed as D
    from repro.core.compat import shard_map
    from repro.core.distributed import DistState
    from repro.core.kernel_fns import kernel_cross, kernel_diag
    data_axes = tuple(data_axes)
    r_size = mesh.shape[restart_axis]
    if restarts % r_size:
        raise ValueError(f"restarts={restarts} not divisible by mesh axis "
                         f"{restart_axis!r} of size {r_size}")
    r_loc = restarts // r_size
    cached = x_real is not None
    body = (D._make_cached_sampling_body(kernel, x_real, cfg, mesh,
                                         data_axes, model_axis, n_valid)
            if cached else
            D._make_sampling_body(kernel, cfg, mesh, data_axes, model_axis,
                                  n_valid))

    def eval_objective(st, xe_loc):
        """Shared-eval-batch objective of one lane's final centers,
        sharded over data (rows) x model (centers)."""
        k_loc, w, d = st.pts.shape
        if cached:
            # index windows: resolve support ids through the real
            # coordinates (``kernel`` is the BASE kernel in cached mode)
            ids = st.pts[..., 0].reshape(-1).astype(jnp.int32)
            sup = x_real[ids]
        else:
            sup = st.pts.reshape(k_loc * w, d)
        cross = kernel_cross(kernel, xe_loc, sup).astype(jnp.float32)
        p = jnp.einsum("bkw,kw->bk",
                       cross.reshape(xe_loc.shape[0], k_loc, w), st.coef)
        diag_e = kernel_diag(kernel, xe_loc).astype(jnp.float32)
        d_loc = diag_e[:, None] - 2.0 * p + st.sqnorm[None, :]
        d_all = jax.lax.all_gather(d_loc, model_axis, axis=1, tiled=True)
        part = jnp.sum(jnp.min(d_all, axis=1))
        for ax in data_axes:
            part = jax.lax.psum(part, ax)
        return part / eval_size

    def select_winner(states, objs_loc, iters_loc):
        """all_gather diagnostics over the restart axis and broadcast the
        argmin lane's (model-sharded) state to every restart group."""
        objs = jax.lax.all_gather(objs_loc, restart_axis, axis=0,
                                  tiled=True)                      # (R,)
        iters = jax.lax.all_gather(iters_loc, restart_axis, axis=0,
                                   tiled=True)                     # (R,)
        best = jnp.argmin(objs).astype(jnp.int32)
        g = jax.lax.axis_index(restart_axis)
        in_group = (best // r_loc) == g
        pick = jnp.where(in_group, best % r_loc, 0)
        win = jax.tree.map(
            lambda a: jax.lax.psum(
                jnp.where(in_group, a[pick], jnp.zeros_like(a[pick])),
                restart_axis),
            states)
        return win, objs, iters, best

    st_stacked = DistState(
        pts=P(restart_axis, model_axis, None, None),
        coef=P(restart_axis, model_axis, None),
        head=P(restart_axis, model_axis),
        sqnorm=P(restart_axis, model_axis),
        counts=P(restart_axis, model_axis),
        step=P(restart_axis))
    st_win = D._state_specs(model_axis)

    def run_lanes(state_st, caches_st, x_loc, xe_loc, keys_loc):
        """The shared per-group driver: each local restart lane runs its
        own early-stopped sharded fit (threading its tile cache through
        the carry when ``caches_st`` is given), then the winner is picked
        across the whole restart axis."""
        states, caches, iters, objs = [], [], [], []
        for lane in range(r_loc):
            st_l = jax.tree.map(lambda a: a[lane], state_st)
            if caches_st is None:
                def swk(st, kb):
                    st, info = body(st, x_loc, kb)
                    return st, info.improvement

                st_f, it_l, _ = run_early_stopped_keyed(
                    cfg, swk, st_l, keys_loc[lane])
            else:
                cc_l = jax.tree.map(lambda a: a[lane], caches_st)

                def swk(carry, kb):
                    st, cc = carry
                    st, cc, info = body(st, cc, x_loc, kb)
                    return (st, cc), info.improvement

                (st_f, cc_l), it_l, _ = run_early_stopped_keyed(
                    cfg, swk, (st_l, cc_l), keys_loc[lane])
                caches.append(cc_l)
            states.append(st_f)
            iters.append(it_l)
            objs.append(eval_objective(st_f, xe_loc))
        states = jax.tree.map(lambda *a: jnp.stack(a), *states)
        win, objs, iters, best = select_winner(states, jnp.stack(objs),
                                               jnp.stack(iters))
        caches_out = (jax.tree.map(lambda *a: jnp.stack(a), *caches)
                      if caches_st is not None else None)
        return win, caches_out, objs, iters, best

    if not cached:
        def fused_local(state_st, x_loc, xe_loc, keys_loc):
            win, _, objs, iters, best = run_lanes(state_st, None, x_loc,
                                                  xe_loc, keys_loc)
            return win, objs, iters, best

        fn = shard_map(
            fused_local, mesh=mesh,
            in_specs=(st_stacked, P(data_axes, None), P(data_axes, None),
                      P(restart_axis, None)),
            out_specs=(st_win, P(), P(), P()),
            check_rep=False)

        # NOTE: state0 is deliberately NOT donated — only the winning
        # lane's (k, ...) state leaves the program, so the stacked
        # (R, k, ...) input can never alias an output and XLA would
        # reject the donation (the while_loop reuses the carry buffers
        # internally regardless)
        @jax.jit
        def run(state0, x, xe, fit_keys):
            win, objs, iters, best = fn(state0, x, xe, fit_keys)
            return EngineResult(state=win, objective=objs[best],
                                objectives=objs, iters=iters, best=best)

        return run

    from repro.cache.tile_cache import GramTileCache

    def fused_local_cached(state_st, caches_st, x_loc, xe_loc, keys_loc):
        return run_lanes(state_st, caches_st, x_loc, xe_loc, keys_loc)

    cache_specs = GramTileCache(
        store=P(restart_axis, data_axes, None, None, None),
        keys=P(restart_axis, data_axes, None),
        stamp=P(restart_axis, data_axes, None),
        clock=P(restart_axis, data_axes),
        hits=P(restart_axis, data_axes),
        misses=P(restart_axis, data_axes),
        evictions=P(restart_axis, data_axes))

    fn = shard_map(
        fused_local_cached, mesh=mesh,
        in_specs=(st_stacked, cache_specs, P(data_axes, None),
                  P(data_axes, None), P(restart_axis, None)),
        out_specs=(st_win, cache_specs, P(), P(), P()),
        check_rep=False)

    # the per-(restart, shard) tile caches round-trip the program with
    # identical shapes — donate them so the whole cache store updates in
    # place (state0 is not donatable: only the winner's (k, ...) slice
    # leaves, see the uncached variant above)
    @functools.partial(jax.jit, donate_argnums=(1,))
    def run(state0, caches0, x_idx, xe, fit_keys):
        win, caches, objs, iters, best = fn(state0, caches0, x_idx, xe,
                                            fit_keys)
        return EngineResult(state=win, objective=objs[best],
                            objectives=objs, iters=iters,
                            best=best), caches

    return run


class MultiRestartEngine:
    """Stateful wrapper: holds (kernel, cfg, restarts, mesh) and exposes
    ``fit`` / ``predict``.  ``mesh=None`` runs all restarts on one device
    (still one compiled program — the vmap batches every kernel matmul);
    with a mesh the restart axis is device-sharded and ``predict`` shards
    query rows for serving."""

    def __init__(self, kernel: KernelFn, cfg: MBConfig, restarts: int = 4,
                 mesh: Optional[Mesh] = None,
                 restart_axis: Optional[str] = None,
                 init: str = "kmeans++",
                 eval_batch_size: Optional[int] = None,
                 share_eval_gram: Optional[bool] = None):
        if restarts < 1:
            raise ValueError("restarts must be >= 1")
        self.kernel = kernel
        self.cfg = cfg
        self.restarts = restarts
        self.mesh = mesh
        self.restart_axis = restart_axis
        self.init = init
        self.eval_batch_size = eval_batch_size
        self.share_eval_gram = share_eval_gram
        self.result: Optional[EngineResult] = None
        self._x: Optional[jax.Array] = None
        self._run = None       # compiled fit program cache
        self._init_run = None  # compiled init-draw cache

    def fit(self, x: jax.Array, key: jax.Array) -> EngineResult:
        """.. deprecated::
            Use :class:`repro.api.KernelKMeans` with
            ``SolverConfig(restarts=R)`` — it caches the compiled program
            the same way and serves ``predict`` through the same paths."""
        from repro.api import legacy as _legacy
        _legacy.warn_legacy("repro.core.engine.MultiRestartEngine.fit",
                            "KernelKMeans(SolverConfig(restarts=R))")
        if self._run is None:
            self._run = make_restart_run(self.kernel, self.cfg,
                                         self.share_eval_gram)
            self._init_run = make_init_run(self.kernel, self.cfg, self.init)
        self.result = _fit_restarts(
            x, self.kernel, self.cfg, key, self.restarts, init=self.init,
            mesh=self.mesh, restart_axis=self.restart_axis,
            eval_batch_size=self.eval_batch_size, _run=self._run,
            _init_run=self._init_run)
        self._x = x
        return self.result

    def predict(self, xq: jax.Array, chunk: int = 4096) -> jax.Array:
        """Assign query points to the best restart's centers.  With a mesh
        the queries are row-sharded over every non-'model' axis (the
        serving path for large query sets)."""
        if self.result is None:
            raise RuntimeError("fit() first")
        from repro.core.minibatch import predict
        if self.mesh is None:
            return predict(self.result.state, self._x, xq, self.kernel,
                           chunk=chunk)
        from repro.core.distributed import predict_distributed
        return predict_distributed(self.result.state, self._x, xq,
                                   self.kernel, self.mesh, chunk=chunk)
