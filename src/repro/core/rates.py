"""Learning-rate schedules for mini-batch (kernel) k-means.

``beta``  — Schwartzman (2023): alpha_i^j = sqrt(b_i^j / b).  Does NOT decay
            to zero; the paper's theory (Theorem 1) requires this rate, and
            §6 shows it also gives better quality in practice.
``sklearn`` — classic Sculley (2010)/sklearn rate: centers are running means,
            alpha_i^j = b_i^j / (c_j + b_i^j) where c_j counts every point
            ever assigned to j.  Decays to zero over time.

Both are pure functions of (batch counts, historical counts, batch size) so
they live inside jit'd steps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def beta_rate(batch_counts: jax.Array, total_counts: jax.Array,
              batch_size: int) -> jax.Array:
    del total_counts
    return jnp.sqrt(batch_counts.astype(jnp.float32) / batch_size)


def sklearn_rate(batch_counts: jax.Array, total_counts: jax.Array,
                 batch_size: int) -> jax.Array:
    del batch_size
    bc = batch_counts.astype(jnp.float32)
    denom = jnp.maximum(total_counts.astype(jnp.float32) + bc, 1.0)
    return bc / denom


RATES = {"beta": beta_rate, "sklearn": sklearn_rate}


def get_rate(name: str):
    try:
        return RATES[name]
    except KeyError:
        raise ValueError(f"unknown learning rate {name!r}; options {list(RATES)}")
