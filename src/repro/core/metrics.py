"""Clustering quality metrics (ARI, NMI) — sklearn is unavailable offline,
so these are self-contained numpy/jnp implementations matching sklearn's
definitions (NMI uses the 'arithmetic' average, sklearn's default)."""
from __future__ import annotations

import numpy as np


def _contingency(labels_true: np.ndarray, labels_pred: np.ndarray) -> np.ndarray:
    lt = np.asarray(labels_true).ravel()
    lp = np.asarray(labels_pred).ravel()
    _, ti = np.unique(lt, return_inverse=True)
    _, pi = np.unique(lp, return_inverse=True)
    nt = ti.max() + 1
    npred = pi.max() + 1
    cm = np.zeros((nt, npred), dtype=np.int64)
    np.add.at(cm, (ti, pi), 1)
    return cm


def adjusted_rand_index(labels_true, labels_pred) -> float:
    """ARI (Rand 1971; Hubert & Arabie correction) — as used in the paper."""
    cm = _contingency(labels_true, labels_pred)
    n = cm.sum()
    if n <= 1:
        return 1.0
    sum_comb_c = (cm * (cm - 1) // 2).sum()
    a = cm.sum(axis=1)
    b = cm.sum(axis=0)
    sum_comb_a = (a * (a - 1) // 2).sum()
    sum_comb_b = (b * (b - 1) // 2).sum()
    total = n * (n - 1) // 2
    expected = sum_comb_a * sum_comb_b / total
    max_index = (sum_comb_a + sum_comb_b) / 2.0
    if max_index == expected:
        return 1.0
    return float((sum_comb_c - expected) / (max_index - expected))


def _entropy(counts: np.ndarray) -> float:
    p = counts[counts > 0].astype(np.float64)
    p = p / p.sum()
    return float(-(p * np.log(p)).sum())


def normalized_mutual_info(labels_true, labels_pred) -> float:
    """NMI with arithmetic-mean normalization (sklearn default)."""
    cm = _contingency(labels_true, labels_pred).astype(np.float64)
    n = cm.sum()
    if n == 0:
        return 0.0
    pi = cm.sum(axis=1)
    pj = cm.sum(axis=0)
    nz = cm > 0
    outer = np.outer(pi, pj)
    mi = (cm[nz] / n * (np.log(cm[nz] * n) - np.log(outer[nz]))).sum()
    hi, hj = _entropy(pi), _entropy(pj)
    denom = 0.5 * (hi + hj)
    if denom <= 0:
        return 1.0 if mi == 0 else 0.0
    return float(max(mi, 0.0) / denom)
