"""Version shims for JAX APIs that moved between releases.

The repo targets a range of JAX versions:

* ``shard_map`` lives at ``jax.experimental.shard_map.shard_map`` up to
  ~0.4.x, is promoted to ``jax.shard_map`` later, and along the way the
  replication-checking kwarg was renamed ``check_rep`` -> ``check_vma``.
  ``compat.shard_map`` accepts either spelling and forwards whichever one
  the installed JAX understands.

Import this module — never ``jax.shard_map`` directly — everywhere a
sharded program is built (core/distributed.py, core/engine.py,
launch/*).
"""
from __future__ import annotations

import inspect
from typing import Any, Callable

import jax


def _resolve_shard_map() -> Callable[..., Any]:
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        return fn
    from jax.experimental.shard_map import shard_map as fn  # noqa: F811
    return fn


_SHARD_MAP = _resolve_shard_map()
# The replication-check kwarg name understood by the installed JAX
# (None if the installed signature has neither — then we drop the flag).
_CHECK_KW = next(
    (kw for kw in ("check_vma", "check_rep")
     if kw in inspect.signature(_SHARD_MAP).parameters),
    None)


def shard_map(f: Callable[..., Any], *, mesh, in_specs, out_specs,
              check_vma: bool | None = None,
              check_rep: bool | None = None) -> Callable[..., Any]:
    """Drop-in for ``jax.shard_map`` that runs on old and new JAX.

    ``check_vma`` and ``check_rep`` are aliases; pass at most one.
    """
    if check_vma is not None and check_rep is not None:
        raise TypeError("pass at most one of check_vma / check_rep")
    flag = check_vma if check_vma is not None else check_rep
    kwargs = {}
    if flag is not None and _CHECK_KW is not None:
        kwargs[_CHECK_KW] = flag
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)
