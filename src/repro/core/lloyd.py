"""Non-kernel baselines the paper compares against (§6): plain Lloyd
k-means and mini-batch k-means with both learning rates.  Centers are
explicit (k, d) vectors here."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.minibatch import sample_batch
from repro.core.rates import get_rate


def _dists(x, centers):
    xx = jnp.sum(x * x, axis=-1)[:, None]
    cc = jnp.sum(centers * centers, axis=-1)[None, :]
    return jnp.maximum(xx + cc - 2.0 * x @ centers.T, 0.0)


def _pp_init(key, x, k):
    """Standard (Euclidean) k-means++."""
    n = x.shape[0]
    k0, key = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)

    def body(t, carry):
        mind, chosen, key = carry
        key, sub = jax.random.split(key)
        p = mind / jnp.maximum(jnp.sum(mind), 1e-30)
        nxt = jax.random.choice(sub, n, p=p)
        chosen = chosen.at[t].set(nxt)
        d = jnp.sum((x - x[nxt]) ** 2, axis=-1)
        return jnp.minimum(mind, d), chosen, key

    chosen = jnp.zeros((k,), jnp.int32).at[0].set(first)
    mind = jnp.sum((x - x[first]) ** 2, axis=-1)
    _, chosen, _ = jax.lax.fori_loop(1, k, body, (mind, chosen, key))
    return x[chosen]


def kmeans_fit(x, k, key, max_iters=100, init="kmeans++"):
    centers = (_pp_init(key, x, k) if init == "kmeans++"
               else x[jax.random.choice(key, x.shape[0], (k,), replace=False)])

    @jax.jit
    def step(centers, assign_prev):
        d = _dists(x, centers)
        assign = jnp.argmin(d, axis=1).astype(jnp.int32)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)
        counts = jnp.sum(onehot, axis=0)
        sums = onehot.T @ x
        new_centers = jnp.where(counts[:, None] > 0,
                                sums / jnp.maximum(counts, 1.0)[:, None],
                                centers)
        obj = jnp.mean(jnp.min(d, axis=1))
        return new_centers, assign, obj, jnp.sum(assign != assign_prev)

    assign = -jnp.ones((x.shape[0],), jnp.int32)
    history = []
    for i in range(max_iters):
        centers, assign, obj, moved = step(centers, assign)
        history.append(dict(step=i, objective=float(obj), moved=int(moved)))
        if int(moved) == 0:
            break
    return centers, assign, history


def minibatch_kmeans_fit(x, k, key, batch_size=1024, rate="beta",
                         max_iters=200, epsilon=0.0, init="kmeans++",
                         early_stop=False):
    """Sculley-style mini-batch k-means; rate in {'beta','sklearn'} — the
    experiment the paper runs to fill Schwartzman (2023)'s empirical gap."""
    rate_fn = get_rate(rate)
    n = x.shape[0]
    kinit, key = jax.random.split(key)
    centers = (_pp_init(kinit, x, k) if init == "kmeans++"
               else x[jax.random.choice(kinit, n, (k,), replace=False)])

    @jax.jit
    def step(centers, counts, bidx):
        xb = x[bidx]
        d = _dists(xb, centers)
        f_before = jnp.mean(jnp.min(d, axis=1))
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)
        bj = jnp.sum(onehot, axis=0)
        alpha = rate_fn(bj, counts, batch_size)
        cm = (onehot.T @ xb) / jnp.maximum(bj, 1.0)[:, None]
        new_centers = jnp.where(
            bj[:, None] > 0,
            (1.0 - alpha)[:, None] * centers + alpha[:, None] * cm,
            centers)
        f_after = jnp.mean(jnp.min(_dists(xb, new_centers), axis=1))
        return new_centers, counts + bj, f_before - f_after

    counts = jnp.zeros((k,), x.dtype)
    history = []
    for i in range(max_iters):
        key, kb = jax.random.split(key)
        bidx = sample_batch(kb, n, batch_size)
        centers, counts, imp = step(centers, counts, bidx)
        history.append(dict(step=i, improvement=float(imp)))
        if early_stop and float(imp) < epsilon:
            break
    assign = jnp.argmin(_dists(x, centers), axis=1).astype(jnp.int32)
    return centers, assign, history
