"""Full-batch kernel k-means (Lloyd in feature space) — the paper's baseline.

Distances to the implicit centers c_j = cm(A_j):
    d(x, c_j) = K(x,x) - 2 (K M)[x,j] + q_j,
where M is the column-normalized membership matrix and
q_j = (M^T K M)[j,j].  The n x n kernel matrix is the O(n^2) bottleneck the
paper is attacking; we never materialize it — rows are streamed in chunks
(pure-jnp `lax.map` here; the Pallas `kernel_matmul` kernel on TPU).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import init as init_lib
from repro.core.kernel_fns import KernelFn, kernel_cross, kernel_diag


class FBInfo(NamedTuple):
    objective: jax.Array
    moved: jax.Array


def kernel_matmul_chunked(kernel: KernelFn, x: jax.Array, y: jax.Array,
                          v: jax.Array, chunk: int = 2048) -> jax.Array:
    """(K(x, y) @ v) without materializing K — row-chunked streaming.
    x:(n,d) y:(m,d) v:(m,c) -> (n,c)."""
    n = x.shape[0]
    pad = (-n) % chunk
    xp = jnp.pad(x, ((0, pad), (0, 0)))

    def one(xc):
        return kernel_cross(kernel, xc, y) @ v

    out = jax.lax.map(one, xp.reshape(-1, chunk, x.shape[1]))
    return out.reshape(-1, v.shape[1])[:n]


def make_fullbatch_step(kernel: KernelFn, k: int, use_pallas: bool = False,
                        chunk: int = 2048):
    def step(assign: jax.Array, x: jax.Array):
        n = x.shape[0]
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)      # (n, k)
        counts = jnp.sum(onehot, axis=0)
        mn = onehot / jnp.maximum(counts, 1.0)[None, :]
        if use_pallas:
            from repro.kernels import ops as kops
            km = kops.kernel_matmul(kernel, x, x, mn)
        else:
            km = kernel_matmul_chunked(kernel, x, x, mn, chunk)    # (n, k)
        q = jnp.sum(mn * km, axis=0)                               # (k,)
        d = kernel_diag(kernel, x)[:, None] - 2.0 * km + q[None, :]
        # empty clusters die (their distance column is +inf)
        d = jnp.where(counts[None, :] > 0, d, jnp.inf)
        new_assign = jnp.argmin(d, axis=1).astype(jnp.int32)
        obj = jnp.mean(jnp.min(d, axis=1))
        moved = jnp.sum(new_assign != assign)
        return new_assign, FBInfo(objective=obj, moved=moved)

    return step


def fit(x: jax.Array, kernel: KernelFn, k: int, key: jax.Array,
        max_iters: int = 100, init: str = "kmeans++", tol_moved: int = 0,
        use_pallas: bool = False):
    """Classic Lloyd loop: stops when no point moves (or max_iters)."""
    n = x.shape[0]
    if init == "kmeans++":
        cidx = init_lib.kmeans_plus_plus(key, x, k, kernel)
    else:
        cidx = init_lib.random_init(key, n, k)
    # initial assignment: nearest initial center point
    cross = kernel_cross(kernel, x, x[cidx])
    d0 = (kernel_diag(kernel, x)[:, None] - 2.0 * cross
          + kernel_diag(kernel, x[cidx])[None, :])
    assign = jnp.argmin(d0, axis=1).astype(jnp.int32)

    step = jax.jit(make_fullbatch_step(kernel, k, use_pallas))
    history = []
    for i in range(max_iters):
        assign, info = step(assign, x)
        history.append(dict(step=i, objective=float(info.objective),
                            moved=int(info.moved)))
        if int(info.moved) <= tol_moved:
            break
    return assign, history


def objective(x: jax.Array, kernel: KernelFn, assign: jax.Array,
              k: int) -> jax.Array:
    """f_X for a given partition (centers = cluster means in feature space)."""
    onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)
    counts = jnp.sum(onehot, axis=0)
    mn = onehot / jnp.maximum(counts, 1.0)[None, :]
    km = kernel_matmul_chunked(kernel, x, x, mn)
    q = jnp.sum(mn * km, axis=0)
    d = kernel_diag(kernel, x)[:, None] - 2.0 * km + q[None, :]
    d = jnp.where(counts[None, :] > 0, d, jnp.inf)
    return jnp.mean(jnp.min(d, axis=1))
