"""Pallas kernel: assignment dots from cache-resolved Gram rows.

Computes   P[i, j] = sum_w coef[j, w] * rows[i, sup_ids[j, w]]
where ``rows`` are the batch's Gram rows K(x_B, x) already resolved through
the Gram tile cache (repro.cache) — so the assignment step of Algorithm 2
performs ZERO kernel evaluations: this kernel fuses the support-column
gather with the coefficient contraction, never materializing the
(b, k*W) cross block in HBM.

TPU mapping (mirrors fused_assign.py):
* grid = (k, b/bt, W/st); the innermost axis streams support-id tiles.
* Each step: gather a (bt, st) sub-block out of the resident (bt, n) row
  tile with a dynamic column take, then contract with the (st,) coefficient
  slice into the (bt, 1) output block.
* VMEM working set per step: bt*n (row tile) + bt*st + st floats — the row
  tile dominates; bt=128 x n=8192 f32 = 4 MB, inside the ~16 MB budget.

The dynamic minor-dimension gather is interpret-mode-verified on CPU (the
repo's convention, tests/test_pallas_kernels.py); TPU-native tuning rides
the existing "TPU-native validation" roadmap item.  Pad slots (coef == 0)
gather column 0 harmlessly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_body(rows_ref, ids_ref, coef_ref, out_ref):
    iw = pl.program_id(2)

    @pl.when(iw == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    r = rows_ref[...].astype(jnp.float32)       # (bt, n)
    ci = ids_ref[0]                             # (st,) int32 column ids
    sub = jnp.take(r, ci, axis=1)               # (bt, st) dynamic gather
    c = coef_ref[0].astype(jnp.float32)         # (st,)
    out_ref[:, 0] += sub @ c


@functools.partial(jax.jit, static_argnames=("bt", "st", "interpret"))
def cached_assign_dots_pallas(rows: jax.Array, sup_ids: jax.Array,
                              coef: jax.Array, *, bt: int = 128,
                              st: int = 128,
                              interpret: bool = False) -> jax.Array:
    """rows: (b, n) f32; sup_ids: (k, W) int32; coef: (k, W) -> P (b, k)."""
    b, n = rows.shape
    k, w = coef.shape

    bp = -b % bt
    wp = -w % st
    rows_p = jnp.pad(rows, ((0, bp), (0, 0)))
    ids_p = jnp.pad(sup_ids.astype(jnp.int32), ((0, 0), (0, wp)))
    coef_p = jnp.pad(coef, ((0, 0), (0, wp)))

    bb = rows_p.shape[0]
    ww = ids_p.shape[1]
    grid = (k, bb // bt, ww // st)

    out = pl.pallas_call(
        _gather_body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, n), lambda j, ib, iw: (ib, 0)),
            pl.BlockSpec((1, st), lambda j, ib, iw: (j, iw)),
            pl.BlockSpec((1, st), lambda j, ib, iw: (j, iw)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda j, ib, iw: (ib, j)),
        out_shape=jax.ShapeDtypeStruct((bb, k), jnp.float32),
        interpret=interpret,
    )(rows_p, ids_p, coef_p)
    return out[:b]
