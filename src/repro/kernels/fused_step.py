"""Streaming fused mini-batch step kernels (the `step="fused"` impl).

The composed Algorithm-2 step materializes the (b, k*W) batch x window
cross-kernel strip AND the (b, k) distance matrix in f32 HBM between
kernel launches, so per-step wall clock is bandwidth-bound.  The fused
step streams support tiles through on-chip memory and keeps only
flash-attention-style ONLINE ARGMIN carries — a running best distance and
best center index per batch row — so neither strip ever exists off-chip.

Two implementations, dispatched by :mod:`repro.kernels.ops`:

* ``streaming_assign_pallas`` — the Pallas TPU kernel.  Grid
  ``(b/bt, k, W/st)``: the innermost axis streams (st, d) support tiles
  of one center's window through VMEM, accumulating the coefficient
  contraction into a (bt, 1) VMEM scratch; at the last window tile the
  center's distances fold into the resident best/argmin output blocks.
  VMEM working set per step: bt*d + st*d + bt*st + O(bt) floats — the
  (b, k*W) strip and (b, k) distances never touch HBM.  Mixed precision:
  ``precision="bf16"`` casts the coordinate tiles to bfloat16 before the
  MXU matmul; the cross products, kernel elementwise math, coefficient
  contraction and argmin carries all stay f32 (the Schwartzman'23 regime:
  low-precision evals, full-precision accumulation).

* ``streaming_assign_xla`` / ``streaming_dists_xla`` /
  ``streaming_min_xla`` — the structural XLA fallback used on non-TPU
  backends (and for kernels without an MXU form, e.g. Laplacian or the
  index-data cached kernels).  An UNROLLED loop over center chunks runs
  exactly the composed path's per-chunk ops (same ``kernel_cross`` +
  einsum + distance expression) and folds each chunk into the running
  best/argmin.  Because every chunk repeats the composed arithmetic on a
  >= 2-center slab (1-center slabs change XLA's gemm lowering), the
  result is BIT-IDENTICAL to the composed step at f32 — the equivalence
  the grid sweep in tests/test_api_grid.py pins — while never holding
  more than one (b, kc*W) slab live.

Tile defaults and the per-backend tuning story live in docs/perf.md.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.kernel_fns import KernelFn, is_index_data, kernel_cross
from repro.kernels.fused_assign import _apply_kernel

# Center-chunk width of the XLA fallback: one (b, kc*W) slab live at a
# time.  Chunks are never narrower than 2 centers — XLA lowers a
# single-center (b, W) gemm differently from a k-center slab, which would
# break bit-identity with the composed path (measured, not hypothetical).
STREAM_CHUNK = 8
_MIN_CHUNK = 2


def center_chunks(k: int, kc: int = STREAM_CHUNK):
    """Static (start, width) chunking of k centers with no width-1 chunk
    (a trailing remainder of 1 is merged into the previous chunk)."""
    kc = max(kc, _MIN_CHUNK)
    if k <= kc:
        return [(0, k)]
    chunks = []
    j0 = 0
    while j0 < k:
        kk = min(kc, k - j0)
        if k - (j0 + kk) == 1:          # never leave a width-1 remainder
            kk += 1
        chunks.append((j0, kk))
        j0 += kk
    return chunks


def _precision_cast(kernel: KernelFn, precision: str):
    """Coordinate cast applied before kernel evaluation.  bf16 only ever
    touches COORDINATES: index-data kernels (Precomputed / CachedKernel)
    carry row ids as data, which a cast would corrupt, so they always
    evaluate at full precision."""
    if precision in ("f32", "float32") or is_index_data(kernel):
        return lambda a: a
    if precision in ("bf16", "bfloat16"):
        return lambda a: a.astype(jnp.bfloat16)
    raise ValueError(f"precision={precision!r} (expected 'f32' or 'bf16')")


_HAS_BARRIER = None     # tri-state: unprobed / usable / unusable


def _register_barrier_batching() -> bool:
    """jax 0.4.x ships no vmap batching rule for ``optimization_barrier``
    — but the multi-restart engine vmaps the whole step, so the slab
    loop's barriers would make ``restarts>1`` untraceable.  The barrier
    is elementwise identity, so its batching rule is the trivial
    passthrough; register it (idempotently) and fall back to no barriers
    at all if the private registry moves in a future jax.  Called
    LAZILY from the first fused-step trace, never at import — importing
    repro.kernels must not mutate jax process globals for programs that
    never run the fused step."""
    try:
        from jax._src.interpreters import batching
        from jax._src.lax import lax as lax_internal

        prim = lax_internal.optimization_barrier_p
        if prim not in batching.primitive_batchers:
            batching.primitive_batchers[prim] = \
                lambda args, dims: (prim.bind(*args), dims)
        return True
    except Exception:                                   # pragma: no cover
        import warnings

        warnings.warn(
            "repro.kernels.fused_step: could not make "
            "lax.optimization_barrier vmap-safe on this jax; the fused "
            "step stays numerically exact but loses its slab-scheduling "
            "hint (peak memory may match the composed step)",
            RuntimeWarning, stacklevel=3)
        return False


def _soft_barrier(args):
    """``lax.optimization_barrier``: sequences the slab loop so XLA's
    scheduler cannot hoist every slab's gemm ahead of the running-min
    chain (which would re-materialize the full strip and erase the
    streaming memory win).  Identity on VALUES — bit-identity with the
    composed path is untouched; on a jax whose barrier cannot be made
    vmap-safe it degrades to a plain identity (scheduling hint lost,
    numerics unchanged, one-time warning)."""
    global _HAS_BARRIER
    if _HAS_BARRIER is None:
        _HAS_BARRIER = _register_barrier_batching()
    if not _HAS_BARRIER:                                # pragma: no cover
        return args
    return jax.lax.optimization_barrier(args)


def _chunk_dists(kernel, cast, xb, sup, coef, sqnorm, diag_b, j0, kk):
    """The composed path's distance block for centers [j0, j0+kk): the
    exact op sequence of ``minibatch._batch_center_dots`` + the distance
    expression, restricted to a center slab."""
    b = xb.shape[0]
    k, w = coef.shape
    sup_c = sup.reshape(k, w, sup.shape[-1])[j0:j0 + kk].reshape(kk * w, -1)
    cross = kernel_cross(kernel, cast(xb), cast(sup_c)).astype(jnp.float32)
    p = jnp.einsum("bkw,kw->bk", cross.reshape(b, kk, w), coef[j0:j0 + kk])
    return diag_b[:, None] - 2.0 * p + sqnorm[None, j0:j0 + kk]


def streaming_assign_xla(kernel: KernelFn, xb: jax.Array, sup: jax.Array,
                         coef: jax.Array, sqnorm: jax.Array,
                         diag_b: jax.Array, *, kc: int = STREAM_CHUNK,
                         precision: str = "f32"):
    """(best, assign): running min distance (b,) f32 and argmin center
    (b,) int32 over all k centers, one (b, kc*W) slab at a time.

    ``lax.optimization_barrier`` threads the batch through the carry
    between slabs: without it XLA's scheduler hoists every slab's gemm
    ahead of the min chain (the slabs have no data dependence on each
    other), which re-materializes the full strip and erases the streaming
    memory win.  The barrier is identity on values, so bit-identity with
    the composed path is untouched."""
    k, _ = coef.shape
    cast = _precision_cast(kernel, precision)
    best = bidx = None
    for j0, kk in center_chunks(k, kc):
        dd = _chunk_dists(kernel, cast, xb, sup, coef, sqnorm, diag_b,
                          j0, kk)
        cmin = jnp.min(dd, axis=1)
        cidx = jnp.argmin(dd, axis=1).astype(jnp.int32) + j0
        if best is None:
            best, bidx = cmin, cidx
        else:
            upd = cmin < best                  # strict: first-min ties,
            best = jnp.where(upd, cmin, best)  # same as jnp.argmin's
            bidx = jnp.where(upd, cidx, bidx)
        best, bidx, xb = _soft_barrier((best, bidx, xb))
    return best, bidx


def streaming_min_xla(kernel: KernelFn, xb: jax.Array, sup: jax.Array,
                      coef: jax.Array, sqnorm: jax.Array,
                      diag_b: jax.Array, *, kc: int = STREAM_CHUNK,
                      precision: str = "f32") -> jax.Array:
    """Running min distance only — the post-update objective pass."""
    k, _ = coef.shape
    cast = _precision_cast(kernel, precision)
    best = None
    for j0, kk in center_chunks(k, kc):
        dd = _chunk_dists(kernel, cast, xb, sup, coef, sqnorm, diag_b,
                          j0, kk)
        cmin = jnp.min(dd, axis=1)
        best = cmin if best is None else jnp.minimum(best, cmin)
        best, xb = _soft_barrier((best, xb))
    return best


def streaming_dists_xla(kernel: KernelFn, xb: jax.Array, sup: jax.Array,
                        coef: jax.Array, sqnorm: jax.Array,
                        diag_b: jax.Array, *, kc: int = STREAM_CHUNK,
                        precision: str = "f32") -> jax.Array:
    """Full (b, k) distance block, computed slab-by-slab.  The sharded
    local step needs the materialized block for its model-axis all_gather
    — (b_loc, k_loc) is small; the win is never holding the (b_loc,
    k_loc*W) strip.  The same barrier chain as
    :func:`streaming_assign_xla` keeps the slabs sequential."""
    k, _ = coef.shape
    cast = _precision_cast(kernel, precision)
    out = []
    for j0, kk in center_chunks(k, kc):
        dd = _chunk_dists(kernel, cast, xb, sup, coef, sqnorm, diag_b,
                          j0, kk)
        dd, xb = _soft_barrier((dd, xb))
        out.append(dd)
    return jnp.concatenate(out, axis=1)


def streamed_sqnorm(kernel: KernelFn, x: jax.Array, idx: jax.Array,
                    coef: jax.Array, *, kc: int = STREAM_CHUNK,
                    compute_dtype=None) -> jax.Array:
    """<C_j, C_j> recompute over INDEX windows, center-chunked and
    barrier-chained: per-center op sequence identical to
    ``minibatch._sqnorm_recompute`` (bit-identical results), but only one
    (kc, W, W) Gram slab is ever live instead of the full (k, W, W) stack
    — at production shapes this is the step's LARGEST allocation, so
    streaming it is what actually lowers the fused step's peak memory.
    Callers must route gram_rows-capable kernels to the composed
    recompute instead (one bulk row lookup beats per-chunk lookups)."""
    k = idx.shape[0]

    def one(idx_row, coef_row):
        pts = x[idx_row]                                       # (W, d)
        if compute_dtype is not None:
            pts = pts.astype(compute_dtype)
        g = kernel_cross(kernel, pts, pts)                     # (W, W)
        if compute_dtype is not None:
            g = g.astype(jnp.float32)
        return coef_row @ (g @ coef_row)

    outs = []
    for j0, kk in center_chunks(k, kc):
        o = jax.vmap(one)(idx[j0:j0 + kk], coef[j0:j0 + kk])
        o, x = _soft_barrier((o, x))
        outs.append(o)
    return jnp.concatenate(outs)


def streamed_sqnorm_pts(kernel: KernelFn, pts: jax.Array, coef: jax.Array,
                        *, kc: int = STREAM_CHUNK,
                        compute_dtype=None) -> jax.Array:
    """:func:`streamed_sqnorm` over COORDINATE windows (k, W, d) — the
    sharded step's layout; per-center ops identical to the paper-faithful
    branch of ``distributed._make_local_step``."""
    k = pts.shape[0]

    def one(pts_row, coef_row):
        p = pts_row if compute_dtype is None \
            else pts_row.astype(compute_dtype)
        g = kernel_cross(kernel, p, p)
        return coef_row @ (g.astype(jnp.float32) @ coef_row)

    outs = []
    for j0, kk in center_chunks(k, kc):
        o = jax.vmap(one)(pts[j0:j0 + kk], coef[j0:j0 + kk])
        o, pts = _soft_barrier((o, pts))
        outs.append(o)
    return jnp.concatenate(outs)


# ---------------------------------------------------------------- Pallas
def _stream_body(x_ref, xsq_ref, diag_ref, sup_ref, supsq_ref, coef_ref,
                 sqn_ref, best_ref, idx_ref, p_acc, *, kind, p0, p1, p2,
                 bf16):
    j = pl.program_id(1)
    iw = pl.program_id(2)
    nw = pl.num_programs(2)

    @pl.when(iw == 0)
    def _init_acc():
        p_acc[...] = jnp.zeros_like(p_acc)

    x = x_ref[...]
    s = sup_ref[0]
    if bf16:
        x = x.astype(jnp.bfloat16)
        s = s.astype(jnp.bfloat16)
    xy = jax.lax.dot_general(x, s, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    kv = _apply_kernel(xy, xsq_ref[...].astype(jnp.float32),
                       supsq_ref[0].astype(jnp.float32), kind, p0, p1, p2)
    p_acc[:, 0] += kv @ coef_ref[0].astype(jnp.float32)

    @pl.when(iw == nw - 1)
    def _fold():
        d = diag_ref[...].astype(jnp.float32) - 2.0 * p_acc[:, 0] \
            + sqn_ref[0]
        first = j == 0
        prev = jnp.where(first, jnp.full_like(d, jnp.inf), best_ref[:, 0])
        prev_i = jnp.where(first, jnp.zeros_like(idx_ref[:, 0]),
                           idx_ref[:, 0])
        upd = d < prev
        best_ref[:, 0] = jnp.where(upd, d, prev)
        idx_ref[:, 0] = jnp.where(upd, jnp.full_like(prev_i, j), prev_i)


@functools.partial(jax.jit, static_argnames=(
    "kind", "p0", "p1", "p2", "bt", "st", "bf16", "interpret"))
def streaming_assign_pallas(
        xb: jax.Array, sup: jax.Array, coef: jax.Array, sqnorm: jax.Array,
        diag_b: jax.Array, *, kind: str = "gaussian", p0: float = 1.0,
        p1: float = 1.0, p2: int = 2, bt: int = 128, st: int = 128,
        bf16: bool = False, interpret: bool = False):
    """xb (b, d); sup (k, W, d); coef (k, W); sqnorm (k,); diag_b (b,)
    -> (best (b,) f32, assign (b,) int32).

    b / W / d are padded to tile multiples (zero support points with zero
    coefficients contribute nothing; padded batch rows are sliced off).
    The online-argmin outputs live in (bt, 1) blocks revisited across the
    two innermost grid axes — never written back per center."""
    from jax.experimental.pallas import tpu as pltpu

    b, d = xb.shape
    k, w, _ = sup.shape
    bp, wp, dp = -b % bt, -w % st, -d % 128
    xb_p = jnp.pad(xb, ((0, bp), (0, dp)))
    sup_p = jnp.pad(sup, ((0, 0), (0, wp), (0, dp)))
    coef_p = jnp.pad(coef, ((0, 0), (0, wp)))
    diag_p = jnp.pad(diag_b, (0, bp))
    xsq = jnp.sum(xb_p.astype(jnp.float32) ** 2, axis=-1)
    supsq = jnp.sum(sup_p.astype(jnp.float32) ** 2, axis=-1)

    bb, dd = xb_p.shape
    ww = sup_p.shape[1]
    grid = (bb // bt, k, ww // st)

    best, idx = pl.pallas_call(
        functools.partial(_stream_body, kind=kind, p0=p0, p1=p1, p2=p2,
                          bf16=bf16),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, dd), lambda ib, j, iw: (ib, 0)),
            pl.BlockSpec((bt,), lambda ib, j, iw: (ib,)),
            pl.BlockSpec((bt,), lambda ib, j, iw: (ib,)),
            pl.BlockSpec((1, st, dd), lambda ib, j, iw: (j, iw, 0)),
            pl.BlockSpec((1, st), lambda ib, j, iw: (j, iw)),
            pl.BlockSpec((1, st), lambda ib, j, iw: (j, iw)),
            pl.BlockSpec((1,), lambda ib, j, iw: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((bt, 1), lambda ib, j, iw: (ib, 0)),
            pl.BlockSpec((bt, 1), lambda ib, j, iw: (ib, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bb, 1), jnp.float32),
            jax.ShapeDtypeStruct((bb, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((bt, 1), jnp.float32)],
        interpret=interpret,
    )(xb_p, xsq, diag_p, sup_p, supsq, coef_p, sqnorm)
    return best[:b, 0], idx[:b, 0]
