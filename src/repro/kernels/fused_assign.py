"""Pallas TPU kernel: fused kernel-distance x coefficient contraction.

Computes   P[i, j] = sum_w coef[j, w] * K(xb[i], sup[j, w])
— the hot loop of Algorithm 2's assignment step (Theorem 1(1)'s O(k b (tau+b))
term) — WITHOUT materializing the (b, k*W) cross-kernel matrix in HBM.

TPU mapping (see DESIGN.md §5):
* grid = (k, b/bt, W/st); the innermost axis streams support tiles.
* Each step: one (bt, d) x (d, st) MXU matmul for the cross products, VPU
  exp for the Gaussian, then a (bt, st) x (st,) contraction with the
  coefficient slice accumulated into the resident (bt, 1) output block.
* VMEM working set per step: bt*d + st*d + bt*st + bt floats
  (= 128*512*4 * 2 + 128*128*4 + small ≈ 0.6 MB at the default tiles —
  comfortably inside the ~16 MB VMEM budget, leaving room for
  double-buffered prefetch of the next support tile).
* Supported kernels: gaussian / linear / polynomial (MXU-friendly);
  laplacian needs an L1 distance (no matmul form) and falls back to the
  XLA path in ops.py.

Block sizes are parameters; tests sweep small tiles in interpret mode, the
TPU default is (128, 128) with d padded to a multiple of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _apply_kernel(xy, xsq, ysq, kind: str, p0: float, p1: float, p2: int):
    """Elementwise kernel from cross products + squared norms (f32)."""
    if kind == "gaussian":
        d2 = jnp.maximum(xsq[:, None] + ysq[None, :] - 2.0 * xy, 0.0)
        return jnp.exp(-d2 / p0)
    if kind == "linear":
        return xy
    if kind == "polynomial":
        return (xy / p1 + p0) ** p2
    raise ValueError(kind)


def _fused_body(x_ref, xsq_ref, sup_ref, supsq_ref, coef_ref, out_ref,
                *, kind, p0, p1, p2):
    iw = pl.program_id(2)

    @pl.when(iw == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # (bt, d)
    s = sup_ref[0].astype(jnp.float32)          # (st, d)
    xy = jax.lax.dot_general(x, s, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (bt, st)
    kv = _apply_kernel(xy, xsq_ref[...].astype(jnp.float32),
                       supsq_ref[0].astype(jnp.float32), kind, p0, p1, p2)
    c = coef_ref[0].astype(jnp.float32)         # (st,)
    out_ref[:, 0] += kv @ c


@functools.partial(jax.jit, static_argnames=(
    "kind", "p0", "p1", "p2", "bt", "st", "interpret"))
def fused_batch_center_dots_pallas(
        xb: jax.Array, sup: jax.Array, coef: jax.Array, *,
        kind: str = "gaussian", p0: float = 1.0, p1: float = 1.0,
        p2: int = 2, bt: int = 128, st: int = 128,
        interpret: bool = False) -> jax.Array:
    """xb: (b, d); sup: (k, W, d); coef: (k, W) -> P (b, k) f32.

    b, W, d are padded to tile multiples here (zero points with zero
    coefficients contribute nothing for every supported kernel)."""
    b, d = xb.shape
    k, w, _ = sup.shape

    bp = -b % bt
    wp = -w % st
    dp = -d % 128
    xb_p = jnp.pad(xb, ((0, bp), (0, dp)))
    sup_p = jnp.pad(sup, ((0, 0), (0, wp), (0, dp)))
    coef_p = jnp.pad(coef, ((0, 0), (0, wp)))
    xsq = jnp.sum(xb_p.astype(jnp.float32) ** 2, axis=-1)        # (b+,)
    supsq = jnp.sum(sup_p.astype(jnp.float32) ** 2, axis=-1)     # (k, W+)

    bb, dd = xb_p.shape
    ww = sup_p.shape[1]
    grid = (k, bb // bt, ww // st)

    out = pl.pallas_call(
        functools.partial(_fused_body, kind=kind, p0=p0, p1=p1, p2=p2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, dd), lambda j, ib, iw: (ib, 0)),
            pl.BlockSpec((bt,), lambda j, ib, iw: (ib,)),
            pl.BlockSpec((1, st, dd), lambda j, ib, iw: (j, iw, 0)),
            pl.BlockSpec((1, st), lambda j, ib, iw: (j, iw)),
            pl.BlockSpec((1, st), lambda j, ib, iw: (j, iw)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda j, ib, iw: (ib, j)),
        out_shape=jax.ShapeDtypeStruct((bb, k), jnp.float32),
        interpret=interpret,
    )(xb_p, xsq, sup_p, supsq, coef_p)
    return out[:b]
