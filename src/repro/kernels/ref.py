"""Pure-jnp oracles for the Pallas kernels — the ground truth every kernel
test asserts against (interpret-mode sweeps in tests/test_pallas_kernels.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import KernelFn, kernel_cross


def batch_center_dots(kernel: KernelFn, xb: jax.Array, sup: jax.Array,
                      coef: jax.Array) -> jax.Array:
    """P[i, j] = sum_w coef[j, w] * K(xb[i], sup[j, w]).

    xb: (b, d); sup: (k, W, d); coef: (k, W) -> (b, k) float32.
    """
    b = xb.shape[0]
    k, w, d = sup.shape
    cross = kernel_cross(kernel, xb, sup.reshape(k * w, d))
    return jnp.einsum("bkw,kw->bk", cross.reshape(b, k, w), coef)


def cached_assign_dots(rows: jax.Array, sup_ids: jax.Array,
                       coef: jax.Array) -> jax.Array:
    """P[i,j] = sum_w coef[j,w] * rows[i, sup_ids[j,w]].

    rows: (b, n) resolved Gram rows; sup_ids: (k, W) int32; coef: (k, W).
    """
    b = rows.shape[0]
    k, w = coef.shape
    gathered = rows[:, sup_ids.reshape(-1)]          # (b, k*W)
    return jnp.einsum("bkw,kw->bk", gathered.reshape(b, k, w), coef)


def kernel_matmul(kernel: KernelFn, x: jax.Array, y: jax.Array,
                  v: jax.Array) -> jax.Array:
    """(K(x, y) @ v): x (n, d), y (m, d), v (m, c) -> (n, c).

    Materializes the full (n, m) kernel matrix — O(n m) memory — which is
    exactly what the Pallas kernel avoids."""
    return kernel_cross(kernel, x, y) @ v
