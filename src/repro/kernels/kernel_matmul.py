"""Pallas TPU kernel: streaming kernel-matrix matmul  (K(x, y) @ v).

The full-batch baseline (Lloyd in feature space), kernel k-means++ and the
<C,C> Gram recompute all reduce to (K(x,y) @ v) with a skinny v.  The naive
path materializes the (n, m) kernel matrix — 19.6 GB for MNIST n = 70k f32 —
and is pure HBM traffic.  This kernel computes K tiles in VMEM from x/y
tiles (FlashAttention-style) and contracts immediately:

    HBM traffic:  O(n*d + m*(d + c) + n*c)   instead of O(n*m).
    grid = (n/nt, m/mt), m innermost; out block (nt, c) stays resident.

Arithmetic intensity rises from ~1 flop/byte (kernel matrix read) to
~min(nt, mt) flop/byte — firmly compute-bound on the MXU for 128x128 tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.fused_assign import _apply_kernel


def _km_body(x_ref, xsq_ref, y_ref, ysq_ref, v_ref, out_ref,
             *, kind, p0, p1, p2):
    im = pl.program_id(1)

    @pl.when(im == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.float32)          # (nt, d)
    y = y_ref[...].astype(jnp.float32)          # (mt, d)
    xy = jax.lax.dot_general(x, y, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (nt, mt)
    kv = _apply_kernel(xy, xsq_ref[...].astype(jnp.float32),
                       ysq_ref[...].astype(jnp.float32), kind, p0, p1, p2)
    out_ref[...] += kv @ v_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=(
    "kind", "p0", "p1", "p2", "nt", "mt", "interpret"))
def kernel_matmul_pallas(x: jax.Array, y: jax.Array, v: jax.Array, *,
                         kind: str = "gaussian", p0: float = 1.0,
                         p1: float = 1.0, p2: int = 2,
                         nt: int = 128, mt: int = 128,
                         interpret: bool = False) -> jax.Array:
    """x: (n, d); y: (m, d); v: (m, c) -> (n, c) f32.

    Padding: m-padding rows get v = 0 (no contribution for any kernel);
    n-padding rows are sliced off; d zero-padded (distance/dot preserving).
    """
    n, d = x.shape
    m, c = v.shape

    np_ = -n % nt
    mp = -m % mt
    dp = -d % 128
    cp = -c % 128
    x_p = jnp.pad(x, ((0, np_), (0, dp)))
    y_p = jnp.pad(y, ((0, mp), (0, dp)))
    v_p = jnp.pad(v, ((0, mp), (0, cp)))
    xsq = jnp.sum(x_p.astype(jnp.float32) ** 2, axis=-1)
    ysq = jnp.sum(y_p.astype(jnp.float32) ** 2, axis=-1)

    nn, dd = x_p.shape
    mm = y_p.shape[0]
    cc = v_p.shape[1]
    grid = (nn // nt, mm // mt)

    out = pl.pallas_call(
        functools.partial(_km_body, kind=kind, p0=p0, p1=p1, p2=p2),
        grid=grid,
        in_specs=[
            pl.BlockSpec((nt, dd), lambda i, im: (i, 0)),
            pl.BlockSpec((nt,), lambda i, im: (i,)),
            pl.BlockSpec((mt, dd), lambda i, im: (im, 0)),
            pl.BlockSpec((mt,), lambda i, im: (im,)),
            pl.BlockSpec((mt, cc), lambda i, im: (im, 0)),
        ],
        out_specs=pl.BlockSpec((nt, cc), lambda i, im: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nn, cc), jnp.float32),
        interpret=interpret,
    )(x_p, xsq, y_p, ysq, v_p)
    return out[:n, :c]
