"""jit'd public wrappers around the Pallas kernels.

Dispatch: on TPU the kernels compile natively; on CPU (this container) they
run in interpret mode, which executes the kernel body in Python — identical
numerics, so tests validate the real tiling logic.  Kernels without an
MXU-friendly form (Laplacian L1, Precomputed gathers) fall back to the XLA
reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import (
    Gaussian, KernelFn, Linear, Polynomial,
)
from repro.kernels import ref
from repro.kernels.cached_gather import cached_assign_dots_pallas
from repro.kernels.fused_assign import fused_batch_center_dots_pallas
from repro.kernels.kernel_matmul import kernel_matmul_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _dispatch(kernel: KernelFn):
    """-> (kind, p0, p1, p2) or None when no Pallas form exists."""
    if isinstance(kernel, Gaussian):
        return "gaussian", float(kernel.kappa), 1.0, 2
    if isinstance(kernel, Linear):
        return "linear", 0.0, 1.0, 2
    if isinstance(kernel, Polynomial):
        return "polynomial", float(kernel.bias), float(kernel.scale), \
            int(kernel.degree)
    return None


def _clamp_tile(tile: int, extent: int, mult: int) -> int:
    """Shrink a tile to the padded extent of a small dimension (rounded up
    to ``mult``).  Per-shard support tiles in the distributed step can be
    far smaller than the 128-default tiles — without clamping, interpret
    mode would pad a (b/D, k/D * W) shard up to a full 128x128 grid cell
    and waste most of the work."""
    return min(tile, max(mult, -(-extent // mult) * mult))


def fused_batch_center_dots(kernel: KernelFn, xb: jax.Array,
                            sup_flat: jax.Array, coef: jax.Array,
                            bt: int = 128, st: int = 128,
                            interpret=None) -> jax.Array:
    """P[i,j] = sum_w coef[j,w] K(xb[i], sup[j,w]);  sup_flat: (k*W, d)."""
    k, w = coef.shape
    sup = sup_flat.reshape(k, w, sup_flat.shape[-1])
    disp = _dispatch(kernel)
    if disp is None:
        return ref.batch_center_dots(kernel, xb, sup, coef)
    kind, p0, p1, p2 = disp
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        # CPU/interpret: no MXU tiling constraints, so fit the tiles to the
        # (possibly per-shard) problem.  TPU keeps the caller's tiles.
        bt = _clamp_tile(bt, xb.shape[0], 8)
        st = _clamp_tile(st, w, 8)
    return fused_batch_center_dots_pallas(
        xb, sup, coef, kind=kind, p0=p0, p1=p1, p2=p2, bt=bt, st=st,
        interpret=interpret)


def cached_assign_dots(rows: jax.Array, sup_ids: jax.Array,
                       coef: jax.Array, bt: int = 128, st: int = 128,
                       interpret=None) -> jax.Array:
    """P[i,j] = sum_w coef[j,w] rows[i, sup_ids[j,w]] — the assignment
    contraction over cache-resolved Gram rows (no kernel evaluations; the
    gather-from-cache tile kernel of the repro.cache subsystem)."""
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        bt = _clamp_tile(bt, rows.shape[0], 8)
        st = _clamp_tile(st, coef.shape[1], 8)
    return cached_assign_dots_pallas(rows, sup_ids, coef, bt=bt, st=st,
                                     interpret=interpret)


def kernel_matmul(kernel: KernelFn, x: jax.Array, y: jax.Array,
                  v: jax.Array, nt: int = 128, mt: int = 128,
                  interpret=None) -> jax.Array:
    """(K(x, y) @ v) without materializing K."""
    disp = _dispatch(kernel)
    if disp is None:
        return ref.kernel_matmul(kernel, x, y, v)
    kind, p0, p1, p2 = disp
    if interpret is None:
        interpret = _interpret_default()
    return kernel_matmul_pallas(x, y, v, kind=kind, p0=p0, p1=p1, p2=p2,
                                nt=nt, mt=mt, interpret=interpret)
