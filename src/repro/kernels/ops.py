"""jit'd public wrappers around the Pallas kernels.

Dispatch: on TPU the kernels compile natively; on CPU (this container) they
run in interpret mode, which executes the kernel body in Python — identical
numerics, so tests validate the real tiling logic.  Kernels without an
MXU-friendly form (Laplacian L1, Precomputed gathers) fall back to the XLA
reference path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import (
    Gaussian, KernelFn, Linear, Polynomial,
)
from repro.kernels import fused_step, ref
from repro.kernels.cached_gather import cached_assign_dots_pallas
from repro.kernels.fused_assign import fused_batch_center_dots_pallas
from repro.kernels.kernel_matmul import kernel_matmul_pallas


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _dispatch(kernel: KernelFn):
    """-> (kind, p0, p1, p2) or None when no Pallas form exists."""
    if isinstance(kernel, Gaussian):
        return "gaussian", float(kernel.kappa), 1.0, 2
    if isinstance(kernel, Linear):
        return "linear", 0.0, 1.0, 2
    if isinstance(kernel, Polynomial):
        return "polynomial", float(kernel.bias), float(kernel.scale), \
            int(kernel.degree)
    return None


def _clamp_tile(tile: int, extent: int, mult: int) -> int:
    """Shrink a tile to the padded extent of a small dimension (rounded up
    to ``mult``).  Per-shard support tiles in the distributed step can be
    far smaller than the 128-default tiles — without clamping, interpret
    mode would pad a (b/D, k/D * W) shard up to a full 128x128 grid cell
    and waste most of the work."""
    return min(tile, max(mult, -(-extent // mult) * mult))


def fused_batch_center_dots(kernel: KernelFn, xb: jax.Array,
                            sup_flat: jax.Array, coef: jax.Array,
                            bt: int = 128, st: int = 128,
                            interpret=None) -> jax.Array:
    """P[i,j] = sum_w coef[j,w] K(xb[i], sup[j,w]);  sup_flat: (k*W, d)."""
    k, w = coef.shape
    sup = sup_flat.reshape(k, w, sup_flat.shape[-1])
    disp = _dispatch(kernel)
    if disp is None:
        return ref.batch_center_dots(kernel, xb, sup, coef)
    kind, p0, p1, p2 = disp
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        # CPU/interpret: no MXU tiling constraints, so fit the tiles to the
        # (possibly per-shard) problem.  TPU keeps the caller's tiles.
        bt = _clamp_tile(bt, xb.shape[0], 8)
        st = _clamp_tile(st, w, 8)
    return fused_batch_center_dots_pallas(
        xb, sup, coef, kind=kind, p0=p0, p1=p1, p2=p2, bt=bt, st=st,
        interpret=interpret)


def cached_assign_dots(rows: jax.Array, sup_ids: jax.Array,
                       coef: jax.Array, bt: int = 128, st: int = 128,
                       interpret=None) -> jax.Array:
    """P[i,j] = sum_w coef[j,w] rows[i, sup_ids[j,w]] — the assignment
    contraction over cache-resolved Gram rows (no kernel evaluations; the
    gather-from-cache tile kernel of the repro.cache subsystem)."""
    if interpret is None:
        interpret = _interpret_default()
    if interpret:
        bt = _clamp_tile(bt, rows.shape[0], 8)
        st = _clamp_tile(st, coef.shape[1], 8)
    return cached_assign_dots_pallas(rows, sup_ids, coef, bt=bt, st=st,
                                     interpret=interpret)


def _streaming_dispatch(kernel: KernelFn, interpret):
    """(disp, interpret): the streaming kernels run the Pallas form only
    on TPU for MXU-friendly kernels; everywhere else (CPU CI, Laplacian,
    index-data kernels) the structural XLA fallback runs — it is the
    bit-identical-at-f32 twin of the composed step, which interpret-mode
    Pallas (per-grid-cell emulation) is not."""
    if interpret is None:
        interpret = _interpret_default()
    return _dispatch(kernel), interpret


def streaming_assign(kernel: KernelFn, xb: jax.Array, sup_flat: jax.Array,
                     coef: jax.Array, sqnorm: jax.Array,
                     diag_b: jax.Array, *, precision: str = "f32",
                     bt: int = 128, st: int = 128,
                     kc: int = fused_step.STREAM_CHUNK,
                     interpret=None):
    """Streaming fused assignment: (best_dist (b,), assign (b,) int32)
    over all k centers without materializing the (b, k*W) cross strip or
    the (b, k) distances — the `step="fused"` hot pass.
    ``sup_flat``: (k*W, d) support rows (index-data rows for cached /
    precomputed kernels)."""
    k, w = coef.shape
    sup = sup_flat.reshape(k, w, sup_flat.shape[-1])
    disp, interpret = _streaming_dispatch(kernel, interpret)
    if disp is None or interpret:
        return fused_step.streaming_assign_xla(
            kernel, xb, sup_flat, coef, sqnorm, diag_b, kc=kc,
            precision=precision)
    kind, p0, p1, p2 = disp
    return fused_step.streaming_assign_pallas(
        xb, sup, coef, sqnorm, diag_b, kind=kind, p0=p0, p1=p1, p2=p2,
        bt=bt, st=st, bf16=precision in ("bf16", "bfloat16"),
        interpret=False)


def streaming_min(kernel: KernelFn, xb: jax.Array, sup_flat: jax.Array,
                  coef: jax.Array, sqnorm: jax.Array, diag_b: jax.Array,
                  *, precision: str = "f32", bt: int = 128, st: int = 128,
                  kc: int = fused_step.STREAM_CHUNK, interpret=None):
    """Streaming min distance (b,) only — the fused step's post-update
    objective pass (assignment indices not needed)."""
    disp, interpret = _streaming_dispatch(kernel, interpret)
    if disp is None or interpret:
        return fused_step.streaming_min_xla(
            kernel, xb, sup_flat, coef, sqnorm, diag_b, kc=kc,
            precision=precision)
    k, w = coef.shape
    kind, p0, p1, p2 = disp
    best, _ = fused_step.streaming_assign_pallas(
        xb, sup_flat.reshape(k, w, sup_flat.shape[-1]), coef, sqnorm,
        diag_b, kind=kind, p0=p0, p1=p1, p2=p2, bt=bt, st=st,
        bf16=precision in ("bf16", "bfloat16"), interpret=False)
    return best


def streaming_dists(kernel: KernelFn, xb: jax.Array, sup_flat: jax.Array,
                    coef: jax.Array, sqnorm: jax.Array, diag_b: jax.Array,
                    *, precision: str = "f32", bt: int = 128,
                    st: int = 128, kc: int = fused_step.STREAM_CHUNK,
                    interpret=None) -> jax.Array:
    """Full (b, k) distance block without the (b, k*W) strip — the fused
    SHARDED step's assignment pass (the model-axis all_gather needs the
    materialized per-local-center block).  On TPU the per-center dots run
    through the fused Pallas contraction; elsewhere the slab fallback."""
    disp, interpret = _streaming_dispatch(kernel, interpret)
    if disp is None or interpret:
        return fused_step.streaming_dists_xla(
            kernel, xb, sup_flat, coef, sqnorm, diag_b, kc=kc,
            precision=precision)
    cdt = jnp.bfloat16 if precision in ("bf16", "bfloat16") else None
    xbc = xb.astype(cdt) if cdt is not None else xb
    supc = sup_flat.astype(cdt) if cdt is not None else sup_flat
    p = fused_batch_center_dots(kernel, xbc, supc, coef, bt=bt, st=st,
                                interpret=False)
    return diag_b[:, None].astype(jnp.float32) - 2.0 * p + sqnorm[None, :]


def kernel_matmul(kernel: KernelFn, x: jax.Array, y: jax.Array,
                  v: jax.Array, nt: int = 128, mt: int = 128,
                  interpret=None) -> jax.Array:
    """(K(x, y) @ v) without materializing K."""
    disp = _dispatch(kernel)
    if disp is None:
        return ref.kernel_matmul(kernel, x, y, v)
    kind, p0, p1, p2 = disp
    if interpret is None:
        interpret = _interpret_default()
    return kernel_matmul_pallas(x, y, v, kind=kind, p0=p0, p1=p1, p2=p2,
                                nt=nt, mt=mt, interpret=interpret)
