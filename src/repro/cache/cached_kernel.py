"""``CachedKernel`` — a KernelFn adapter over the Gram tile cache.

A ``CachedKernel`` wraps a base kernel, the real ``(n, d)`` dataset, and a
:class:`repro.cache.tile_cache.GramTileCache`.  Like the existing
``Precomputed`` kernel, the "data" that flows through every algorithm in
:mod:`repro.core` is an ``(m, 1)`` array of float row indices into the
dataset — which is exactly what lets call sites stay unchanged: the whole
truncated-center machinery (init, fit, predict, the shard_map step) is
already index-agnostic because ``Precomputed`` exists.

Two access modes:

* **Functional read-through** (registered into ``kernel_cross`` /
  ``kernel_diag``): hits are gathered from the resident tiles, misses are
  recomputed on the fly *without* inserting (the KernelFn contract returns
  only the matrix, so state cannot be threaded).  Correct always; fast when
  the cache has been warmed.
* **Stateful** (:func:`cross_update`, :func:`warm_rows`,
  :func:`predict_cached`): lookups insert on miss, maintain LRU stamps and
  hit/miss/eviction counters, and return the updated ``CachedKernel`` —
  the fit / serving paths thread it through their loops.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.cache import tile_cache
from repro.cache.tile_cache import GramTileCache
from repro.core.kernel_fns import (
    KernelFn, diag_is_one, diag_of, gram_rows_fn, kernel_diag,
    register_kernel,
)


class CachedKernel(NamedTuple):
    """KernelFn pytree: base kernel + dataset coordinates + tile cache."""

    base: KernelFn        # the kernel actually evaluated on misses
    x: jax.Array          # (n, d) real dataset coordinates
    cache: GramTileCache  # device-resident row-block strips of K(x, x)


def make_cached(base: KernelFn, x: jax.Array, tile: int = 256,
                capacity: int = 16,
                dtype=jnp.float32) -> Tuple[CachedKernel, jax.Array]:
    """Build a cold CachedKernel over ``x`` and the index-data view ``xi``
    (``(n, 1)`` float row ids — pass ``xi`` wherever the algorithms expect
    the dataset, mirroring the ``Precomputed`` convention)."""
    n = x.shape[0]
    if n > 2 ** 24:
        raise ValueError(f"n={n} row ids are not exactly representable in "
                         "the float32 index-data convention (max 2**24)")
    ck = CachedKernel(base=base, x=x,
                      cache=tile_cache.create_cache(n, tile, capacity, dtype))
    # ids always in float32: half-precision dataset dtypes cannot represent
    # row ids past 256 and would silently alias rows
    xi = jnp.arange(n, dtype=jnp.float32)[:, None]
    return ck, xi


def _row_ids(data: jax.Array) -> jax.Array:
    return data[:, 0].astype(jnp.int32)


def cross_update(ck: CachedKernel, xi: jax.Array, yi: jax.Array,
                 max_blocks: Optional[int] = None):
    """Stateful K(x[ri], x[ci]): inserts missing row blocks (LRU) and
    updates counters.  Returns ``(K (m, c) f32, ck')``."""
    out, cache = tile_cache.lookup_rows(
        ck.cache, ck.base, ck.x, _row_ids(xi), _row_ids(yi),
        insert=True, max_blocks=max_blocks)
    return out, ck._replace(cache=cache)


def warm_rows(ck: CachedKernel, ridx: jax.Array,
              max_blocks: Optional[int] = None) -> CachedKernel:
    """Make the row blocks of ``ridx`` resident (the per-iteration prologue
    of the cached fit loop: warm batch + window rows, then let the unchanged
    Algorithm-2 step serve every cross-kernel block as a hit)."""
    return ck._replace(cache=tile_cache.warm(
        ck.cache, ck.base, ck.x, ridx.astype(jnp.int32).reshape(-1),
        max_blocks=max_blocks))


def _cross_readonly(ck: CachedKernel, xi: jax.Array,
                    yi: jax.Array) -> jax.Array:
    """kernel_cross contract: read-through lookup, state updates dropped."""
    out, _ = tile_cache.lookup_rows(ck.cache, ck.base, ck.x,
                                    _row_ids(xi), _row_ids(yi), insert=False)
    return out


def cross_rows_readonly(ck: CachedKernel, xi: jax.Array) -> jax.Array:
    """Full Gram rows K(x[ri], x) (m, n) read-through — the input to the
    Pallas gather-from-cache assignment kernel (repro.kernels.ops
    .cached_assign_dots)."""
    out, _ = tile_cache.lookup_rows(ck.cache, ck.base, ck.x,
                                    _row_ids(xi), None, insert=False)
    return out


def window_grams(kernel: KernelFn, pts: jax.Array) -> jax.Array:
    """Per-center window Grams K(win_j, win_j), (k, W, W), for any kernel
    advertising the ``gram_rows`` capability; ``pts`` is the (k, W, 1)
    index-data window.  ALL k*W support strips resolve in ONE read-through
    lookup (warm after the fit loop's ``warm_rows`` prologue), then each
    center's block is a pure column gather from its own strips — the
    landmark compressor's K_mW / K_mm / leverage-score assembly path
    (:mod:`repro.landmark.compress`)."""
    k, w, _ = pts.shape
    rows_fn = gram_rows_fn(kernel)
    if rows_fn is None:
        raise TypeError(f"{type(kernel).__name__} does not advertise "
                        "gram_rows; evaluate window Grams directly")
    rows = rows_fn(kernel, pts.reshape(k * w, -1)).astype(jnp.float32)
    ids = pts[..., 0].astype(jnp.int32)                        # (k, W)
    return jax.vmap(lambda r, i: r[:, i])(rows.reshape(k, w, -1), ids)


def _diag(ck: CachedKernel, xi: jax.Array) -> jax.Array:
    """kernel_diag contract: O(m), never touches the tile store."""
    return kernel_diag(ck.base, ck.x[_row_ids(xi)])


register_kernel(CachedKernel, cross=_cross_readonly, diag=_diag,
                diag_one=lambda ck: diag_is_one(ck.base),
                gram_rows=cross_rows_readonly)


def predict_cached(ck: CachedKernel, state, xq_idx: jax.Array,
                   chunk: int = 4096):
    """Cache-aware serving: assign query rows (given as dataset row indices)
    to the fitted truncated centers, threading the cache across chunks so
    repeated query rows hit warm tiles.  Numerics match
    ``repro.core.minibatch.predict`` on the index-data view; returns
    ``(labels (nq,), ck')`` — counters on ``ck'`` are the serving hit/miss
    telemetry."""
    k, w = state.coef.shape
    sup_ids = state.idx.reshape(-1).astype(jnp.int32)
    qi = xq_idx.reshape(-1).astype(jnp.int32)
    nq = qi.shape[0]
    chunk = min(chunk, max(nq, 1))
    pad = (-nq) % chunk
    qp = jnp.pad(qi, (0, pad)).reshape(-1, chunk)

    def one_chunk(ck, rows):
        cross, cache = tile_cache.lookup_rows(
            ck.cache, ck.base, ck.x, rows, sup_ids, insert=True)
        p = jnp.einsum("bkw,kw->bk", cross.reshape(chunk, k, w), state.coef)
        diag_b = diag_of(ck.base, ck.x[rows]).astype(p.dtype)
        d = diag_b[:, None] - 2.0 * p + state.sqnorm[None, :]
        return ck._replace(cache=cache), jnp.argmin(d, axis=1) \
            .astype(jnp.int32)

    ck, out = jax.lax.scan(one_chunk, ck, qp)
    return out.reshape(-1)[:nq], ck
