"""``PrecomputedGram`` — the full-Gram fast path for small n.

When ``n`` is small enough that the O(n^2) Gram matrix fits on device
(n = 16384 float32 is 1 GiB; the paper's datasets are far smaller), LRU
machinery is pure overhead: compute every strip exactly once up front and
serve all lookups as gathers.  This is the same trick the seed's graph
kernels (heat / k-nn, ``repro.data.graph_kernels``) already use — here it
is available for *any* base kernel.

``precompute_gram`` builds the matrix in row strips via ``lax.map`` so the
peak working set stays at ``block * n`` instead of requiring an
``(n, n)``-sized intermediate per kernel evaluation pass, and
``as_kernel`` hands back a plain :class:`repro.core.kernel_fns.Precomputed`
plus the index-data view — from there every algorithm in repro.core
consumes it natively.

Crossover vs the LRU tile cache (see docs/cache.md): PrecomputedGram wins
when the fit + serving workload will eventually touch most row blocks
(total misses ~ n/tile strips anyway) or when n^2 memory is cheap;
the LRU wins when n is large and the working set (batch + windows) is a
small, slowly-drifting subset of the dataset.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import KernelFn, Precomputed, kernel_cross


class PrecomputedGram(NamedTuple):
    """Full Gram shards, row-major: ``gram[i, j] = K(x_i, x_j)``."""

    gram: jax.Array  # (n, n)

    @property
    def n(self) -> int:
        return self.gram.shape[0]


def precompute_gram(base: KernelFn, x: jax.Array, block: int = 1024,
                    dtype=jnp.float32) -> PrecomputedGram:
    """Compute K(x, x) once, in ``block``-row strips (bounded peak memory).
    Rows are padded to a block multiple and the pad rows sliced away."""
    n = x.shape[0]
    b = min(block, n)
    pad = (-n) % b
    xp = jnp.pad(x, ((0, pad), (0, 0)))

    def strip(rows):
        return kernel_cross(base, rows, x).astype(dtype)

    g = jax.lax.map(strip, xp.reshape(-1, b, x.shape[1]))
    return PrecomputedGram(gram=g.reshape(-1, n)[:n])


def as_kernel(pg: PrecomputedGram) -> Tuple[Precomputed, jax.Array]:
    """View as a core ``Precomputed`` kernel + its (n, 1) index data —
    drop-in for fit / predict / the distributed paths."""
    xi = jnp.arange(pg.n, dtype=jnp.float32)[:, None]
    return Precomputed(gram=pg.gram), xi
