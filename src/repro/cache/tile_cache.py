"""Device-resident Gram tile cache — LRU over dataset row blocks.

Every step of Algorithm 2 pays O(k (tau+b)^2) kernel evaluations, but
batches sampled with replacement keep hitting the same support rows (the
``CenterState.idx`` windows change slowly), so most K(x_i, x_j) tiles are
recomputed verbatim across iterations.  This module caches *row-block
strips* of the full Gram matrix: entry ``b`` holds
``K(x[b*tile:(b+1)*tile], x)`` of shape ``(tile, n)``, so any cross-kernel
block K(x[ridx], x[cidx]) is a gather once the row blocks of ``ridx`` are
resident.

Design constraints (all driven by jit):

* **Fixed capacity, fixed shapes.**  The store is a ``(capacity, tile, n)``
  array; keys / LRU stamps are small int32 arrays.  The whole cache is a
  NamedTuple pytree, so it can be carried through ``lax.scan`` /
  ``lax.while_loop`` and donated across jit calls.
* **Block-granular ``lax.cond``.**  A lookup scans the (padded, unique) row
  blocks of the query; each step is one ``cond(hit, gather, compute)``.
  ``cond`` executes a single branch, so cache hits genuinely skip the
  kernel evaluation — this is where the wall-clock win comes from.  (Under
  ``vmap`` a ``cond`` lowers to ``select`` and both branches run; keep
  cached lookups out of vmapped axes.)
* **Stats as state.**  hit / miss / eviction counters ride in the pytree,
  so the serving demo and the ``kernel_cache`` benchmark report *measured*
  kernel-evaluation counts, not estimates.

See docs/cache.md for capacity / tile-size tuning guidance and for when
:class:`repro.cache.precomputed.PrecomputedGram` (the O(n^2) full-Gram fast
path) beats the LRU.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import KernelFn, kernel_cross


class GramTileCache(NamedTuple):
    """Fixed-capacity LRU tile store (a jit-carryable pytree).

    Invariants:
    * ``keys[s] == -1``  <=>  slot ``s`` is empty (its ``stamp`` is -1).
    * resident keys are unique block ids in ``[0, n // tile)``.
    * ``stamp[s]`` is the clock value of slot ``s``'s last touch; the LRU
      victim is ``argmin(stamp)`` (empty slots sort first).
    """

    store: jax.Array      # (capacity, tile, n) cached Gram row strips
    keys: jax.Array       # (capacity,) int32 block id, -1 = empty
    stamp: jax.Array      # (capacity,) int32 last-use clock, -1 = empty
    clock: jax.Array      # () int32 monotonic use counter
    hits: jax.Array       # () int32
    misses: jax.Array     # () int32  (each miss = tile * n kernel evals)
    evictions: jax.Array  # () int32

    @property
    def capacity(self) -> int:
        return self.store.shape[0]

    @property
    def tile(self) -> int:
        return self.store.shape[1]

    @property
    def n(self) -> int:
        return self.store.shape[2]

    @property
    def n_blocks(self) -> int:
        return self.n // self.tile


def create_cache(n: int, tile: int, capacity: int,
                 dtype=jnp.float32) -> GramTileCache:
    """Empty cache over an ``n``-row dataset partitioned into ``n / tile``
    row blocks.  ``tile`` must divide ``n`` (blocks must not overlap — a row
    in two blocks would break key identity)."""
    if n % tile:
        raise ValueError(f"tile {tile} must divide dataset rows {n} "
                         "(subsample or pick a divisor)")
    if capacity < 1:
        raise ValueError("capacity must be >= 1")
    def z():
        # distinct buffers — donating the cache alongside other state must
        # not hand XLA the same buffer twice
        return jnp.zeros((), jnp.int32)

    return GramTileCache(
        store=jnp.zeros((capacity, tile, n), dtype),
        keys=jnp.full((capacity,), -1, jnp.int32),
        stamp=jnp.full((capacity,), -1, jnp.int32),
        clock=z(), hits=z(), misses=z(), evictions=z())


def _padded_unique_blocks(blocks: jax.Array, max_blocks: int) -> jax.Array:
    """Unique block ids of ``blocks`` compacted to the front of a fixed
    ``(max_blocks,)`` vector, padded with -1.  ``max_blocks`` must bound the
    true unique count (``min(n_blocks, len(blocks))`` always does)."""
    s = jnp.sort(blocks)
    first = jnp.concatenate([jnp.ones((1,), bool), s[1:] != s[:-1]])
    perm = jnp.argsort(jnp.logical_not(first), stable=True)
    return jnp.where(first[perm], s[perm], -1)[:max_blocks]


def _ensure_block(cache: GramTileCache, base: KernelFn, x: jax.Array,
                  bid: jax.Array, insert: bool):
    """cond(hit -> gather + LRU touch, miss -> compute strip [+ insert]).
    Returns (cache', strip (tile, n)).  ``bid`` must be a valid block id."""
    tile = cache.tile
    present = cache.keys == bid
    slot_h = jnp.argmax(present)

    def on_hit(cache):
        strip = jax.lax.dynamic_index_in_dim(cache.store, slot_h, 0,
                                             keepdims=False)
        return cache._replace(
            stamp=cache.stamp.at[slot_h].set(cache.clock),
            clock=cache.clock + 1, hits=cache.hits + 1), strip

    def on_miss(cache):
        rows = jax.lax.dynamic_slice_in_dim(x, bid * tile, tile, 0)
        strip = kernel_cross(base, rows, x).astype(cache.store.dtype)
        cache = cache._replace(misses=cache.misses + 1)
        if insert:
            slot = jnp.argmin(cache.stamp)      # empties (-1) evict first
            cache = cache._replace(
                store=jax.lax.dynamic_update_index_in_dim(
                    cache.store, strip, slot, 0),
                keys=cache.keys.at[slot].set(bid.astype(jnp.int32)),
                stamp=cache.stamp.at[slot].set(cache.clock),
                clock=cache.clock + 1,
                evictions=cache.evictions
                + (cache.keys[slot] >= 0).astype(jnp.int32))
        return cache, strip

    return jax.lax.cond(jnp.any(present), on_hit, on_miss, cache)


def warm(cache: GramTileCache, base: KernelFn, x: jax.Array,
         ridx: jax.Array,
         max_blocks: Optional[int] = None) -> GramTileCache:
    """Make every row block touched by ``ridx`` resident (LRU-inserting on
    miss).  After warming, read-only lookups over ``ridx`` are all hits —
    provided ``capacity`` covers the working set (thrash is correct, just
    slow; the counters expose it)."""
    ridx = ridx.astype(jnp.int32)
    L = max_blocks if max_blocks is not None \
        else min(cache.n_blocks, ridx.shape[0])
    ub = _padded_unique_blocks(ridx // cache.tile, L)

    def step(cache, bid):
        def real(cache):
            cache, _ = _ensure_block(cache, base, x, bid, insert=True)
            return cache

        return jax.lax.cond(bid >= 0, real, lambda c: c, cache), None

    cache, _ = jax.lax.scan(step, cache, ub)
    return cache


def lookup_rows(cache: GramTileCache, base: KernelFn, x: jax.Array,
                ridx: jax.Array, cidx: Optional[jax.Array],
                insert: bool = True,
                max_blocks: Optional[int] = None):
    """Cross-kernel block K(x[ridx], x[cidx]) served from the cache.

    ``cidx=None`` returns full Gram rows, shape ``(len(ridx), n)``.
    ``insert=True`` first warms the needed blocks (LRU inserts + counters);
    ``insert=False`` is the read-through mode, leaving the cache untouched
    (used by the functional :func:`repro.core.kernel_fns.kernel_cross`
    adapter, which cannot return updated state).

    After warming (or when already warm) the common case is *every* needed
    block resident, served by a pure double gather with no block scan at
    all; only when some block is absent — read-through misses, or LRU
    thrash where the warm pass itself evicted an earlier needed block —
    does the ``cond`` fall back to the per-block accumulate scan (correct,
    slower; thrash strips recomputed there are not re-counted, so in the
    eviction-free regime the miss counter is the exact kernel-eval count).
    Returns ``(out, cache')``.
    """
    ridx = ridx.astype(jnp.int32)
    tile = cache.tile
    m = ridx.shape[0]
    c = cache.n if cidx is None else cidx.shape[0]
    blocks = ridx // tile
    if insert:
        cache = warm(cache, base, x, ridx, max_blocks)

    present = cache.keys[None, :] == blocks[:, None]           # (m, C)
    slots = jnp.argmax(present, axis=1)                        # (m,)
    rel = ridx - blocks * tile

    def fast(_):
        rows = cache.store[slots, rel]                         # (m, n)
        return rows if cidx is None else rows[:, cidx]

    def slow(_):
        L = max_blocks if max_blocks is not None \
            else min(cache.n_blocks, m)
        ub = _padded_unique_blocks(blocks, L)

        def step(out, bid):
            def real(out):
                _, strip = _ensure_block(cache, base, x, bid, insert=False)
                cols = strip if cidx is None else strip[:, cidx]
                picked = cols[jnp.clip(ridx - bid * tile, 0, tile - 1)]
                return jnp.where((blocks == bid)[:, None], picked, out)

            return jax.lax.cond(bid >= 0, real, lambda o: o, out), None

        out0 = jnp.zeros((m, c), cache.store.dtype)
        out, _ = jax.lax.scan(step, out0, ub)
        return out

    out = jax.lax.cond(jnp.all(jnp.any(present, axis=1)), fast, slow,
                       None)
    return out, cache


def stats(cache: GramTileCache) -> dict:
    """Host-side counter snapshot (python ints) — serving / bench reporting.
    ``evals`` is the *measured* kernel-evaluation count: every miss computes
    one ``(tile, n)`` strip."""
    hits = int(cache.hits)
    misses = int(cache.misses)
    return dict(
        hits=hits, misses=misses, evictions=int(cache.evictions),
        resident=int(jnp.sum(cache.keys >= 0)),
        capacity=cache.capacity, tile=cache.tile, n_blocks=cache.n_blocks,
        evals=misses * cache.tile * cache.n,
        hit_rate=hits / max(hits + misses, 1))
