"""repro.cache — Gram tile cache subsystem (nested-batch kernel reuse).

Batches sampled with replacement keep re-evaluating the same K(x_i, x_j)
tiles across Algorithm-2 iterations.  This package makes that reuse
explicit:

    GramTileCache    fixed-capacity, device-resident LRU over Gram row
                     blocks (jit-carryable pytree; tile_cache.py)
    CachedKernel     KernelFn adapter: registered into kernel_cross /
                     kernel_diag, so fit / predict / shard_map call sites
                     consume it unchanged (cached_kernel.py)
    PrecomputedGram  the O(n^2) full-Gram fast path for small n
                     (precomputed.py)

Importing this package registers ``CachedKernel`` with
``repro.core.kernel_fns``.
"""
from repro.cache.tile_cache import (  # noqa: F401
    GramTileCache, create_cache, lookup_rows, stats, warm,
)
from repro.cache.cached_kernel import (  # noqa: F401
    CachedKernel, cross_rows_readonly, cross_update, make_cached,
    predict_cached, warm_rows,
)
from repro.cache.precomputed import (  # noqa: F401
    PrecomputedGram, as_kernel, precompute_gram,
)
