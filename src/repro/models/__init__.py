from repro.models.config import ModelConfig  # noqa: F401
from repro.models.transformer import (  # noqa: F401
    init_params, forward_train, prefill, decode_step, init_cache,
)
