"""Attention blocks: GQA (with qk-norm / QKV-bias / sliding-window / M-RoPE)
and MLA (DeepSeek-V2 multi-head latent attention with absorbed decode).

Cache protocol (decode): each layer's cache is a dict of arrays whose leading
layout is (B, C, ...) with C = cache capacity (= sliding window size for SWA
archs — the sub-quadratic long_500k path).  `kpos` tracks the global position
held in every slot (-1 = empty) so ring overwrites and window masking are
uniform."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import apply_mrope, apply_rope, dense_init, rms_norm
from repro.models.config import ModelConfig

NEG_INF = -1e30


# --------------------------------------------------------------------- GQA
def init_gqa(key, cfg: ModelConfig, dtype):
    hd = cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd), dtype=dtype),
        "wk": dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd),
                         dtype=dtype),
        "wv": dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd),
                         dtype=dtype),
        "wo": dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model), dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), dtype)
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _rope_qk(cfg: ModelConfig, q, k, pos):
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, pos, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k


def _qkv(p, cfg: ModelConfig, h):
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_forward(p, cfg: ModelConfig, h, pos):
    """Full-sequence path (train / prefill / encode).  h: (B, S, D)."""
    b, s, _ = h.shape
    hd = cfg.hd
    groups = cfg.n_heads // cfg.n_kv_heads
    q, k, v = _qkv(p, cfg, h)
    q, k = _rope_qk(cfg, q, k, pos)
    q = q.reshape(b, s, cfg.n_kv_heads, groups, hd)
    # When head_dim is the sharded contraction axis (head count not
    # divisible by the model axis, e.g. arctic's 56 on 16), the score
    # partial-sums cross devices: attn_scores_bf16 halves that wire
    # traffic; softmax stays f32 AFTER the reduction (§Perf cell B).
    acc = jnp.bfloat16 if cfg.attn_scores_bf16 else jnp.float32
    scores = jnp.einsum("bqhgd,bchd->bhgqc", q, k,
                        preferred_element_type=acc)
    scores = scores.astype(jnp.float32) / jnp.sqrt(hd)
    qi = jnp.arange(s)[:, None]
    ci = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if cfg.causal:
        mask &= ci <= qi
    if cfg.sliding_window is not None:
        mask &= ci > qi - cfg.sliding_window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    out = jnp.einsum("bhgqc,bchd->bqhgd", attn, v)
    out = out.reshape(b, s, cfg.n_heads, hd)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"])


def gqa_cache_init(cfg: ModelConfig, b: int, cache_len: int, dtype):
    c = min(cache_len, cfg.sliding_window or cache_len)
    return {
        "k": jnp.zeros((b, c, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((b, c, cfg.n_kv_heads, cfg.hd), dtype),
        "kpos": jnp.full((b, c), -1, jnp.int32),
    }


def gqa_decode(p, cfg: ModelConfig, h, pos, cache):
    """One-token decode.  h: (B, 1, D); pos: (B,) int32 current position."""
    b, _, _ = h.shape
    hd = cfg.hd
    groups = cfg.n_heads // cfg.n_kv_heads
    c = cache["k"].shape[1]
    q, k, v = _qkv(p, cfg, h)
    q, k = _rope_qk(cfg, q, k, pos[:, None]) if cfg.mrope_sections is None \
        else _rope_qk(cfg, q, k, jnp.broadcast_to(pos[None, :, None],
                                                  (3, b, 1)))
    slot = (pos % c)                                        # (B,) ring slot
    bidx = jnp.arange(b)
    ck = cache["k"].at[bidx, slot].set(k[:, 0])
    cv = cache["v"].at[bidx, slot].set(v[:, 0])
    kpos = cache["kpos"].at[bidx, slot].set(pos)
    q = q.reshape(b, 1, cfg.n_kv_heads, groups, hd)
    scores = jnp.einsum("bqhgd,bchd->bhgqc", q, ck
                        ).astype(jnp.float32) / jnp.sqrt(hd)
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    if cfg.sliding_window is not None:
        valid &= kpos > (pos[:, None] - cfg.sliding_window)
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    out = jnp.einsum("bhgqc,bchd->bqhgd", attn, cv).reshape(b, 1,
                                                            cfg.n_heads, hd)
    o = jnp.einsum("bshd,hdo->bso", out, p["wo"])
    return o, {"k": ck, "v": cv, "kpos": kpos}


# --------------------------------------------------------------------- MLA
def init_mla(key, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 8)
    qin = cfg.q_lora or cfg.d_model
    p = {
        "wdkv": dense_init(ks[0], (cfg.d_model, cfg.kv_lora), dtype=dtype),
        "wkr": dense_init(ks[1], (cfg.d_model, cfg.rope_head_dim),
                          dtype=dtype),
        "wuk": dense_init(ks[2], (cfg.kv_lora, cfg.n_heads,
                                  cfg.nope_head_dim), dtype=dtype),
        "wuv": dense_init(ks[3], (cfg.kv_lora, cfg.n_heads, cfg.v_head_dim),
                          dtype=dtype),
        "wuq": dense_init(ks[4], (qin, cfg.n_heads,
                                  cfg.nope_head_dim + cfg.rope_head_dim),
                          dtype=dtype),
        "wo": dense_init(ks[5], (cfg.n_heads, cfg.v_head_dim, cfg.d_model),
                         dtype=dtype),
        "kv_norm": jnp.ones((cfg.kv_lora,), dtype),
    }
    if cfg.q_lora:
        p["wdq"] = dense_init(ks[6], (cfg.d_model, cfg.q_lora), dtype=dtype)
        p["q_norm"] = jnp.ones((cfg.q_lora,), dtype)
    return p


def _mla_q(p, cfg: ModelConfig, h, pos):
    if cfg.q_lora:
        cq = rms_norm(h @ p["wdq"], p["q_norm"], cfg.norm_eps)
    else:
        cq = h
    q = jnp.einsum("bsq,qhd->bshd", cq, p["wuq"])
    qn, qr = jnp.split(q, [cfg.nope_head_dim], axis=-1)
    qr = apply_rope(qr, pos, cfg.rope_theta)
    return qn, qr


def mla_forward(p, cfg: ModelConfig, h, pos):
    b, s, _ = h.shape
    ckv = rms_norm(h @ p["wdkv"], p["kv_norm"], cfg.norm_eps)   # (B,S,kvl)
    kr = apply_rope((h @ p["wkr"])[:, :, None, :], pos,
                    cfg.rope_theta)[:, :, 0]                    # (B,S,rhd)
    qn, qr = _mla_q(p, cfg, h, pos)
    kn = jnp.einsum("bsl,lhd->bshd", ckv, p["wuk"])
    v = jnp.einsum("bsl,lhd->bshd", ckv, p["wuv"])
    scale = 1.0 / jnp.sqrt(cfg.nope_head_dim + cfg.rope_head_dim)
    scores = (jnp.einsum("bqhd,bchd->bhqc", qn, kn)
              + jnp.einsum("bqhd,bcd->bhqc", qr, kr)
              ).astype(jnp.float32) * scale
    qi = jnp.arange(s)[:, None]
    mask = jnp.arange(s)[None, :] <= qi
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    out = jnp.einsum("bhqc,bchd->bqhd", attn, v)
    return jnp.einsum("bshd,hdo->bso", out, p["wo"])


def mla_cache_init(cfg: ModelConfig, b: int, cache_len: int, dtype):
    return {
        "ckv": jnp.zeros((b, cache_len, cfg.kv_lora), dtype),
        "kr": jnp.zeros((b, cache_len, cfg.rope_head_dim), dtype),
        "kpos": jnp.full((b, cache_len), -1, jnp.int32),
    }


def mla_decode(p, cfg: ModelConfig, h, pos, cache):
    """Absorbed-matrix decode: scores/values computed in the compressed
    kv_lora space — the 576-per-token cache that is MLA's point."""
    b = h.shape[0]
    ckv_t = rms_norm(h @ p["wdkv"], p["kv_norm"], cfg.norm_eps)  # (B,1,kvl)
    kr_t = apply_rope((h @ p["wkr"])[:, :, None, :], pos[:, None],
                      cfg.rope_theta)[:, :, 0]                   # (B,1,rhd)
    bidx = jnp.arange(b)
    slot = pos % cache["ckv"].shape[1]
    ckv = cache["ckv"].at[bidx, slot].set(ckv_t[:, 0])
    kr = cache["kr"].at[bidx, slot].set(kr_t[:, 0])
    kpos = cache["kpos"].at[bidx, slot].set(pos)

    qn, qr = _mla_q(p, cfg, h, pos[:, None])                    # (B,1,H,*)
    q_c = jnp.einsum("bqhd,lhd->bqhl", qn, p["wuk"])            # absorb W_uk
    scale = 1.0 / jnp.sqrt(cfg.nope_head_dim + cfg.rope_head_dim)
    scores = (jnp.einsum("bqhl,bcl->bhqc", q_c, ckv)
              + jnp.einsum("bqhd,bcd->bhqc", qr, kr)
              ).astype(jnp.float32) * scale
    valid = (kpos >= 0) & (kpos <= pos[:, None])
    scores = jnp.where(valid[:, None, None], scores, NEG_INF)
    attn = jax.nn.softmax(scores, axis=-1).astype(h.dtype)
    ctx_c = jnp.einsum("bhqc,bcl->bqhl", attn, ckv)
    out = jnp.einsum("bqhl,lhd->bqhd", ctx_c, p["wuv"])         # absorb W_uv
    o = jnp.einsum("bshd,hdo->bso", out, p["wo"])
    return o, {"ckv": ckv, "kr": kr, "kpos": kpos}
