"""RWKV-6 "Finch" block: time-mix with data-dependent per-channel decay and
channel-mix (arXiv:2404.05892).

Train path: sequential lax.scan over time (the paper-faithful recurrence).
This is deliberately the BASELINE — it is memory-bound on TPU (elementwise
state updates, no MXU work), which the roofline analysis surfaces; the
chunked matmul re-formulation is a §Perf hillclimb (see EXPERIMENTS.md).
Decode path: O(1) recurrent update — the attention-free long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm
from repro.models.config import ModelConfig


def _heads(cfg: ModelConfig):
    hd = cfg.ssm_head_dim
    return cfg.d_model // hd, hd


def init_rwkv6_timemix(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    nh, hd = _heads(cfg)
    lora = max(32, d // 16)
    ks = jax.random.split(key, 10)
    return {
        # token-shift interpolation factors per stream (r, k, v, w, g)
        "mu": jnp.full((5, d), 0.5, dtype),
        "wr": dense_init(ks[0], (d, d), dtype=dtype),
        "wk": dense_init(ks[1], (d, d), dtype=dtype),
        "wv": dense_init(ks[2], (d, d), dtype=dtype),
        "wg": dense_init(ks[3], (d, d), dtype=dtype),
        "wo": dense_init(ks[4], (d, d), dtype=dtype),
        # data-dependent decay: w_t = exp(-exp(w0 + tanh(x w1) w2))
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w1": dense_init(ks[5], (d, lora), dtype=dtype),
        "w2": dense_init(ks[6], (lora, d), scale=0.1, dtype=dtype),
        "u": dense_init(ks[7], (nh, hd), dtype=jnp.float32),  # bonus
        "ln_x": jnp.ones((d,), dtype),
    }


def _timemix_streams(p, cfg, x, x_prev):
    """Token shift: per-stream lerp between x_t and x_{t-1}."""
    mu = p["mu"].astype(x.dtype)
    xs = [x + (x_prev - x) * mu[i] for i in range(5)]
    r = xs[0] @ p["wr"]
    k = xs[1] @ p["wk"]
    v = xs[2] @ p["wv"]
    g = jax.nn.silu(xs[4] @ p["wg"])
    w = jnp.exp(-jnp.exp(
        p["w0"][None] + (jnp.tanh(xs[3] @ p["w1"]) @ p["w2"])
        .astype(jnp.float32)))                            # (.., D) in (0,1)
    return r, k, v, g, w


def _wkv_step(state, r, k, v, w, u, nh, hd):
    """state: (B, nh, hd, hd) [k-dim, v-dim].  One recurrence step."""
    rb = r.reshape(-1, nh, hd)
    kb = k.reshape(-1, nh, hd)
    vb = v.reshape(-1, nh, hd)
    wb = w.reshape(-1, nh, hd)
    kv = jnp.einsum("bhk,bhv->bhkv", kb, vb)
    out = jnp.einsum("bhk,bhkv->bhv", rb,
                     state + u[None, :, :, None].astype(state.dtype) * kv)
    new_state = wb[..., None].astype(state.dtype) * state + kv
    return new_state, out


def _wkv_sequential(r, k, v, w, u, nh, hd, b):
    def step(state, inp):
        rt, kt, vt, wt = inp
        state, out = _wkv_step(state, rt, kt, vt, wt, u, nh, hd)
        return state, out

    state0 = jnp.zeros((b, nh, hd, hd), r.dtype)
    xs = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(w.astype(r.dtype), 1, 0))
    stateN, outs = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(outs, 0, 1), stateN


def _wkv_chunked(r, k, v, w, u, nh, hd, chunk):
    """Exact chunked-matmul WKV (beyond-paper §Perf): turns the elementwise
    recurrence into MXU matmuls.  Per chunk of length Q, with per-channel
    log-decay lw and inclusive cumulative sums L_i = sum_{l<=i} lw_l:

      o_i    = r_i . S_{chunk-start} * exp(L_{i-1})                (carry-in)
             + sum_{j<i} [r_i exp(L_{i-1} - L_j)] k_j^T v_j        (intra)
             + u * (r_i . k_i) v_i                                  (bonus)
      S_end  = exp(L_Q) * S_start + sum_j (k_j exp(L_Q - L_j))^T v_j

    Every exponent is <= 0 (decays), so all rescaled factors are <= 1 —
    no overflow, validated against the sequential oracle in tests."""
    b, s, d = r.shape
    q = chunk
    nc = s // q

    def hsplit(x):
        return x.reshape(b, nc, q, nh, hd)

    rc, kc, vc = hsplit(r), hsplit(k), hsplit(v)
    # decay clamp: |log w| <= 160/Q keeps every rescaled factor below
    # exp(80) < f32 max.  At Q=64 this only constrains w >= 0.082/step —
    # far below trained RWKV decays (documented §Perf numerics note).
    lw = jnp.log(jnp.clip(w.astype(jnp.float32), 1e-38, 1.0))
    lw = jnp.maximum(lw, -160.0 / q).reshape(b, nc, q, nh, hd)
    lcum = jnp.cumsum(lw, axis=2)                       # L_i inclusive
    lend = lcum[:, :, -1:]                              # L_Q
    mid = 0.5 * lend                                    # per-channel ref

    # rescaled factors: each exponent is within +-|L_Q|/2 (no overflow),
    # and every PRODUCT r'_i k'_j = r_i k_j exp(L_{i-1} - L_j) <= r_i k_j.
    r_in = rc * jnp.exp(lcum - lw).astype(rc.dtype)     # r_i W_{i-1}
    r_rel = rc * jnp.exp(lcum - lw - mid).astype(rc.dtype)
    k_rel = kc * jnp.exp(mid - lcum).astype(kc.dtype)   # k_j W_mid / W_j

    # intra-chunk: scores_ij = r_rel_i . k_rel_j = r_i k_j exp(L_{i-1}-L_j)
    scores = jnp.einsum("bcqhk,bcjhk->bchqj", r_rel, k_rel)
    mask = jnp.tril(jnp.ones((q, q), bool), k=-1)
    scores = jnp.where(mask[None, None, None], scores, 0.0)
    y_intra = jnp.einsum("bchqj,bcjhv->bcqhv", scores, vc)
    bonus = jnp.einsum("bcqhk,bcqhk->bcqh", rc,
                       u[None, None, None].astype(rc.dtype) * kc)
    y_intra = y_intra + bonus[..., None] * vc

    # chunk state contributions: need k_j exp(L_Q - L_j) = k_rel * exp(mid)
    states = jnp.einsum("bcjhk,bcjhv->bchkv", k_rel, vc) \
        * jnp.exp(mid)[:, :, 0, :, :, None].astype(kc.dtype)
    cdecay = jnp.exp(lend[:, :, 0])                       # (B,NC,H,hd)

    def scan_fn(carry, inp):
        st, dec = inp                                     # (B,H,K,V),(B,H,K)
        prev = carry
        carry = dec[..., None].astype(carry.dtype) * carry + st
        return carry, prev

    s0 = jnp.zeros((b, nh, hd, hd), r.dtype)
    stateN, sprev = jax.lax.scan(
        scan_fn, s0, (jnp.moveaxis(states, 1, 0),
                      jnp.moveaxis(cdecay, 1, 0)))
    sprev = jnp.moveaxis(sprev, 0, 1)                     # (B,NC,H,K,V)

    y_carry = jnp.einsum("bcqhk,bchkv->bcqhv", r_in, sprev)
    y = (y_intra + y_carry).reshape(b, s, nh, hd)
    return y.reshape(b, s, d), stateN


def rwkv6_timemix_forward(p, cfg: ModelConfig, h, pos=None):
    """h: (B, S, D); sequential scan baseline, or chunked matmuls when
    cfg.rwkv_chunked (see module doc / §Perf)."""
    b, s, d = h.shape
    nh, hd = _heads(cfg)
    x_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, w = _timemix_streams(p, cfg, h, x_prev)

    if cfg.rwkv_chunked and s % cfg.rwkv_chunk == 0:
        out, _ = _wkv_chunked(r, k, v, w, p["u"], nh, hd, cfg.rwkv_chunk)
    else:
        out, _ = _wkv_sequential(r, k, v, w, p["u"], nh, hd, b)
        out = out.reshape(b, s, d)
    out = rms_norm(out, p["ln_x"], cfg.norm_eps) * g
    return out @ p["wo"]


def init_rwkv6_chanmix(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mu": jnp.full((2, d), 0.5, dtype),
        "wk": dense_init(ks[0], (d, f), dtype=dtype),
        "wv": dense_init(ks[1], (f, d), dtype=dtype),
        "wr": dense_init(ks[2], (d, d), dtype=dtype),
    }


def rwkv6_chanmix_forward(p, cfg: ModelConfig, h):
    x_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    mu = p["mu"].astype(h.dtype)
    xk = h + (x_prev - h) * mu[0]
    xr = h + (x_prev - h) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    return jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])


def rwkv6_cache_init(cfg: ModelConfig, b: int, dtype):
    nh, hd = _heads(cfg)
    return {
        "state": jnp.zeros((b, nh, hd, hd), dtype),
        "x_tm": jnp.zeros((b, cfg.d_model), dtype),   # prev token (time-mix)
        "x_cm": jnp.zeros((b, cfg.d_model), dtype),   # prev token (chan-mix)
    }


def rwkv6_timemix_decode(p, cfg: ModelConfig, h, cache):
    b, _, d = h.shape
    nh, hd = _heads(cfg)
    x = h[:, 0]
    r, k, v, g, w = _timemix_streams(p, cfg, x, cache["x_tm"])
    state, out = _wkv_step(cache["state"], r, k, v, w.astype(h.dtype),
                           p["u"], nh, hd)
    out = out.reshape(b, d)
    out = rms_norm(out, p["ln_x"], cfg.norm_eps) * g
    return (out @ p["wo"])[:, None], {"state": state, "x_tm": x}


def rwkv6_chanmix_decode(p, cfg: ModelConfig, h, cache):
    x = h[:, 0]
    mu = p["mu"].astype(h.dtype)
    xk = x + (cache["x_cm"] - x) * mu[0]
    xr = x + (cache["x_cm"] - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    out = jax.nn.sigmoid(xr @ p["wr"]) * (k @ p["wv"])
    return out[:, None], {"x_cm": x}
