"""Shared building blocks: norms, rotary embeddings (incl. M-RoPE), init."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(
        x.dtype)


def dense_init(key, shape, scale: float = 1.0, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    return (jax.random.normal(key, shape, dtype)
            * (scale / jnp.sqrt(jnp.maximum(fan_in, 1))))


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); pos: (B, S) int32 -> rotated x (interleaved pairs)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = pos[..., None].astype(jnp.float32) * freqs    # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, pos3: jax.Array, sections, theta: float
                ) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): pos3 (3, B, S) = (temporal, h, w) ids;
    `sections` split hd/2 frequency bands across the three position streams."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    sec = jnp.concatenate([
        jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)])
    # pick the position stream per frequency band
    pos_per_band = jnp.take_along_axis(
        pos3.transpose(1, 2, 0).astype(jnp.float32),    # (B, S, 3)
        jnp.broadcast_to(sec[None, None, :],
                         (x.shape[0], x.shape[1], hd // 2)),
        axis=-1)                                        # (B, S, hd/2)
    ang = pos_per_band * freqs
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
