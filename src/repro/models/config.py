"""Model configuration covering all 10 assigned architectures.

One frozen dataclass; every flag corresponds to a documented architectural
feature of some assigned config (see src/repro/configs/)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    d_ff: int = 1024
    vocab: int = 1024
    head_dim: Optional[int] = None          # default d_model // n_heads

    # ---- attention flags ----
    qk_norm: bool = False                   # qwen3
    qkv_bias: bool = False                  # qwen2
    sliding_window: Optional[int] = None    # h2o-danube SWA
    rope_theta: float = 1e4
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    causal: bool = True                     # False: hubert encoder

    # ---- MLP ----
    mlp: str = "swiglu"                     # swiglu | sq_relu | gelu

    # ---- MLA (deepseek-v2) ----
    mla: bool = False
    kv_lora: int = 512
    q_lora: Optional[int] = None
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128

    # ---- MoE ----
    moe: bool = False
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 2
    moe_d_ff: int = 0
    dense_residual: bool = False            # arctic: dense FFN || MoE
    capacity_factor: float = 1.25

    # ---- hybrid / SSM ----
    attn_every: int = 0                     # zamba2: shared attn block period
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # ---- embeddings / frontend ----
    tie_embeddings: bool = False
    frontend: str = "none"                  # none | stub (vlm patch / audio frame)
    frontend_dim: int = 0                   # stub input feature dim

    # ---- numerics / training ----
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    remat: bool = True

    # ---- beyond-paper perf options (§Perf, default off = baseline) ----
    moe_group_dispatch: bool = False   # per-data-shard MoE routing (EP)
    rwkv_chunked: bool = False         # chunked-matmul WKV (vs seq scan)
    rwkv_chunk: int = 32               # WKV chunk length (numerics note)
    attn_scores_bf16: bool = False     # bf16 score partials on the wire
                                       # (softmax still f32 post-reduce)
    scan_unroll: bool = False          # unroll layer scans (measurement
                                       # mode: XLA cost_analysis counts a
                                       # while body ONCE — see §Roofline)

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else \
            self.d_model // self.n_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Eligible for the long_500k cell (see DESIGN.md skip notes)."""
        return (self.family in ("ssm", "hybrid")
                or self.sliding_window is not None)

    def reduced(self, **overrides) -> "ModelConfig":
        """Smoke-test-sized config of the same family (per instructions:
        small layers/width, few experts, tiny vocab)."""
        small = dict(
            n_layers=min(self.n_layers, 4) if self.attn_every == 0
            else 2 * max(self.attn_every, 1),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 4) if self.n_kv_heads
            < self.n_heads else 4,
            d_ff=256,
            vocab=512,
            head_dim=32,
            kv_lora=32, q_lora=(48 if self.q_lora else None),
            rope_head_dim=16, nope_head_dim=32, v_head_dim=32,
            n_experts=8 if self.moe else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            moe_d_ff=64 if self.moe else 0,
            ssm_state=16, ssm_head_dim=16, ssm_chunk=32,
            frontend_dim=64 if self.frontend == "stub" else 0,
            sliding_window=64 if self.sliding_window else None,
            mrope_sections=(4, 6, 6) if self.mrope_sections else None,
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)
