"""Mamba2 (SSD) block — chunked matmul formulation (TPU-native: the
intra-chunk work is MXU matmuls; the inter-chunk recurrence is a short
lax.scan over S/chunk steps).  Follows the minimal SSD reference of the
Mamba2 paper; single B/C group broadcast across heads (zamba2's layout)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm
from repro.models.config import ModelConfig


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_head_dim
    return d_inner, nheads


def init_mamba2(key, cfg: ModelConfig, dtype):
    d_inner, nheads = _dims(cfg)
    n = cfg.ssm_state
    conv_ch = d_inner + 2 * n                      # x + B + C get conv'd
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z, xBC, dt]
        "w_in": dense_init(ks[0], (cfg.d_model,
                                   2 * d_inner + 2 * n + nheads),
                           dtype=dtype),
        "conv_w": dense_init(ks[1], (cfg.ssm_conv, conv_ch), dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((nheads,), jnp.float32),
        "d_skip": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "out_norm": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], (d_inner, cfg.d_model), dtype=dtype),
    }


def _segsum(x):
    """x: (..., Q) -> (..., Q, Q) with out[i,j] = sum_{j < l <= i} x[l],
    -inf above the diagonal (strictly lower-triangular cumulative sums)."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def _ssd_chunked(x, dt, a, bmat, cmat, chunk):
    """SSD scan.  x: (B,S,H,P) pre-multiplied by dt; dt: (B,S,H);
    a: (H,) negative; bmat/cmat: (B,S,N) single group -> broadcast to heads.
    Returns y: (B,S,H,P) and final state (B,H,P,N).

    S is front-padded to a chunk multiple with dt = 0 entries: decay
    exp(0*A) = 1 and zero input contribution leave the recurrence exact."""
    bsz, s_orig, h, p = x.shape
    pad = (-s_orig) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (pad, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (pad, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (pad, 0), (0, 0)))
    bsz, s, h, p = x.shape
    n = bmat.shape[-1]
    nc = s // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h)
    bc = bmat.reshape(bsz, nc, chunk, n)
    cc = cmat.reshape(bsz, nc, chunk, n)

    da = dtc * a[None, None, None, :]                  # (B,NC,Q,H)
    da = jnp.transpose(da, (0, 1, 3, 2))               # (B,NC,H,Q)
    da_cs = jnp.cumsum(da, axis=-1)                    # (B,NC,H,Q)

    # intra-chunk (diagonal blocks): L = exp(segsum(dA))
    el = jnp.exp(_segsum(da))                          # (B,NC,H,Q,Q)
    y_diag = jnp.einsum("bcqn,bckn,bchqk,bckhp->bcqhp",
                        cc, bc, el.astype(x.dtype), xc)

    # chunk -> state contributions
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)    # (B,NC,H,Q)
    states = jnp.einsum("bckn,bchk,bckhp->bchpn",
                        bc, decay_states.astype(x.dtype), xc)  # (B,NC,H,P,N)

    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cs[..., -1])              # (B,NC,H)

    def scan_fn(hstate, inp):
        st, dec = inp                                  # (B,H,P,N), (B,H)
        prev = hstate
        hstate = dec[..., None, None].astype(x.dtype) * hstate + st
        return hstate, prev

    h0 = jnp.zeros((bsz, h, p, n), x.dtype)
    hlast, hprev = jax.lax.scan(
        scan_fn, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay,
                                                               1, 0)))
    hprev = jnp.moveaxis(hprev, 0, 1)                  # (B,NC,H,P,N)

    # off-diagonal: contribution of the carried state into each chunk
    state_decay = jnp.exp(da_cs)                       # (B,NC,H,Q)
    y_off = jnp.einsum("bcqn,bchpn,bchq->bcqhp",
                       cc, hprev, state_decay.astype(x.dtype))
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y[:, pad:], hlast


def _causal_conv(x, w, b):
    """x: (B,S,C); w: (K,C) depthwise causal conv."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _split_proj(p, cfg, h):
    d_inner, nheads = _dims(cfg)
    n = cfg.ssm_state
    zxbcdt = h @ p["w_in"]
    z, xbc, dt_raw = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt_raw, d_inner, nheads, n


def mamba2_forward(p, cfg: ModelConfig, h, pos=None):
    """h: (B, S, D) -> (B, S, D).  S must be a multiple of cfg.ssm_chunk
    (transformer.py pads)."""
    bsz, s, _ = h.shape
    z, xbc, dt_raw, d_inner, nheads, n = _split_proj(p, cfg, h)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])       # (B,S,H)
    a = -jnp.exp(p["a_log"])                               # (H,)
    xh = x.reshape(bsz, s, nheads, cfg.ssm_head_dim)
    xdt = xh * dt[..., None].astype(xh.dtype)
    y, _ = _ssd_chunked(xdt, dt, a, bmat, cmat, cfg.ssm_chunk)
    y = y + p["d_skip"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, s, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    return y @ p["w_out"]


def mamba2_cache_init(cfg: ModelConfig, b: int, dtype):
    d_inner, nheads = _dims(cfg)
    n = cfg.ssm_state
    return {
        "conv": jnp.zeros((b, cfg.ssm_conv - 1, d_inner + 2 * n), dtype),
        "ssm": jnp.zeros((b, nheads, cfg.ssm_head_dim, n), dtype),
    }


def mamba2_decode(p, cfg: ModelConfig, h, pos, cache):
    """One-token recurrent update — O(1) in sequence length (the long_500k
    path for hybrid archs)."""
    bsz = h.shape[0]
    z, xbc, dt_raw, d_inner, nheads, n = _split_proj(p, cfg, h)
    # conv ring: window = [cache, current]
    win = jnp.concatenate([cache["conv"], xbc], axis=1)    # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", win, p["conv_w"]) + p["conv_b"]
    xbc1 = jax.nn.silu(conv_out)[:, None, :]
    x, bmat, cmat = jnp.split(xbc1, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None])       # (B,1,H)
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt[:, 0] * a[None])                       # (B,H)
    xh = x.reshape(bsz, nheads, cfg.ssm_head_dim)
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt[:, 0].astype(xh.dtype),
                     bmat[:, 0], xh)
    ssm = da[..., None, None].astype(xh.dtype) * cache["ssm"] + dbx
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0], ssm)
    y = y + p["d_skip"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(bsz, 1, d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    new_cache = {"conv": win[:, 1:], "ssm": ssm}
    return y @ p["w_out"], new_cache
