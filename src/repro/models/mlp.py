"""MLP variants (SwiGLU / squared-ReLU / GELU) and the MoE layer
(top-k routing, capacity-based fixed-shape dispatch, shared experts,
arctic-style parallel dense residual)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import dense_init
from repro.models.config import ModelConfig


# ------------------------------------------------------------- dense MLPs
def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype):
    ks = jax.random.split(key, 3)
    if kind == "swiglu":
        return {"wg": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
                "wu": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
                "wd": dense_init(ks[2], (d_ff, d_model), dtype=dtype)}
    return {"w1": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "w2": dense_init(ks[1], (d_ff, d_model), dtype=dtype)}


def mlp_forward(p, kind: str, h):
    if kind == "swiglu":
        return (jax.nn.silu(h @ p["wg"]) * (h @ p["wu"])) @ p["wd"]
    if kind == "sq_relu":                     # nemotron-4
        return jnp.square(jax.nn.relu(h @ p["w1"])) @ p["w2"]
    if kind == "gelu":                        # hubert
        return jax.nn.gelu(h @ p["w1"]) @ p["w2"]
    raise ValueError(kind)


# ------------------------------------------------------------------- MoE
def init_moe(key, cfg: ModelConfig, dtype):
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),
        "wg": dense_init(ks[1], (e, d, f), dtype=dtype),
        "wu": dense_init(ks[2], (e, d, f), dtype=dtype),
        "wd": dense_init(ks[3], (e, f, d), dtype=dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], d, f * cfg.n_shared_experts,
                               "swiglu", dtype)
    if cfg.dense_residual:
        p["dense"] = init_mlp(ks[5], d, cfg.d_ff, "swiglu", dtype)
    return p


def _moe_dispatch(p, cfg: ModelConfig, x, cap):
    """x: (T, D) -> (buckets (E, C, D), combine metadata).  Fixed-shape
    capacity dispatch via per-slot one-hot cumsum ranks."""
    t, d = x.shape
    e, kk = cfg.n_experts, cfg.top_k
    logits = (x.astype(jnp.float32) @ p["router"])          # (T, E)
    gates, idx = jax.lax.top_k(logits, kk)                  # (T, kk)
    gates = jax.nn.softmax(gates, axis=-1)

    fill = jnp.zeros((e,), jnp.int32)
    buckets = jnp.zeros((e, cap, d), x.dtype)
    token_slot = []
    for slot in range(kk):
        eid = idx[:, slot]                                  # (T,)
        oh = jax.nn.one_hot(eid, e, dtype=jnp.int32)        # (T, E)
        rank = (jnp.cumsum(oh, axis=0) - 1)[jnp.arange(t), eid] + fill[eid]
        keep = rank < cap
        bslot = jnp.where(keep, rank, cap)                  # cap => dropped
        buckets = buckets.at[eid, bslot].set(
            jnp.where(keep[:, None], x, 0).astype(x.dtype), mode="drop")
        token_slot.append((eid, bslot, keep))
        fill = fill + jnp.sum(oh, axis=0).astype(jnp.int32)
    return buckets, (gates, token_slot)


def _moe_combine(y, meta, t, d, cap):
    """y: (E, C, D) expert outputs -> (T, D) gate-weighted combine.
    Token-side gather y[eid] — simple, but under EP sharding GSPMD must
    all-gather y along 'model' (§Perf cell B, refuted path)."""
    gates, token_slot = meta
    out = jnp.zeros((t, d), jnp.float32)
    for slot, (eid, bslot, keep) in enumerate(token_slot):
        contrib = y[eid, jnp.minimum(bslot, cap - 1)]
        out = out + jnp.where(keep[:, None],
                              gates[:, slot][:, None] * contrib, 0.0)
    return out


def _moe_combine_scatter(y, meta, t, d, cap):
    """Expert-side combine: invert the dispatch into (E, C) -> token
    scatter-adds.  Each expert shard produces a partial (T, D) that XLA
    psums over 'model' — no all-gather of the (E, C, D) outputs
    (§Perf cell B, confirmed path)."""
    gates, token_slot = meta
    e = y.shape[0]
    target = jnp.full((e, cap), t, jnp.int32)           # t == dropped
    weight = jnp.zeros((e, cap), jnp.float32)
    for slot, (eid, bslot, keep) in enumerate(token_slot):
        tid = jnp.where(keep, jnp.arange(t), t)
        target = target.at[eid, bslot].set(tid, mode="drop")
        weight = weight.at[eid, bslot].set(
            jnp.where(keep, gates[:, slot], 0.0), mode="drop")
    out = jnp.zeros((t, d), jnp.float32)
    out = out.at[target.reshape(-1)].add(
        weight.reshape(-1, 1) * y.reshape(e * cap, d).astype(jnp.float32),
        mode="drop")
    return out


def _expert_ffn(p, buckets):
    """(..., E, C, D) x (E, D, F) batched expert SwiGLU."""
    g = jax.nn.silu(jnp.einsum("...ecd,edf->...ecf", buckets, p["wg"]))
    u = jnp.einsum("...ecd,edf->...ecf", buckets, p["wu"])
    return jnp.einsum("...ecf,efd->...ecd", g * u, p["wd"])


def moe_forward(p, cfg: ModelConfig, h):
    """h: (B, S, D) -> (B, S, D).

    Baseline path: GLOBAL capacity dispatch — tokens -> (E, C, D) buckets ->
    batched expert SwiGLU -> weighted combine.  Experts (leading E axis)
    shard over 'model' (EP); overflow tokens are dropped (their residual
    passes through), standard practice.

    ``cfg.moe_group_dispatch`` (beyond-paper §Perf optimization): routing,
    capacity and combine are computed PER DATA-SHARD GROUP (G = dp size), so
    the (G, E, Cg, D) buckets shard as (data, model, -, -) and the only
    cross-device movement is the model-axis all-to-all of routed tokens —
    GSPMD no longer reshards a global (E, C, D) tensor over all chips.
    """
    from repro.launch import context as ctx

    b, s, d = h.shape
    t = b * s
    e, kk = cfg.n_experts, cfg.top_k

    groups = ctx.dp_size() if cfg.moe_group_dispatch else 1
    if groups > 1 and b % groups == 0:
        tg = t // groups
        cap = int(cfg.capacity_factor * kk * tg / e + 1)
        x = h.reshape(groups, tg, d)
        x = ctx.constrain(x, "data*", None, None)
        buckets, meta = jax.vmap(
            lambda xx: _moe_dispatch(p, cfg, xx, cap))(x)   # (G, E, C, D)
        buckets = ctx.constrain(buckets, "data*", "model", None, None)
        y = _expert_ffn(p, buckets)                          # (G, E, C, D)
        y = ctx.constrain(y, "data*", "model", None, None)
        out = jax.vmap(
            lambda yy, gg, ts: _moe_combine_scatter(yy, (gg, ts), tg, d,
                                                    cap)
        )(y, meta[0], meta[1])
        out = ctx.constrain(out, "data*", None, None)
        out = out.astype(h.dtype).reshape(b, s, d)
    else:
        cap = int(cfg.capacity_factor * kk * t / e + 1)
        buckets, meta = _moe_dispatch(p, cfg, h.reshape(t, d), cap)
        y = _expert_ffn(p, buckets)
        out = _moe_combine(y, meta, t, d, cap).astype(h.dtype)
        out = out.reshape(b, s, d)

    if cfg.n_shared_experts:
        out = out + mlp_forward(p["shared"], "swiglu", h)
    if cfg.dense_residual:
        out = out + mlp_forward(p["dense"], "swiglu", h)
    return out


def moe_aux_loss(p, cfg: ModelConfig, h):
    """Load-balance auxiliary loss (Switch-style): E * sum_e f_e * p_e."""
    b, s, d = h.shape
    x = h.reshape(b * s, d).astype(jnp.float32)
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, idx = jax.lax.top_k(logits, cfg.top_k)
    frac = jnp.mean(jax.nn.one_hot(idx[:, 0], cfg.n_experts), axis=0)
    return cfg.n_experts * jnp.sum(frac * jnp.mean(probs, axis=0))
