"""Model stack: init / train-forward / prefill / decode for all families.

* Layers are stacked on a leading axis and applied with lax.scan (one layer
  lowered once -> small HLO even for 96-layer models) with optional remat.
* ``hybrid`` (zamba2): groups of `attn_every` Mamba2 layers followed by ONE
  shared full-attention block (parameters reused across groups — zamba2's
  signature trick); the shared block keeps a per-group KV cache.
* ``ssm`` (rwkv6): time-mix + channel-mix blocks, attention-free.
* ``audio`` / ``vlm``: the modality frontend is a STUB — inputs arrive as
  precomputed frame/patch embeddings of `frontend_dim` (per instructions);
  vlm additionally owns a token embedding for text decode with M-RoPE.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import mamba2, mlp, rwkv6
from repro.models.common import dense_init, rms_norm
from repro.models.config import ModelConfig

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------ init
def _init_block(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    if cfg.family == "ssm":
        return {"norm1": jnp.ones((cfg.d_model,), dt),
                "tm": rwkv6.init_rwkv6_timemix(k1, cfg, dt),
                "norm2": jnp.ones((cfg.d_model,), dt),
                "cm": rwkv6.init_rwkv6_chanmix(k2, cfg, dt)}
    if cfg.family == "hybrid":
        return {"norm1": jnp.ones((cfg.d_model,), dt),
                "mamba": mamba2.init_mamba2(k1, cfg, dt)}
    block = {"norm1": jnp.ones((cfg.d_model,), dt),
             "norm2": jnp.ones((cfg.d_model,), dt)}
    block["attn"] = (attn.init_mla(k1, cfg, dt) if cfg.mla
                     else attn.init_gqa(k1, cfg, dt))
    block["mlp"] = (mlp.init_moe(k2, cfg, dt) if cfg.moe
                    else mlp.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp,
                                      dt))
    return block


def init_params(cfg: ModelConfig, key) -> Params:
    dt = _dtype(cfg)
    keys = jax.random.split(key, 6)
    p: Params = {"final_norm": jnp.ones((cfg.d_model,), dt)}

    if cfg.frontend == "stub":
        p["frontend_w"] = dense_init(keys[0], (cfg.frontend_dim,
                                               cfg.d_model), dtype=dt)
    if cfg.frontend != "stub" or cfg.family == "vlm":
        p["embed"] = dense_init(keys[1], (cfg.vocab, cfg.d_model),
                                dtype=dt)
    if not cfg.tie_embeddings or "embed" not in p:
        p["lm_head"] = dense_init(keys[2], (cfg.d_model, cfg.vocab),
                                  dtype=dt)

    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        lkeys = jax.random.split(keys[3], groups * cfg.attn_every)
        stacked = jax.vmap(lambda k: _init_block(cfg, k))(lkeys)
        p["layers"] = jax.tree.map(
            lambda x: x.reshape(groups, cfg.attn_every, *x.shape[1:]),
            stacked)
        k4a, k4b = jax.random.split(keys[4])
        p["shared_attn"] = {
            "norm": jnp.ones((cfg.d_model,), dt),
            "attn": attn.init_gqa(k4a, cfg, dt),
            "norm2": jnp.ones((cfg.d_model,), dt),
            "mlp": mlp.init_mlp(k4b, cfg.d_model, cfg.d_ff, cfg.mlp, dt)}
    else:
        lkeys = jax.random.split(keys[3], cfg.n_layers)
        p["layers"] = jax.vmap(lambda k: _init_block(cfg, k))(lkeys)
    return p


# ----------------------------------------------------------------- blocks
def _apply_block(cfg: ModelConfig, lp: Params, h, pos):
    if cfg.family == "ssm":
        h = h + rwkv6.rwkv6_timemix_forward(
            lp["tm"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps))
        h = h + rwkv6.rwkv6_chanmix_forward(
            lp["cm"], cfg, rms_norm(h, lp["norm2"], cfg.norm_eps))
        return h
    if cfg.family == "hybrid":
        return h + mamba2.mamba2_forward(
            lp["mamba"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps))
    a = attn.mla_forward if cfg.mla else attn.gqa_forward
    h = h + a(lp["attn"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps), pos)
    x = rms_norm(h, lp["norm2"], cfg.norm_eps)
    h = h + (mlp.moe_forward(lp["mlp"], cfg, x) if cfg.moe
             else mlp.mlp_forward(lp["mlp"], cfg.mlp, x))
    return h


def _embed_inputs(cfg: ModelConfig, params: Params, batch):
    if cfg.frontend == "stub" and "embeds" in batch:
        h = batch["embeds"].astype(_dtype(cfg)) @ params["frontend_w"]
    else:
        h = params["embed"][batch["tokens"]]
    b, s = h.shape[:2]
    if cfg.mrope_sections is not None:
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(s)[None, None], (3, b, s))
    else:
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    return h, pos


def _lm_head(cfg: ModelConfig, params: Params, h):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and "embed" in params:
        return jnp.einsum("bsd,vd->bsv", h, params["embed"])
    return h @ params["lm_head"]


def forward_train(params: Params, cfg: ModelConfig, batch) -> jax.Array:
    """batch: {'tokens': (B,S) int32} or {'embeds': (B,S,Fd)} (+positions).
    Returns logits (B, S, vocab)."""
    h, pos = _embed_inputs(cfg, params, batch)

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(hh, gp):
            def mamba_body(hh2, lp):
                return _apply_block(cfg, lp, hh2, pos), None

            if cfg.remat:
                mamba_body = jax.checkpoint(mamba_body)
            hh, _ = jax.lax.scan(mamba_body, hh, gp,
                                 unroll=cfg.scan_unroll)
            hh = hh + attn.gqa_forward(
                shared["attn"], cfg,
                rms_norm(hh, shared["norm"], cfg.norm_eps), pos)
            hh = hh + mlp.mlp_forward(
                shared["mlp"], cfg.mlp,
                rms_norm(hh, shared["norm2"], cfg.norm_eps))
            return hh, None

        if cfg.remat:
            group_body = jax.checkpoint(group_body)
        h, _ = jax.lax.scan(group_body, h, params["layers"],
                            unroll=cfg.scan_unroll)
    else:
        def body(hh, lp):
            return _apply_block(cfg, lp, hh, pos), None

        if cfg.remat:
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["layers"],
                            unroll=cfg.scan_unroll)
    return _lm_head(cfg, params, h)


# ----------------------------------------------------------------- caches
def _block_cache(cfg: ModelConfig, b: int, cache_len: int, dtype):
    if cfg.family == "ssm":
        return rwkv6.rwkv6_cache_init(cfg, b, dtype)
    if cfg.family == "hybrid":
        return mamba2.mamba2_cache_init(cfg, b, dtype)
    if cfg.mla:
        return attn.mla_cache_init(cfg, b, cache_len, dtype)
    return attn.gqa_cache_init(cfg, b, cache_len, dtype)


def init_cache(cfg: ModelConfig, b: int, cache_len: int) -> Params:
    """Stacked (L, ...) cache pytree (decode scans over the leading axis)."""
    dt = _dtype(cfg)
    one = _block_cache(cfg, b, cache_len, dt)
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.attn_every
        cache = {
            "blocks": jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x[None, None], (groups, cfg.attn_every) + x.shape).copy(),
                one),
            "shared": jax.tree.map(
                lambda x: jnp.broadcast_to(x[None],
                                           (groups,) + x.shape).copy(),
                attn.gqa_cache_init(cfg, b, cache_len, dt)),
        }
        return cache
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x[None],
                                   (cfg.n_layers,) + x.shape).copy(), one)


def _decode_block(cfg: ModelConfig, lp, h, pos, cache):
    if cfg.family == "ssm":
        o, c1 = rwkv6.rwkv6_timemix_decode(
            lp["tm"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps),
            {"state": cache["state"], "x_tm": cache["x_tm"]})
        h = h + o
        o, c2 = rwkv6.rwkv6_chanmix_decode(
            lp["cm"], cfg, rms_norm(h, lp["norm2"], cfg.norm_eps),
            {"x_cm": cache["x_cm"]})
        h = h + o
        return h, {**c1, **c2}
    if cfg.family == "hybrid":
        o, c = mamba2.mamba2_decode(
            lp["mamba"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps), pos,
            cache)
        return h + o, c
    dec = attn.mla_decode if cfg.mla else attn.gqa_decode
    o, c = dec(lp["attn"], cfg, rms_norm(h, lp["norm1"], cfg.norm_eps),
               pos, cache)
    h = h + o
    x = rms_norm(h, lp["norm2"], cfg.norm_eps)
    h = h + (mlp.moe_forward(lp["mlp"], cfg, x) if cfg.moe
             else mlp.mlp_forward(lp["mlp"], cfg.mlp, x))
    return h, c


def decode_step(params: Params, cfg: ModelConfig, cache, tokens, pos):
    """One-token decode.  tokens: (B, 1) int32; pos: (B,) int32.
    Returns (logits (B, 1, V), new_cache)."""
    h = params["embed"][tokens]

    if cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(hh, xs):
            gp, gcache = xs

            def inner(hh2, xs2):
                lp, lc = xs2
                hh2, nc = _decode_block(cfg, lp, hh2, pos, lc)
                return hh2, nc

            hh, new_block_cache = jax.lax.scan(inner, hh,
                                               (gp, gcache["blocks"]),
                                               unroll=cfg.scan_unroll)
            o, nsh = attn.gqa_decode(
                shared["attn"], cfg,
                rms_norm(hh, shared["norm"], cfg.norm_eps), pos,
                gcache["shared"])
            hh = hh + o
            hh = hh + mlp.mlp_forward(
                shared["mlp"], cfg.mlp,
                rms_norm(hh, shared["norm2"], cfg.norm_eps))
            return hh, {"blocks": new_block_cache, "shared": nsh}

        h, new_cache = jax.lax.scan(
            group_body, h,
            (params["layers"],
             {"blocks": cache["blocks"], "shared": cache["shared"]}),
            unroll=cfg.scan_unroll)
    else:
        def body(hh, xs):
            lp, lc = xs
            hh, nc = _decode_block(cfg, lp, hh, pos, lc)
            return hh, nc

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache),
                                    unroll=cfg.scan_unroll)
    logits = _lm_head(cfg, params, h)
    return logits, new_cache


def prefill(params: Params, cfg: ModelConfig, batch,
            cache_len: Optional[int] = None):
    """Full-sequence forward that also populates the decode cache.
    ``cache_len``: total cache capacity (must cover prefill + decode tokens
    for full-attention archs; SWA archs clamp it to the window — the
    sub-quadratic long-context path).  Returns (last logits (B,1,V), cache).
    """
    h, pos = _embed_inputs(cfg, params, batch)
    b, s = h.shape[:2]
    dt = _dtype(cfg)
    cl = cache_len if cache_len is not None else s + 1

    def fill_gqa_cache(lp, hh):
        c = min(cl, cfg.sliding_window or cl)
        w = min(s, c)                      # tokens that fit the window
        q, k, v = attn._qkv(lp["attn"], cfg, hh)
        del q
        _, k = attn._rope_qk(cfg, jnp.zeros_like(k), k, pos)
        kw, vw = k[:, -w:], v[:, -w:]
        pw = jnp.broadcast_to(jnp.arange(s - w, s)[None], (b, w))
        slots = pw % c
        bidx = jnp.arange(b)[:, None]
        cache = attn.gqa_cache_init(cfg, b, c, dt)
        return {"k": cache["k"].at[bidx, slots].set(kw),
                "v": cache["v"].at[bidx, slots].set(vw),
                "kpos": cache["kpos"].at[bidx, slots].set(pw)}

    if cfg.family == "ssm":
        def body(hh, lp):
            nrm = rms_norm(hh, lp["norm1"], cfg.norm_eps)
            x_tm = nrm[:, -1]
            # recompute final state by running the scan (returns outputs);
            # we re-run _timemix capturing the state
            rkvgw = rwkv6._timemix_streams(
                lp["tm"], cfg, nrm,
                jnp.pad(nrm, ((0, 0), (1, 0), (0, 0)))[:, :-1])
            r, k, v, g, w = rkvgw
            nh, hd = rwkv6._heads(cfg)

            def stp(st, inp):
                rt, kt, vt, wt = inp
                st, out = rwkv6._wkv_step(st, rt, kt, vt, wt, lp["tm"]["u"],
                                          nh, hd)
                return st, out

            st0 = jnp.zeros((b, nh, hd, hd), hh.dtype)
            stN, outs = jax.lax.scan(
                stp, st0, (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
                           jnp.moveaxis(v, 1, 0),
                           jnp.moveaxis(w.astype(hh.dtype), 1, 0)))
            out = jnp.moveaxis(outs, 0, 1).reshape(b, s, cfg.d_model)
            out = rms_norm(out, lp["tm"]["ln_x"], cfg.norm_eps) * g
            hh = hh + out @ lp["tm"]["wo"]
            nrm2 = rms_norm(hh, lp["norm2"], cfg.norm_eps)
            hh = hh + rwkv6.rwkv6_chanmix_forward(lp["cm"], cfg, nrm2)
            return hh, {"state": stN, "x_tm": x_tm, "x_cm": nrm2[:, -1]}

        h, cache = jax.lax.scan(body, h, params["layers"],
                                unroll=cfg.scan_unroll)
    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def group_body(hh, gp):
            def inner(hh2, lp):
                nrm = rms_norm(hh2, lp["norm1"], cfg.norm_eps)
                z, xbc, dt_raw, d_inner, nheads, n = mamba2._split_proj(
                    lp["mamba"], cfg, nrm)
                xbc_conv = jax.nn.silu(mamba2._causal_conv(
                    xbc, lp["mamba"]["conv_w"], lp["mamba"]["conv_b"]))
                x, bm, cm = jnp.split(xbc_conv, [d_inner, d_inner + n], -1)
                dtv = jax.nn.softplus(
                    dt_raw.astype(jnp.float32)
                    + lp["mamba"]["dt_bias"][None, None])
                a = -jnp.exp(lp["mamba"]["a_log"])
                xh = x.reshape(b, s, nheads, cfg.ssm_head_dim)
                y, hlast = mamba2._ssd_chunked(
                    xh * dtv[..., None].astype(xh.dtype), dtv, a, bm, cm,
                    cfg.ssm_chunk)
                y = y + lp["mamba"]["d_skip"][None, None, :, None].astype(
                    y.dtype) * xh
                y = y.reshape(b, s, d_inner)
                y = rms_norm(y * jax.nn.silu(z), lp["mamba"]["out_norm"],
                             cfg.norm_eps)
                hh2 = hh2 + y @ lp["mamba"]["w_out"]
                return hh2, {"conv": xbc[:, -(cfg.ssm_conv - 1):],
                             "ssm": hlast}

            hh, bc = jax.lax.scan(inner, hh, gp, unroll=cfg.scan_unroll)
            nrm = rms_norm(hh, shared["norm"], cfg.norm_eps)
            sc = fill_gqa_cache({"attn": shared["attn"]}, nrm)
            hh = hh + attn.gqa_forward(shared["attn"], cfg, nrm, pos)
            hh = hh + mlp.mlp_forward(
                shared["mlp"], cfg.mlp,
                rms_norm(hh, shared["norm2"], cfg.norm_eps))
            return hh, {"blocks": bc, "shared": sc}

        h, cache = jax.lax.scan(group_body, h, params["layers"],
                                unroll=cfg.scan_unroll)
    else:
        def body(hh, lp):
            nrm = rms_norm(hh, lp["norm1"], cfg.norm_eps)
            a = attn.mla_forward if cfg.mla else attn.gqa_forward
            if cfg.mla:
                ckv = rms_norm(nrm @ lp["attn"]["wdkv"],
                               lp["attn"]["kv_norm"], cfg.norm_eps)
                kr = attn.apply_rope(
                    (nrm @ lp["attn"]["wkr"])[:, :, None, :], pos,
                    cfg.rope_theta)[:, :, 0]
                kpos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
                base = attn.mla_cache_init(cfg, b, cl, dt)
                lc = {"ckv": base["ckv"].at[:, :s].set(ckv),
                      "kr": base["kr"].at[:, :s].set(kr),
                      "kpos": base["kpos"].at[:, :s].set(kpos)}
            else:
                lc = fill_gqa_cache(lp, nrm)
            hh = hh + a(lp["attn"], cfg, nrm, pos)
            x = rms_norm(hh, lp["norm2"], cfg.norm_eps)
            hh = hh + (mlp.moe_forward(lp["mlp"], cfg, x) if cfg.moe
                       else mlp.mlp_forward(lp["mlp"], cfg.mlp, x))
            return hh, lc

        h, cache = jax.lax.scan(body, h, params["layers"],
                                unroll=cfg.scan_unroll)

    logits = _lm_head(cfg, params, h[:, -1:])
    return logits, cache


def count_params(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))
