"""Landmark compression subsystem (docs/compression.md).

Nystrom-projects truncated-center support windows onto m landmark rows so
serving cost is O(k * m) regardless of fit history — the ``compress``
axis of :class:`repro.api.SolverConfig` and the bounded-memory mode of
the always-on service.
"""
from repro.landmark.basis import (
    LandmarkBasis, jittered_solve, ridge_leverage_scores, select_rows,
    whitening_factor,
)
from repro.landmark.compress import (
    CompressInfo, CompressSpec, compress_center_state, compress_dist_state,
    compress_state, compress_windows, grow_window, spec_of, wrap_local_step,
    wrap_step,
)
from repro.landmark.serving import CompressedKernelCenters

__all__ = [
    "LandmarkBasis", "jittered_solve", "ridge_leverage_scores",
    "select_rows", "whitening_factor",
    "CompressInfo", "CompressSpec", "compress_center_state",
    "compress_dist_state", "compress_state", "compress_windows",
    "grow_window", "spec_of", "wrap_local_step", "wrap_step",
    "CompressedKernelCenters",
]
