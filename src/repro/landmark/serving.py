"""``CompressedKernelCenters`` — the bounded-cost serving representation.

A compressed model is k centers, each a beta-weighted combination of its
own m landmark rows: predict / transform / score cost O(k * m) per query
point, independent of how many ``partial_fit`` rounds produced it, and
the original support window is never touched again (it can be dropped,
archived, or kept only as the learner's resumable carry).

Serving reuses the SAME chunked kernels as the uncompressed path
(:func:`repro.core.minibatch.assign_chunked` /
:func:`center_distances_chunked`): the landmark rows flatten to a
(k * m, d) support array and the (k, m) beta matrix plays the coef role,
so the Actor's bucket / bit-exactness machinery serves compressed and
uncompressed models through one compiled program family.
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import KernelFn
from repro.landmark.compress import (
    CompressInfo, CompressSpec, compress_windows, spec_of,
)


class CompressedKernelCenters(NamedTuple):
    """k Nystrom-projected centers over per-center landmark rows."""

    kernel: KernelFn
    landmarks: jax.Array  # (k, m, d) rows (or (k, m, 1) index data)
    coef: jax.Array       # (k, m) projection coefficients beta
    sqnorm: jax.Array     # (k,) ||C~_j||^2

    @classmethod
    def from_serving(cls, kernel: KernelFn, sup: jax.Array,
                     coef: jax.Array, sqnorm: jax.Array, *,
                     spec=None, m: Optional[int] = None,
                     selector: str = "uniform", jitter: float = 1e-6,
                     step=0) -> Tuple["CompressedKernelCenters",
                                      CompressInfo]:
        """Compress a standard serving tuple — ``sup`` (k*W, d) support
        rows, ``coef`` (k, W), ``sqnorm`` (k,) — onto m landmarks per
        center.  ``step`` keys the (deterministic) uniform selection, so
        replaying the same fit history reproduces the same model
        bit-for-bit.  Returns ``(compressed, CompressInfo)``."""
        if spec is None:
            if m is None:
                raise ValueError("from_serving needs spec= or m=")
            spec = CompressSpec(every=0, m=int(m), selector=selector,
                                jitter=jitter)
        else:
            spec = spec_of(spec)
        k, w = coef.shape
        pts = sup.reshape(k, w, -1)
        step = jnp.asarray(step, jnp.int32)
        sel, beta, csq, info = compress_windows(
            kernel, pts, jnp.asarray(coef), jnp.asarray(sqnorm), step, spec)
        lm = jnp.take_along_axis(pts, sel[..., None], axis=1)
        return cls(kernel=kernel, landmarks=lm, coef=beta,
                   sqnorm=csq), info

    # --------------------------------------------------------------- shape
    @property
    def k(self) -> int:
        return self.coef.shape[0]

    @property
    def m(self) -> int:
        return self.coef.shape[1]

    def serving_tuple(self):
        """``(kernel, sup (k*m, d), coef (k, m), sqnorm (k,))`` — the
        exact contract of ``KernelKMeans._serving_tuple`` / the Actor."""
        km = self.k * self.m
        return (self.kernel, self.landmarks.reshape(km, -1), self.coef,
                self.sqnorm)

    # ------------------------------------------------------------- queries
    def predict(self, xq: jax.Array, chunk: int = 4096) -> jax.Array:
        from repro.core.minibatch import assign_chunked
        kern, sup, coef, sqnorm = self.serving_tuple()
        return assign_chunked(kern, coef, sqnorm, sup, jnp.asarray(xq),
                              chunk)

    def transform(self, xq: jax.Array, chunk: int = 4096) -> jax.Array:
        from repro.core.minibatch import center_distances_chunked
        kern, sup, coef, sqnorm = self.serving_tuple()
        return center_distances_chunked(kern, coef, sqnorm, sup,
                                        jnp.asarray(xq), chunk)

    def score(self, xq: jax.Array) -> float:
        """Negative mean min squared feature-space distance (sklearn
        convention, matching ``KernelKMeans.score``)."""
        return -float(jnp.mean(jnp.min(self.transform(xq), axis=1)))
