"""Landmark selection + Nyström projection — the numerical core of the
compression subsystem (docs/compression.md).

A set of m landmark rows Z spans an m-dimensional subspace
span{phi(z_1), ..., phi(z_m)} of the RKHS.  Projecting any element
C = sum_i c_i phi(x_i) onto that subspace is the normal-equation solve

    K_mm beta = K_mZ→support c        (beta = argmin ||C - sum beta_i phi(z_i)||)

and the orthonormalized feature map (the EigenPro-style subsampled
spectral basis, SNIPPETS.md snippets 1-2) is

    psi(x) = K_mm^{-1/2} K(Z, x).

Both factor through one jittered symmetric solve of K_mm: Cholesky when it
succeeds, a clipped-eigenvalue ``eigh`` fallback when the (numerically
rank-deficient) landmark Gram defeats it.  Everything here is pure jnp —
vmap/shard_map/jit-safe, so the same ops run inside a compiled while_loop
(the in-loop ``compress`` axis) and on the host (``KernelKMeans.compress``).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import KernelFn, diag_of, kernel_cross

_SELECTORS = ("uniform", "leverage")


def jittered_solve(kmm: jax.Array, rhs: jax.Array,
                   jitter: float) -> jax.Array:
    """Solve ``(K_mm + jitter * scale * I) beta = rhs`` for a symmetric
    PSD ``kmm``.  The jitter is RELATIVE (scaled by the mean diagonal),
    so one setting works across kernel magnitudes.  Cholesky is attempted
    first; entries that come back non-finite (a rank-deficient or
    duplicated landmark set) are replaced by the clipped-``eigh`` solve —
    both candidates are cheap at landmark sizes, and the ``where``-select
    keeps the op vmap-safe (no data-dependent control flow)."""
    m = kmm.shape[-1]
    kmm = kmm.astype(jnp.float32)
    rhs = rhs.astype(jnp.float32)
    scale = jnp.maximum(jnp.trace(kmm) / m, 1e-12)
    a = kmm + (jitter * scale) * jnp.eye(m, dtype=jnp.float32)
    chol = jnp.linalg.cholesky(a)
    y = jax.scipy.linalg.solve_triangular(chol, rhs, lower=True)
    beta_c = jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)
    evals, evecs = jnp.linalg.eigh(a)
    evals = jnp.maximum(evals, jitter * scale)
    beta_e = evecs @ ((evecs.T @ rhs) / evals)
    ok = jnp.all(jnp.isfinite(beta_c))
    return jnp.where(ok, beta_c, beta_e)


def whitening_factor(kmm: jax.Array, jitter: float):
    """``(evals, evecs)`` of the jittered landmark Gram with eigenvalues
    clipped from below — ``K_mm^{-1/2} v = evecs diag(evals^{-1/2})
    evecs^T v`` is then always well defined (the Nyström feature map)."""
    m = kmm.shape[-1]
    kmm = kmm.astype(jnp.float32)
    scale = jnp.maximum(jnp.trace(kmm) / m, 1e-12)
    a = kmm + (jitter * scale) * jnp.eye(m, dtype=jnp.float32)
    evals, evecs = jnp.linalg.eigh(a)
    return jnp.maximum(evals, jitter * scale), evecs


def ridge_leverage_scores(gram: jax.Array, lam: jax.Array) -> jax.Array:
    """diag(G (G + lam I)^{-1}) for a symmetric PSD ``gram`` — the ridge
    leverage score of every candidate row, via ``eigh`` (robust to the
    rank deficiency a window Gram with duplicated support rows has)."""
    evals, evecs = jnp.linalg.eigh(gram.astype(jnp.float32))
    evals = jnp.maximum(evals, 0.0)
    w = evals / (evals + lam)
    return jnp.einsum("ia,a,ia->i", evecs, w, evecs)


def select_rows(key: Optional[jax.Array], gram_or_none, mask: jax.Array,
                m: int, selector: str, jitter: float) -> jax.Array:
    """Pick ``m`` candidate row indices (static shape) out of the rows
    where ``mask`` is True.

    ``selector='uniform'``: Gumbel-top-m over the masked rows — a uniform
    draw without replacement, pure in ``key``.  ``'leverage'``: top-m by
    ridge leverage score of the candidate Gram (``gram_or_none`` must be
    the (c, c) candidate Gram) — deterministic, the leverage-score-sketch
    selector.  Fewer than m active rows: the masked (score -inf) rows
    fill the tail; their zero coefficients keep them inert downstream."""
    if selector == "uniform":
        if key is None:
            raise ValueError("selector='uniform' needs a PRNG key")
        scores = jax.random.gumbel(key, mask.shape, jnp.float32)
    elif selector == "leverage":
        c = mask.shape[0]
        g = jnp.where(mask[:, None] & mask[None, :], gram_or_none, 0.0)
        lam = jnp.maximum(jitter * jnp.trace(g) / c, 1e-12)
        scores = ridge_leverage_scores(g, lam)
    else:
        raise ValueError(f"selector={selector!r} not in {_SELECTORS}")
    scores = jnp.where(mask, scores, -jnp.inf)
    _, sel = jax.lax.top_k(scores, m)
    return sel.astype(jnp.int32)


class LandmarkBasis(NamedTuple):
    """A fitted landmark basis: the m landmark rows plus the spectral
    factorization of their (jittered) Gram.  Standalone entry point of
    the subsystem — :func:`repro.landmark.compress.compress_state` uses
    the same selection/solve primitives per center; this class is the
    reusable piece for EigenPro-style sibling estimators (features /
    project over an explicit candidate pool)."""

    kernel: KernelFn
    z: jax.Array        # (m, d) landmark rows (or (m, 1) index data)
    evals: jax.Array    # (m,)  clipped eigenvalues of the jittered K_mm
    evecs: jax.Array    # (m, m)

    @classmethod
    def build(cls, kernel: KernelFn, candidates: jax.Array, m: int, *,
              selector: str = "uniform", key: Optional[jax.Array] = None,
              weights: Optional[jax.Array] = None,
              jitter: float = 1e-6) -> "LandmarkBasis":
        """Select m landmarks from ``candidates`` (c, d) and factor their
        Gram.  ``weights`` (c,) marks active candidates (> 0); by default
        all rows are candidates.  ``selector='leverage'`` computes the
        candidate Gram once — for cached/precomputed kernels that is a
        Gram-strip gather, not a kernel evaluation."""
        c = candidates.shape[0]
        if not 1 <= m <= c:
            raise ValueError(f"m={m} not in [1, {c}]")
        mask = jnp.ones((c,), bool) if weights is None else (weights != 0)
        gram = None
        if selector == "leverage":
            gram = kernel_cross(kernel, candidates, candidates) \
                .astype(jnp.float32)
        sel = select_rows(key, gram, mask, m, selector, jitter)
        z = candidates[sel]
        kmm = (gram[sel][:, sel] if gram is not None
               else kernel_cross(kernel, z, z).astype(jnp.float32))
        evals, evecs = whitening_factor(kmm, jitter)
        return cls(kernel=kernel, z=z, evals=evals, evecs=evecs)

    # ------------------------------------------------------------ queries
    def features(self, x: jax.Array) -> jax.Array:
        """Nyström feature map ``psi(x) = K_mm^{-1/2} K(Z, x)`` — (nq, m)
        rows whose inner products approximate the kernel."""
        cross = kernel_cross(self.kernel, x, self.z).astype(jnp.float32)
        half = self.evecs * jax.lax.rsqrt(self.evals)[None, :]
        return cross @ (half @ self.evecs.T).T

    def project_coef(self, support: jax.Array,
                     coef: jax.Array) -> jax.Array:
        """Projection coefficients beta (m,) of ``sum_i coef_i
        phi(support_i)`` onto the landmark span: the normal-equation solve
        ``K_mm beta = K(Z, support) coef`` through the stored factor."""
        kms = kernel_cross(self.kernel, self.z, support).astype(jnp.float32)
        rhs = kms @ coef.astype(jnp.float32)
        return self.evecs @ ((self.evecs.T @ rhs) / self.evals)

    def max_feature_norm(self, x: jax.Array) -> jax.Array:
        """max_i ||phi(x_i)|| over rows — the gamma of the drift bound."""
        return jnp.sqrt(jnp.max(diag_of(self.kernel, x)))
