"""``compress_state`` — project truncated-center windows onto m landmark
rows, in place, with an objective-drift certificate.

Each center C_j = sum_w coef[j,w] phi(p_jw) is replaced by its ORTHOGONAL
projection onto the span of m landmarks selected from its own window:

    beta_j = K_mm^{-1} K_mW coef_j          (repro.landmark.basis solve)
    C~_j   = sum_i beta_ji phi(z_ji)

Because the update is a projection, delta_j = C_j - C~_j is orthogonal to
the landmark span, so ||C~_j||^2 = ||C_j||^2 - ||delta_j||^2 and for any
query point (gamma = max ||phi(x)||, 1 for normalized kernels):

    |d(x, C~_j) - d(x, C_j)| <= 2 gamma eps_j + eps_j^2,
    eps_j = ||delta_j||                                (docs/compression.md)

The per-call drift bound reported in :class:`CompressInfo` is the max of
that expression over centers; it bounds the batch-objective drift of ONE
compression and does not compound across cycles (each cycle projects the
CURRENT centers, and the fit between cycles re-descends the objective).

The op is shape-preserving: the (k, W) window arrays keep their shapes
with the first m slots holding the landmarks and the rest zeroed (the
``coef == 0`` empty-slot convention), and the ring head resets to m — so
the SAME compiled Algorithm-2 step keeps running afterwards, which is what
lets every executor trigger compression inside its loop.  The cadence
hook registers ONCE, in the fit-loop core —
:func:`repro.core.loop.compress_hook` wraps ``wrap_step`` /
``wrap_local_step`` below for both the single-device and the shard-local
step bodies (docs/architecture.md); executors opt in through their
``LoopSpec`` hooks rather than wiring the cadence themselves.
"""
from __future__ import annotations

from typing import Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.kernel_fns import (
    KernelFn, diag_of, gram_rows_fn, kernel_cross,
)
from repro.core.state import CenterState
from repro.landmark.basis import _SELECTORS, jittered_solve, select_rows

_KEY_SALT = 0x6C4D   # 'lm' — the in-loop selection key namespace


class CompressSpec(NamedTuple):
    """Static (hashable) compression parameters — rides ``MBConfig`` into
    the program-cache keys, so compressed and uncompressed programs never
    collide.  ``every=0`` disables the in-loop hook (round-cadence /
    explicit compression only)."""

    every: int = 0
    m: int = 64
    selector: str = "uniform"
    jitter: float = 1e-6


class CompressInfo(NamedTuple):
    residual: jax.Array       # (k,) ||C_j - C~_j||^2  (projection residual)
    sqnorm_before: jax.Array  # (k,)
    sqnorm_after: jax.Array   # (k,)
    drift_bound: jax.Array    # ()  max_j 2 gamma eps_j + eps_j^2


def spec_of(compress) -> Optional[CompressSpec]:
    """Normalize the ``SolverConfig.compress`` axis value — ``"off"`` /
    ``None``, a mapping, or a (possibly JSON-round-tripped) sequence of
    pairs — to a :class:`CompressSpec` (or ``None`` for off)."""
    if compress is None or compress == "off" or compress == ():
        return None
    if isinstance(compress, CompressSpec):
        d = compress._asdict()
    elif isinstance(compress, Mapping):
        d = dict(compress)
    else:
        try:
            d = {str(key): val for key, val in compress}
        except (TypeError, ValueError):
            raise ValueError(
                f"compress={compress!r}: expected 'off', a mapping like "
                "{'every': T, 'm': m, 'selector': ...}, or a sequence of "
                "pairs") from None
    unknown = set(d) - set(CompressSpec._fields)
    if unknown:
        raise ValueError(f"compress: unknown keys {sorted(unknown)} "
                         f"(expected {CompressSpec._fields})")
    if "m" not in d:
        raise ValueError("compress needs 'm' (the landmark count)")
    spec = CompressSpec(every=int(d.get("every", 0)), m=int(d["m"]),
                        selector=str(d.get("selector", "uniform")),
                        jitter=float(d.get("jitter", 1e-6)))
    if spec.m < 1:
        raise ValueError(f"compress m={spec.m} must be >= 1")
    if spec.every < 0:
        raise ValueError(f"compress every={spec.every} must be >= 0")
    if spec.selector not in _SELECTORS:
        raise ValueError(f"compress selector={spec.selector!r} not in "
                         f"{_SELECTORS}")
    if spec.jitter <= 0:
        raise ValueError("compress jitter must be > 0")
    return spec


def _center_keys(step: jax.Array, k: int, offset) -> jax.Array:
    """Per-center selection keys, pure in ``(step, global center id)`` —
    deterministic across resume/replay (bit-identical crash recovery) and
    decorrelated across model shards via ``offset``."""
    base = jax.random.fold_in(jax.random.PRNGKey(_KEY_SALT), step)
    return jax.vmap(lambda j: jax.random.fold_in(base, j))(
        jnp.arange(k, dtype=jnp.int32) + offset)


def compress_windows(kernel: KernelFn, pts: jax.Array, coef: jax.Array,
                     sqnorm: jax.Array, step: jax.Array,
                     spec: CompressSpec, offset=0):
    """The shared per-center projection over (k, W, d) window points
    (coordinates, or (k, W, 1) index data for cached/precomputed kernels).
    Returns ``(sel (k, m), beta (k, m), new_sqnorm (k,), CompressInfo)``.

    Kernels advertising ``gram_rows`` (the tile cache) resolve ALL k*W
    support strips in ONE lookup outside the per-center vmap — K_mW and
    K_mm then assemble as pure gathers from resident Gram strips (the
    ``cache/`` reuse path; a lookup under vmap would lower its cond to
    select and recompute strips on every hit)."""
    k, w = coef.shape
    m = spec.m
    if m > w:
        raise ValueError(f"compress m={m} exceeds window W={w} "
                         "(m <= tau + batch_size)")
    keys = _center_keys(step, k, offset)
    rows_fn = gram_rows_fn(kernel)
    grams = None
    if rows_fn is not None:
        from repro.cache.cached_kernel import window_grams
        grams = window_grams(kernel, pts)                      # (k, W, W)
    need_gram = spec.selector == "leverage"

    def one(key_j, pts_j, coef_j, sq_j, gram_j):
        mask = coef_j != 0
        sel = select_rows(key_j, gram_j, mask, m, spec.selector,
                          spec.jitter)
        if gram_j is not None:
            kmw = gram_j[sel]                                  # (m, W)
        else:
            kmw = kernel_cross(kernel, pts_j[sel], pts_j) \
                .astype(jnp.float32)
        # Mask empty window slots on BOTH sides: columns so they don't feed
        # the projection, rows so filler landmarks (selected when fewer than
        # m slots are active) stay inert — the jittered diagonal then pins
        # their beta at exactly 0, preserving the coef==0 slot convention.
        kmw = kmw * (mask[sel][:, None] & mask[None, :]).astype(jnp.float32)
        kmm = kmw[:, sel]
        rhs = kmw @ coef_j.astype(jnp.float32)
        beta = jittered_solve(kmm, rhs, spec.jitter)
        csq = beta @ (kmm @ beta)
        resid = jax.nn.relu(sq_j - 2.0 * (beta @ rhs) + csq)
        return sel, beta, csq, resid

    if grams is None and need_gram:
        grams = jax.vmap(
            lambda p: kernel_cross(kernel, p, p).astype(jnp.float32))(pts)
    if grams is not None:
        sel, beta, csq, resid = jax.vmap(one)(keys, pts, coef, sqnorm,
                                              grams)
    else:
        sel, beta, csq, resid = jax.vmap(
            lambda kj, pj, cj, sj: one(kj, pj, cj, sj, None))(
            keys, pts, coef, sqnorm)

    gamma = jnp.sqrt(jnp.maximum(
        jnp.max(diag_of(kernel, pts.reshape(k * w, -1))), 0.0))
    eps = jnp.sqrt(resid)
    info = CompressInfo(residual=resid, sqnorm_before=sqnorm,
                        sqnorm_after=csq,
                        drift_bound=jnp.max(2.0 * gamma * eps + resid))
    return sel, beta, csq, info


def compress_center_state(kernel: KernelFn, state: CenterState,
                          x: jax.Array, spec: CompressSpec, offset=0):
    """Project a :class:`CenterState` onto m landmark rows drawn from its
    own support — shape-preserving (see module docstring).  ``x`` is the
    dataset the window indices point into (the index-data view for
    cached/precomputed kernels).  Returns ``(state', CompressInfo)``."""
    k, w = state.idx.shape
    pts = x[state.idx.reshape(-1)].reshape(k, w, -1)
    sel, beta, csq, info = compress_windows(kernel, pts, state.coef,
                                            state.sqnorm, state.step,
                                            spec, offset)
    lm_idx = jnp.take_along_axis(state.idx, sel, axis=1)       # (k, m)
    new_idx = jnp.zeros_like(state.idx).at[:, :spec.m].set(lm_idx)
    new_coef = jnp.zeros_like(state.coef).at[:, :spec.m].set(beta)
    head = jnp.full_like(state.head, spec.m % w)
    return state._replace(idx=new_idx, coef=new_coef, head=head,
                          sqnorm=csq), info


def compress_dist_state(kernel: KernelFn, state, spec: CompressSpec,
                        offset=0):
    """:func:`compress_center_state` for the sharded coordinate-window (or
    index-window) ``DistState`` — fully center-local, so it runs inside
    the model-sharded ``shard_map`` body with zero collectives."""
    k, w, _ = state.pts.shape
    sel, beta, csq, info = compress_windows(kernel, state.pts, state.coef,
                                            state.sqnorm, state.step,
                                            spec, offset)
    lm = jnp.take_along_axis(state.pts, sel[..., None], axis=1)
    new_pts = jnp.zeros_like(state.pts).at[:, :spec.m].set(lm)
    new_coef = jnp.zeros_like(state.coef).at[:, :spec.m].set(beta)
    head = jnp.full_like(state.head, spec.m % w)
    return state._replace(pts=new_pts, coef=new_coef, head=head,
                          sqnorm=csq), info


def compress_state(kernel: KernelFn, state, compress, x=None):
    """Dispatching front door: compress any supported center-support
    representation (``CenterState`` — needs ``x`` — or ``DistState``)
    onto m landmark rows.  ``compress`` is anything :func:`spec_of`
    accepts.  Returns ``(state', CompressInfo)``."""
    spec = spec_of(compress)
    if spec is None:
        raise ValueError("compress_state called with compress='off'")
    if isinstance(state, CenterState):
        if x is None:
            raise ValueError("CenterState compression needs the dataset x "
                             "its window indices point into")
        return compress_center_state(kernel, state, x, spec)
    if hasattr(state, "pts"):
        return compress_dist_state(kernel, state, spec)
    raise TypeError(f"cannot compress state of type {type(state).__name__}")


# ----------------------------------------------------------- in-loop hooks
def wrap_step(step, kernel: KernelFn, spec: CompressSpec):
    """Wrap a ``make_step`` step so every ``spec.every``-th iteration ends
    with an in-place landmark projection — same (state, x, batch_idx)
    signature and state shapes, so jit/while_loop/donation all carry over.
    (Under a vmapped driver — the multi-restart engine — the ``cond``
    lowers to ``select`` and the projection is computed every step and
    discarded off-cadence; correct, just not free.)"""

    def step2(state, x, batch_idx):
        state, info = step(state, x, batch_idx)
        state = jax.lax.cond(
            (state.step % spec.every) == 0,
            lambda s: compress_center_state(kernel, s, x, spec)[0],
            lambda s: s, state)
        return state, info

    return step2


def wrap_local_step(local_step, kernel: KernelFn, spec: CompressSpec,
                    model_axis: str):
    """The sharded counterpart of :func:`wrap_step` — wraps the
    shard-local Algorithm-2 body; centers are model-sharded, so the
    projection is device-local (selection keys fold in the GLOBAL center
    id via the model-axis index)."""

    def step2(state, xb_loc, w_loc=None, b_eff=None):
        state, info = local_step(state, xb_loc, w_loc=w_loc, b_eff=b_eff)
        k_loc = state.coef.shape[0]
        offset = jax.lax.axis_index(model_axis) * k_loc
        state = jax.lax.cond(
            (state.step % spec.every) == 0,
            lambda s: compress_dist_state(kernel, s, spec,
                                          offset=offset)[0],
            lambda s: s, state)
        return state, info

    return step2


# ------------------------------------------------------- unbounded windows
def grow_window(state: CenterState, extra: int) -> CenterState:
    """Widen the ring window by ``extra`` empty slots (inserted at the
    write head, preserving ring order) — the no-eviction "unbounded
    stream" mode: nothing is ever truncated, so serving cost grows
    linearly with fit history.  This is the baseline the ``compress``
    axis bounds (benchmarks/run.py ``landmark``); the Algorithm-2 step
    reads W from the state shape, so fitting continues unchanged (at the
    cost of a per-growth recompile)."""
    if extra <= 0:
        return state
    k, w = state.idx.shape
    pos = jnp.arange(w)

    def one(idx_row, coef_row, h):
        dest = jnp.where(pos < h, pos, pos + extra)
        idx2 = jnp.zeros((w + extra,), idx_row.dtype).at[dest].set(idx_row)
        coef2 = jnp.zeros((w + extra,),
                          coef_row.dtype).at[dest].set(coef_row)
        return idx2, coef2

    idx2, coef2 = jax.vmap(one)(state.idx, state.coef, state.head)
    return state._replace(idx=idx2, coef=coef2)
