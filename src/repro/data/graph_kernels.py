"""Graph-based kernels from the paper's Appendix C.

* k-nn kernel:  gram = D^-1 A D^-1, A = symmetrized k-nn adjacency with
  self-loops (self-loops keep K(x,x) > 0 so gamma is well defined; the
  paper's Table 1 reports gamma ~ 1e-3 for this kernel — it is D^-1's
  doing, and our construction reproduces that scale).
* heat kernel:  gram = expm(-t * L),  L = I - D^-1/2 A D^-1/2, via
  eigendecomposition (symmetric => PSD for every t).  NOTE: the paper's
  Appendix C literally writes exp(-t D^-1/2 A D^-1/2), but cites Chung
  (1997), whose heat kernel is e^{-tL}; the literal formula inverts the
  spectrum (up-weights high-frequency eigenvectors), so we implement
  Chung's definition.  gamma << 1 here matches the paper's Table 1.

These return `Precomputed` kernels whose "data" is the (n, 1) index array —
see repro.core.kernel_fns.  Construction is O(n^2 d) (exact k-nn); the paper
treats kernel construction as a separate preprocessing cost (the black bar
in Figure 1) and so do we.
"""
from __future__ import annotations

import numpy as np

from repro.core.kernel_fns import Precomputed


def knn_adjacency(x: np.ndarray, k: int = 10) -> np.ndarray:
    """Symmetrized k-nn 0/1 adjacency with self-loops, exact O(n^2 d)."""
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    sq = (x * x).sum(1)
    d2 = sq[:, None] + sq[None, :] - 2.0 * (x @ x.T)
    nn = np.argsort(d2, axis=1)[:, : k + 1]  # includes self (distance 0)
    a = np.zeros((n, n), np.float32)
    rows = np.repeat(np.arange(n), k + 1)
    a[rows, nn.ravel()] = 1.0
    a = np.maximum(a, a.T)  # symmetrize (union)
    np.fill_diagonal(a, 1.0)
    return a


def knn_kernel(x: np.ndarray, k: int = 10):
    """gram = D^-1 A D^-1; returns (Precomputed, index_data (n,1) f32)."""
    a = knn_adjacency(x, k)
    dinv = 1.0 / a.sum(1)
    gram = (dinv[:, None] * a) * dinv[None, :]
    idx = np.arange(a.shape[0], dtype=np.float32)[:, None]
    return Precomputed(gram=gram), idx


def heat_kernel(x: np.ndarray, k: int = 10, t: float = 1.0):
    """gram = expm(-t (I - D^-1/2 A D^-1/2)) (Chung 1997), PSD for all t."""
    a = knn_adjacency(x, k)
    dq = 1.0 / np.sqrt(a.sum(1))
    m = (dq[:, None] * a) * dq[None, :]
    w, u = np.linalg.eigh(m.astype(np.float64))
    gram = (u * np.exp(-t * (1.0 - w))[None, :]) @ u.T
    idx = np.arange(a.shape[0], dtype=np.float32)[:, None]
    return Precomputed(gram=gram.astype(np.float32)), idx
