"""Synthetic clustering datasets.

The paper evaluates on MNIST / PenDigits / Letters / HAR, none of which are
available offline here.  These generators produce the two regimes the paper's
claims rely on:

* linearly separable mixtures (``blobs``, ``anisotropic``) where plain
  k-means already works, and
* non-linearly-separable manifolds (``circles``, ``moons``) where kernel
  k-means succeeds and plain k-means provably cannot (the paper's motivation).

All generators are deterministic in ``seed`` and return ``(X, y)`` float32 /
int32 numpy arrays.
"""
from __future__ import annotations

import numpy as np


def blobs(n: int = 2000, d: int = 16, k: int = 8, spread: float = 0.15,
          seed: int = 0):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(k, d))
    centers /= np.linalg.norm(centers, axis=1, keepdims=True)
    y = rng.integers(0, k, size=n)
    x = centers[y] + spread * rng.normal(size=(n, d))
    return x.astype(np.float32), y.astype(np.int32)


def anisotropic(n: int = 2000, d: int = 8, k: int = 4, seed: int = 0):
    x, y = blobs(n, d, k, spread=0.4, seed=seed)
    rng = np.random.default_rng(seed + 1)
    transform = np.eye(d) + 0.6 * rng.normal(size=(d, d)) / np.sqrt(d)
    return (x @ transform).astype(np.float32), y


def circles(n: int = 2000, noise: float = 0.05, factor: float = 0.45,
            seed: int = 0):
    """Two concentric circles — the canonical kernel-k-means win."""
    rng = np.random.default_rng(seed)
    n_out = n // 2
    n_in = n - n_out
    t_out = rng.uniform(0, 2 * np.pi, n_out)
    t_in = rng.uniform(0, 2 * np.pi, n_in)
    x = np.concatenate([
        np.stack([np.cos(t_out), np.sin(t_out)], axis=1),
        factor * np.stack([np.cos(t_in), np.sin(t_in)], axis=1),
    ])
    x += noise * rng.normal(size=x.shape)
    y = np.concatenate([np.zeros(n_out), np.ones(n_in)])
    perm = rng.permutation(n)
    return x[perm].astype(np.float32), y[perm].astype(np.int32)


def moons(n: int = 2000, noise: float = 0.06, seed: int = 0):
    rng = np.random.default_rng(seed)
    n_a = n // 2
    n_b = n - n_a
    ta = rng.uniform(0, np.pi, n_a)
    tb = rng.uniform(0, np.pi, n_b)
    a = np.stack([np.cos(ta), np.sin(ta)], axis=1)
    b = np.stack([1.0 - np.cos(tb), 0.5 - np.sin(tb)], axis=1)
    x = np.concatenate([a, b]) + noise * rng.normal(size=(n, 2))
    y = np.concatenate([np.zeros(n_a), np.ones(n_b)])
    perm = rng.permutation(n)
    return x[perm].astype(np.float32), y[perm].astype(np.int32)


_REGISTRY = {
    "blobs": blobs,
    "anisotropic": anisotropic,
    "circles": circles,
    "moons": moons,
}


def make_dataset(name: str, **kw):
    """Paper-dataset stand-ins with matched (n, d, k):

    mnist-like   -> blobs(n=70000, d=784, k=10)  [shape proxy]
    pendigits-like -> blobs(n=10992, d=16, k=10)
    letters-like -> blobs(n=20000, d=16, k=26)
    har-like     -> blobs(n=10299, d=561, k=6)
    """
    proxies = {
        "mnist-like": dict(fn=blobs, n=70000, d=784, k=10),
        "pendigits-like": dict(fn=blobs, n=10992, d=16, k=10),
        "letters-like": dict(fn=blobs, n=20000, d=16, k=26),
        "har-like": dict(fn=blobs, n=10299, d=561, k=6),
    }
    if name in proxies:
        spec = dict(proxies[name])
        fn = spec.pop("fn")
        spec.update(kw)
        return fn(**spec)
    return _REGISTRY[name](**kw)
