from repro.data.synthetic import (  # noqa: F401
    anisotropic,
    blobs,
    circles,
    moons,
    make_dataset,
)
