"""Deterministic, resumable data pipelines.

Every batch is a pure function of (seed, step): restarting after a failure
needs no iterator state — restore the checkpoint's step counter and the
stream continues exactly (tested in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    """Synthetic LM token stream with a learnable structure (Zipf-ish
    unigram + short-range repetition) so training loss measurably drops."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        probs = 1.0 / np.arange(1, vocab + 1) ** 1.1
        self._probs = jnp.asarray(probs / probs.sum(), jnp.float32)

    def __call__(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        toks = jax.random.choice(k1, self.vocab, (self.batch, self.seq),
                                 p=self._probs)
        # inject copy structure: every even position repeats the previous
        # token with p=0.5 (gives the model something to learn)
        rep = jax.random.bernoulli(k2, 0.5, (self.batch, self.seq))
        shifted = jnp.roll(toks, 1, axis=1)
        toks = jnp.where(rep & (jnp.arange(self.seq)[None] % 2 == 0),
                         shifted, toks).astype(jnp.int32)
        labels = jnp.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels}


class EmbedPipeline:
    """Precomputed frame/patch embeddings for stub-frontend archs."""

    def __init__(self, dim: int, batch: int, seq: int, seed: int = 0,
                 vocab: int = 512):
        self.dim, self.batch, self.seq, self.seed = dim, batch, seq, seed
        self.vocab = vocab

    def __call__(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed ^ 0x5EED),
                                 step)
        k1, k2 = jax.random.split(key)
        emb = jax.random.normal(k1, (self.batch, self.seq, self.dim),
                                jnp.float32)
        labels = jax.random.randint(k2, (self.batch, self.seq), 0,
                                    self.vocab, jnp.int32)
        return {"embeds": emb, "labels": labels}


class ClusterBatchPipeline:
    """(b, d) point batches for the distributed clustering service —
    sampling from a host-resident dataset, keyed by step (resumable: every
    batch is a pure function of (seed, step), so restoring a checkpoint's
    step counter continues the stream exactly).

    ``mode='iid'`` is the paper's uniform-with-replacement model.
    ``mode='nested'`` mirrors ``repro.core.minibatch.sample_batch_nested``
    (Newling & Fleuret-style reuse): the first ``reuse * batch`` positions
    refresh only every ``refresh`` steps (staggered by position), the tail
    is fresh each step — consecutive batches share most rows, which keeps
    the Gram tile cache (repro.cache) hot in the serving/fit loop.
    Marginally each position is still uniform over the dataset.
    ``mode='keyed'`` draws batch t with ``repro.core.minibatch
    .sample_batch`` keyed by the t-th key of the UNIFIED fit-key stream
    (``repro.api.keys``) — the host-driven sharded solver plan feeds this
    stream to the shard_map step, so its batches match what the on-device
    plans would draw from the same fit key.  Still pure in (key, step): a
    sequential cursor makes in-order access O(1), random access replays
    the split chain."""

    def __init__(self, x: np.ndarray, batch: int, seed: int = 0,
                 mode: str = "iid", reuse: float = 0.5, refresh: int = 8,
                 key=None):
        if mode not in ("iid", "nested", "keyed"):
            raise ValueError(mode)
        self.x, self.batch, self.seed = np.asarray(x), batch, seed
        self.mode, self.reuse, self.refresh = mode, reuse, refresh
        if mode == "keyed":
            from repro.api import keys as api_keys
            self._base_key = api_keys.as_key(seed if key is None else key)
            self._cursor = None          # (next_step, carried key)

    def _keyed_indices(self, step: int) -> np.ndarray:
        from repro.api import keys as api_keys
        from repro.core.minibatch import sample_batch

        if self._cursor is None or step < self._cursor[0]:
            self._cursor = (0, self._base_key)
        s, key = self._cursor
        kb = None
        while s <= step:
            key, kb = api_keys.next_batch_key(key)
            s += 1
        self._cursor = (s, key)
        return np.asarray(sample_batch(kb, self.x.shape[0], self.batch))

    def batch_indices(self, step: int) -> np.ndarray:
        """The (b,) row indices of batch ``step`` — pure in (seed, step)."""
        n = self.x.shape[0]
        if self.mode == "keyed":
            return self._keyed_indices(step)
        if self.mode == "iid":
            rng = np.random.default_rng((self.seed, step))
            return rng.integers(0, n, self.batch)
        m = int(self.batch * self.reuse)
        head = np.empty((m,), np.int64)
        for i in range(m):
            epoch = (step + i) // self.refresh
            head[i] = np.random.default_rng(
                (self.seed, i, epoch)).integers(0, n)
        tail = np.random.default_rng((self.seed, step, 0x7A11)) \
            .integers(0, n, self.batch - m)
        return np.concatenate([head, tail])

    def __call__(self, step: int):
        return self.x[self.batch_indices(step)]

    def __iter__(self):
        step = 0
        while True:
            yield self(step)
            step += 1
