"""Deterministic, resumable data pipelines.

Every batch is a pure function of (seed, step): restarting after a failure
needs no iterator state — restore the checkpoint's step counter and the
stream continues exactly (tested in tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    """Synthetic LM token stream with a learnable structure (Zipf-ish
    unigram + short-range repetition) so training loss measurably drops."""

    def __init__(self, vocab: int, batch: int, seq: int, seed: int = 0):
        self.vocab, self.batch, self.seq, self.seed = vocab, batch, seq, seed
        probs = 1.0 / np.arange(1, vocab + 1) ** 1.1
        self._probs = jnp.asarray(probs / probs.sum(), jnp.float32)

    def __call__(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        toks = jax.random.choice(k1, self.vocab, (self.batch, self.seq),
                                 p=self._probs)
        # inject copy structure: every even position repeats the previous
        # token with p=0.5 (gives the model something to learn)
        rep = jax.random.bernoulli(k2, 0.5, (self.batch, self.seq))
        shifted = jnp.roll(toks, 1, axis=1)
        toks = jnp.where(rep & (jnp.arange(self.seq)[None] % 2 == 0),
                         shifted, toks).astype(jnp.int32)
        labels = jnp.roll(toks, -1, axis=1)
        return {"tokens": toks, "labels": labels}


class EmbedPipeline:
    """Precomputed frame/patch embeddings for stub-frontend archs."""

    def __init__(self, dim: int, batch: int, seq: int, seed: int = 0,
                 vocab: int = 512):
        self.dim, self.batch, self.seq, self.seed = dim, batch, seq, seed
        self.vocab = vocab

    def __call__(self, step: int):
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed ^ 0x5EED),
                                 step)
        k1, k2 = jax.random.split(key)
        emb = jax.random.normal(k1, (self.batch, self.seq, self.dim),
                                jnp.float32)
        labels = jax.random.randint(k2, (self.batch, self.seq), 0,
                                    self.vocab, jnp.int32)
        return {"embeds": emb, "labels": labels}


class ClusterBatchPipeline:
    """(b, d) point batches for the distributed clustering service —
    uniform-with-replacement sampling from a host-resident dataset, keyed
    by step (the paper's sampling model, resumable)."""

    def __init__(self, x: np.ndarray, batch: int, seed: int = 0):
        self.x, self.batch, self.seed = np.asarray(x), batch, seed

    def __call__(self, step: int):
        rng = np.random.default_rng((self.seed, step))
        idx = rng.integers(0, self.x.shape[0], self.batch)
        return self.x[idx]

    def __iter__(self):
        step = 0
        while True:
            yield self(step)
            step += 1
